"""Int8 error-feedback gradient compression."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import EFState, compress, compressed_psum, decompress, init_ef


def test_compress_roundtrip_bound(rng):
    g = jnp.asarray(rng.normal(size=(128,)) * 5, jnp.float32)
    q, scale = compress(g)
    back = decompress(q, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) / 2 + 1e-6
    assert q.dtype == jnp.int8


def test_psum_path_roundtrips_through_compress(rng):
    """Regression: the psum path must quantize through the same helper as
    standalone compress() — with the pmax'd amax passed in, its transmitted
    value is exactly decompress(compress(g, amax)) and the standalone
    round-trip bound holds inside the collective path too."""
    from repro.launch.mesh import compat_make_mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = compat_make_mesh((1,), ("dp",))

    g = jnp.asarray(rng.normal(size=(64,)) * 3, jnp.float32)
    ef = init_ef({"w": g})

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_rep=False)
    def step(g, r):
        out, ef2 = compressed_psum({"w": g}, EFState(residual={"w": r}), "dp")
        return out["w"], ef2.residual["w"]

    sent, resid = step(g, ef.residual["w"])
    q, scale = compress(g)                      # 1 worker: pmax == local amax
    np.testing.assert_array_equal(np.asarray(sent),
                                  np.asarray(decompress(q, scale)))
    # residual is exactly what int8 dropped, bounded by half a code step
    np.testing.assert_array_equal(np.asarray(resid),
                                  np.asarray(g - decompress(q, scale)))
    assert float(jnp.abs(resid).max()) <= float(scale) / 2 + 1e-6


def test_compress_external_amax_roundtrip_bound(rng):
    """compress() with a caller-supplied (e.g. pmax'd) bound still
    round-trips within half a step of the *wider* grid."""
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    amax = jnp.max(jnp.abs(g)) * 4.0            # another worker's larger amax
    q, scale = compress(g, amax)
    assert q.dtype == jnp.int8
    # multiply-form grid (bound * (1/127)): the division form was rewritten
    # inconsistently between eager and jitted code (see compress())
    assert float(scale) == float(jnp.maximum(amax, 1e-12) * (1.0 / 127.0))
    assert float(jnp.abs(decompress(q, scale) - g).max()) \
        <= float(scale) / 2 + 1e-6


def test_error_feedback_unbiased_over_steps(rng):
    """Sum of transmitted values + residual == sum of true gradients."""
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("dp",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    grads = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    ef = init_ef(grads)
    sent_total = jnp.zeros(32)
    true_total = jnp.zeros(32)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_rep=False)
    def step(g, r):
        out, ef2 = compressed_psum({"w": g}, EFState(residual={"w": r}), "dp")
        return out["w"], ef2.residual["w"]

    r = ef.residual["w"]
    for i in range(5):
        g = grads["w"] * (i + 1)
        sent, r = step(g, r)
        sent_total = sent_total + sent
        true_total = true_total + g
    # transmitted + final residual == true sum (error feedback invariant)
    np.testing.assert_allclose(np.asarray(sent_total + r),
                               np.asarray(true_total), rtol=1e-4, atol=1e-4)


def test_ef_sgd_converges_like_exact(rng):
    """EF-compressed SGD reaches the same quadratic minimum."""
    target = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    x_ef = jnp.zeros(16)
    x_ex = jnp.zeros(16)
    resid = jnp.zeros(16)
    lr = 0.2
    for _ in range(60):
        g_ef = (x_ef - target) + resid
        q, s = compress(g_ef)
        sent = decompress(q, s)
        resid = g_ef - sent
        x_ef = x_ef - lr * sent
        x_ex = x_ex - lr * (x_ex - target)
    assert float(jnp.linalg.norm(x_ef - target)) < 0.05
    assert float(jnp.linalg.norm(x_ef - x_ex)) < 0.05
