"""The centralized interpret-mode knob (repro.kernels.runtime)."""
import pathlib
import re

import pytest

from repro.kernels.runtime import interpret_default, resolve_interpret

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def test_default_is_interpret(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    assert interpret_default() is True


@pytest.mark.parametrize("value,expect", [
    ("0", False), ("false", False), ("no", False), ("off", False),
    ("", False), ("  FALSE  ", False),
    ("1", True), ("true", True), ("compiled-anyway", True),
])
def test_env_override(monkeypatch, value, expect):
    monkeypatch.setenv("REPRO_INTERPRET", value)
    assert interpret_default() is expect


def test_resolve_explicit_wins(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    assert resolve_interpret(None) is False
    monkeypatch.delenv("REPRO_INTERPRET")
    assert resolve_interpret(None) is True


def test_no_hardcoded_interpret_defaults():
    """No kernel wrapper may regress to ``interpret: bool = True`` — the
    default lives in runtime.interpret_default() so flipping to compiled
    Mosaic kernels stays a one-env-var switch."""
    pat = re.compile(r"interpret\s*:\s*bool\s*=\s*(True|False)")
    offenders = []
    for path in SRC.rglob("*.py"):
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_every_pallas_call_resolves():
    """Every ``pallas_call(... interpret=...)`` site must route through
    resolve_interpret (or an Acu field that defaults to None)."""
    for path in SRC.rglob("kernel.py"):
        src = path.read_text()
        if "pallas_call" not in src:
            continue
        raw = re.findall(r"interpret=interpret\b", src)
        assert not raw, f"{path}: pallas_call takes raw interpret argument"
