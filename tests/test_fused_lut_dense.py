"""Fused quantize->LUT-GEMM->dequant kernel: bit-exactness vs the pure-jnp
oracle (``Acu._lut_matmul_jnp`` + ``_affine_matmul_dequant``), interpret mode.

"Bit-exact" here is literal float equality: the kernel must perform the same
quantize, the same int32 accumulate (with integer-space K-pad correction), and
the same single combined-scale dequant ``acc * (xs * ws)`` as the unfused
reference pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, get_multiplier, make_acu, matmul_plan
from repro.core.acu import Acu, AcuMode
from repro.core.approx_ops import (ApproxConfig, _affine_matmul_dequant,
                                   approx_dense, approx_matmul)
from repro.core.quantization import (QParams, acu_operand, affine_qparams,
                                     quantize, symmetric_qparams)
from repro.kernels.fused_lut_dense.ops import fused_lut_dense
from repro.kernels.fused_lut_dense.ref import fused_lut_dense_ref

MULT = get_multiplier("mul8s_1L2H")
LUT = jnp.asarray(build_lut(MULT))
ACU = make_acu("mul8s_1L2H", AcuMode.LUT)
ACU_PALLAS = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)


def unfused_oracle(x, w, xqp, wqp, acu=ACU):
    """The three-stage reference pipeline the fused kernel replaces."""
    a = acu_operand(quantize(x, xqp), xqp)
    wq = acu_operand(quantize(w, wqp), wqp)
    acc = acu._lut_matmul_jnp(a, wq)
    return _affine_matmul_dequant(acc, xqp, wqp)


@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (130, 70, 50),
                                   (1, 257, 3), (256, 8, 384), (33, 64, 129)])
def test_fused_matches_oracle_shapes(shape):
    """Shape sweep incl. non-divisible M/K/N; per-channel weight scales."""
    M, K, N = shape
    rng = np.random.default_rng(M * K + N)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    wq = acu_operand(quantize(w, wqp), wqp)
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True)
    ref = unfused_oracle(x, w, xqp, wqp)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("zp_case", ["zero", "mid", "lo_edge", "hi_edge"])
def test_fused_zero_point_edges(zp_case):
    """Affine activation quantization: zero-point at 0, mid-range, and the
    clip-range edges. a_bits=7 keeps shifted codes inside the 8-bit ACU's
    operand range even at the edges."""
    bits = 7
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    zp = {"zero": 0.0, "mid": 11.0, "lo_edge": float(lo),
          "hi_edge": float(hi)}[zp_case]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(20, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
    xqp = QParams(scale=jnp.float32(0.05), zero_point=jnp.float32(zp),
                  bits=bits)
    wqp = symmetric_qparams(jnp.max(jnp.abs(w)), 8)
    wq = acu_operand(quantize(w, wqp), wqp)
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=bits, interpret=True)
    ref = unfused_oracle(x, w, xqp, wqp)
    assert jnp.array_equal(out, ref)


def test_fused_kernel_matches_own_ref():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(17, 130)), jnp.float32)
    wq = jnp.asarray(rng.integers(-128, 128, (130, 21)), jnp.int32)
    ws = jnp.asarray(np.abs(rng.normal(size=(21,))) * 0.02 + 1e-4, jnp.float32)
    out = fused_lut_dense(x, wq, LUT, 128, 0.03, -5.0, ws, bits=8,
                          interpret=True)
    ref = fused_lut_dense_ref(x, wq, LUT.reshape(-1), 128, 256, 0.03, -5.0,
                              ws, bits=8)
    assert jnp.array_equal(out, ref)


def test_fused_k_pad_correction_nonzero_m00():
    """K padding contributes LUT[off, off] = M[0, 0] per padded k; the kernel
    must subtract it in integer space. Exercised with a synthetic multiplier
    whose M[0, 0] != 0 (every registered family has M[0, 0] == 0)."""
    import dataclasses

    from repro.core.multipliers import make_exact

    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = jnp.asarray(build_lut(biased))
    assert int(lut[128, 128]) == 7
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(6, 30)), jnp.float32)  # K=30 -> pad 98
    wq = jnp.asarray(rng.integers(-128, 128, (30, 5)), jnp.int32)
    out = fused_lut_dense(x, wq, lut, 128, 0.04, 2.0, 0.01, bits=8,
                          interpret=True)
    ref = fused_lut_dense_ref(x, wq, lut.reshape(-1), 128, 256, 0.04, 2.0,
                              0.01, bits=8)
    assert jnp.array_equal(out, ref)


def test_fused_emit_acc_is_raw_accumulator():
    """emit_acc=True returns the int32 accumulator (tile K-pad already
    corrected) — what the mesh contraction route psums — and dequantizing it
    reproduces the normal fused output bitwise."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(9, 40)), jnp.float32)   # K=40 -> pad 88
    w = jnp.asarray(rng.normal(size=(40, 7)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    wq = acu_operand(quantize(w, wqp), wqp)
    acc = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True, emit_acc=True)
    assert acc.dtype == jnp.int32
    a = acu_operand(quantize(x, xqp), xqp)
    assert jnp.array_equal(acc, ACU._lut_matmul_jnp(a, wq))
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True)
    dq = acc.astype(jnp.float32) * (xqp.scale * wqp.scale.reshape(1, -1))
    assert jnp.array_equal(out, dq)


def test_matmul_plan_fused_routing():
    """matmul_plan serves a fused plan only when it can (LUT + pallas + table)
    and falls back to unfused otherwise."""
    assert matmul_plan(ACU_PALLAS, fused=True).fused
    assert not matmul_plan(ACU_PALLAS, fused=False).fused
    assert not matmul_plan(ACU, fused=True).fused            # no pallas
    func = make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL, use_pallas=True)
    assert not matmul_plan(func, fused=True).fused           # not LUT mode
    # acu-level default threads through
    fused_acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                         fused=True)
    assert matmul_plan(fused_acu).fused


@pytest.mark.parametrize("shape", [(12, 40, 9), (64, 128, 32)])
def test_ste_fused_equals_unfused(shape):
    """Public approx_matmul: fused cfg == unfused cfg, bitwise, and the STE
    backward (exact fp32 arithmetic) is identical for both."""
    M, K, N = shape
    rng = np.random.default_rng(K)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = affine_qparams(jnp.min(x), jnp.max(x), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    c0 = ApproxConfig(acu=ACU_PALLAS)
    c1 = ApproxConfig(acu=ACU_PALLAS, fused=True)
    y0 = approx_matmul(x, w, c0, xqp, wqp)
    y1 = approx_matmul(x, w, c1, xqp, wqp)
    assert jnp.array_equal(y0, y1)
    g0 = jax.grad(lambda x: approx_matmul(x, w, c0, xqp, wqp).sum())(x)
    g1 = jax.grad(lambda x: approx_matmul(x, w, c1, xqp, wqp).sum())(x)
    assert jnp.array_equal(g0, g1)


def test_approx_dense_fused_batched():
    """approx_dense with leading batch dims routes through the fused kernel
    (acu-level fused flag) and matches the unfused result bitwise."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 5, 33)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(33, 14)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(14,)), jnp.float32)
    fused_acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                         fused=True)
    y0 = approx_dense(x, w, b, ApproxConfig(acu=ACU_PALLAS))
    y1 = approx_dense(x, w, b, ApproxConfig(acu=fused_acu))
    assert y1.shape == (3, 5, 14)
    assert jnp.array_equal(y0, y1)


def test_acu_matmul_unchanged_by_fused_flag():
    """Acu.matmul stays the unfused integer-operand GEMM regardless of the
    fused default (it has no qparams to fuse with)."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.integers(-128, 128, (7, 19)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (19, 4)), jnp.int32)
    import dataclasses
    fused_acu = dataclasses.replace(ACU, fused=True)
    assert jnp.array_equal(fused_acu.matmul(a, w), ACU.matmul(a, w))
