"""Fused quantize->LUT-GEMM->dequant kernel: bit-exactness vs the pure-jnp
oracle (``Acu._lut_matmul_jnp`` + ``_affine_matmul_dequant``), interpret mode.

"Bit-exact" here is literal float equality: the kernel must perform the same
quantize, the same int32 accumulate (with integer-space K-pad correction), and
the same single combined-scale dequant ``acc * (xs * ws)`` as the unfused
reference pipeline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, get_multiplier, make_acu, matmul_plan
from repro.core.acu import Acu, AcuMode
from repro.core.approx_ops import (ApproxConfig, _affine_matmul_dequant,
                                   approx_dense, approx_matmul)
from repro.core.quantization import (QParams, acu_operand, affine_qparams,
                                     quantize, symmetric_qparams)
from repro.kernels.fused_lut_dense.ops import fused_lut_dense
from repro.kernels.fused_lut_dense.ref import fused_lut_dense_ref

MULT = get_multiplier("mul8s_1L2H")
LUT = jnp.asarray(build_lut(MULT))
ACU = make_acu("mul8s_1L2H", AcuMode.LUT)
ACU_PALLAS = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)


def unfused_oracle(x, w, xqp, wqp, acu=ACU):
    """The three-stage reference pipeline the fused kernel replaces."""
    a = acu_operand(quantize(x, xqp), xqp)
    wq = acu_operand(quantize(w, wqp), wqp)
    acc = acu._lut_matmul_jnp(a, wq)
    return _affine_matmul_dequant(acc, xqp, wqp)


@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (130, 70, 50),
                                   (1, 257, 3), (256, 8, 384), (33, 64, 129)])
def test_fused_matches_oracle_shapes(shape):
    """Shape sweep incl. non-divisible M/K/N; per-channel weight scales."""
    M, K, N = shape
    rng = np.random.default_rng(M * K + N)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    wq = acu_operand(quantize(w, wqp), wqp)
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True)
    ref = unfused_oracle(x, w, xqp, wqp)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("zp_case", ["zero", "mid", "lo_edge", "hi_edge"])
def test_fused_zero_point_edges(zp_case):
    """Affine activation quantization: zero-point at 0, mid-range, and the
    clip-range edges. a_bits=7 keeps shifted codes inside the 8-bit ACU's
    operand range even at the edges."""
    bits = 7
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    zp = {"zero": 0.0, "mid": 11.0, "lo_edge": float(lo),
          "hi_edge": float(hi)}[zp_case]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(20, 40)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
    xqp = QParams(scale=jnp.float32(0.05), zero_point=jnp.float32(zp),
                  bits=bits)
    wqp = symmetric_qparams(jnp.max(jnp.abs(w)), 8)
    wq = acu_operand(quantize(w, wqp), wqp)
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=bits, interpret=True)
    ref = unfused_oracle(x, w, xqp, wqp)
    assert jnp.array_equal(out, ref)


def test_fused_kernel_matches_own_ref():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(17, 130)), jnp.float32)
    wq = jnp.asarray(rng.integers(-128, 128, (130, 21)), jnp.int32)
    ws = jnp.asarray(np.abs(rng.normal(size=(21,))) * 0.02 + 1e-4, jnp.float32)
    out = fused_lut_dense(x, wq, LUT, 128, 0.03, -5.0, ws, bits=8,
                          interpret=True)
    ref = fused_lut_dense_ref(x, wq, LUT.reshape(-1), 128, 256, 0.03, -5.0,
                              ws, bits=8)
    assert jnp.array_equal(out, ref)


def test_fused_k_pad_correction_nonzero_m00():
    """K padding contributes LUT[off, off] = M[0, 0] per padded k; the kernel
    must subtract it in integer space. Exercised with a synthetic multiplier
    whose M[0, 0] != 0 (every registered family has M[0, 0] == 0)."""
    import dataclasses

    from repro.core.multipliers import make_exact

    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = jnp.asarray(build_lut(biased))
    assert int(lut[128, 128]) == 7
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(6, 30)), jnp.float32)  # K=30 -> pad 98
    wq = jnp.asarray(rng.integers(-128, 128, (30, 5)), jnp.int32)
    out = fused_lut_dense(x, wq, lut, 128, 0.04, 2.0, 0.01, bits=8,
                          interpret=True)
    ref = fused_lut_dense_ref(x, wq, lut.reshape(-1), 128, 256, 0.04, 2.0,
                              0.01, bits=8)
    assert jnp.array_equal(out, ref)


def test_fused_emit_acc_is_raw_accumulator():
    """emit_acc=True returns the int32 accumulator (tile K-pad already
    corrected) — what the mesh contraction route psums — and dequantizing it
    reproduces the normal fused output bitwise."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(9, 40)), jnp.float32)   # K=40 -> pad 88
    w = jnp.asarray(rng.normal(size=(40, 7)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    wq = acu_operand(quantize(w, wqp), wqp)
    acc = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True, emit_acc=True)
    assert acc.dtype == jnp.int32
    a = acu_operand(quantize(x, xqp), xqp)
    assert jnp.array_equal(acc, ACU._lut_matmul_jnp(a, wq))
    out = fused_lut_dense(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                          wqp.scale, bits=8, interpret=True)
    dq = acc.astype(jnp.float32) * (xqp.scale * wqp.scale.reshape(1, -1))
    assert jnp.array_equal(out, dq)


def test_matmul_plan_fused_routing():
    """matmul_plan serves a fused plan only when it can (LUT + pallas + table)
    and falls back to unfused otherwise."""
    assert matmul_plan(ACU_PALLAS, fused=True).fused
    assert not matmul_plan(ACU_PALLAS, fused=False).fused
    assert not matmul_plan(ACU, fused=True).fused            # no pallas
    func = make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL, use_pallas=True)
    assert not matmul_plan(func, fused=True).fused           # not LUT mode
    # acu-level default threads through
    fused_acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                         fused=True)
    assert matmul_plan(fused_acu).fused


@pytest.mark.parametrize("shape", [(12, 40, 9), (64, 128, 32)])
def test_ste_fused_equals_unfused(shape):
    """Public approx_matmul: fused cfg == unfused cfg, bitwise, and the STE
    backward (exact fp32 arithmetic) is identical for both."""
    M, K, N = shape
    rng = np.random.default_rng(K)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = affine_qparams(jnp.min(x), jnp.max(x), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    c0 = ApproxConfig(acu=ACU_PALLAS)
    c1 = ApproxConfig(acu=ACU_PALLAS, fused=True)
    y0 = approx_matmul(x, w, c0, xqp, wqp)
    y1 = approx_matmul(x, w, c1, xqp, wqp)
    assert jnp.array_equal(y0, y1)
    g0 = jax.grad(lambda x: approx_matmul(x, w, c0, xqp, wqp).sum())(x)
    g1 = jax.grad(lambda x: approx_matmul(x, w, c1, xqp, wqp).sum())(x)
    assert jnp.array_equal(g0, g1)


def test_approx_dense_fused_batched():
    """approx_dense with leading batch dims routes through the fused kernel
    (acu-level fused flag) and matches the unfused result bitwise."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 5, 33)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(33, 14)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(14,)), jnp.float32)
    fused_acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                         fused=True)
    y0 = approx_dense(x, w, b, ApproxConfig(acu=ACU_PALLAS))
    y1 = approx_dense(x, w, b, ApproxConfig(acu=fused_acu))
    assert y1.shape == (3, 5, 14)
    assert jnp.array_equal(y0, y1)


def test_acu_matmul_unchanged_by_fused_flag():
    """Acu.matmul stays the unfused integer-operand GEMM regardless of the
    fused default (it has no qparams to fuse with)."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.integers(-128, 128, (7, 19)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (19, 4)), jnp.int32)
    import dataclasses
    fused_acu = dataclasses.replace(ACU, fused=True)
    assert jnp.array_equal(fused_acu.matmul(a, w), ACU.matmul(a, w))


# ---------------------------------------------------------------------------
# approximate backward: fused_lut_bwd (in-kernel fake-quant STE grads)
# ---------------------------------------------------------------------------

import dataclasses

from _hypothesis_compat import given, settings, strategies as st
from repro.core.multipliers import make_exact
from repro.kernels.fused_lut_dense.ops import fused_lut_bwd
from repro.kernels.fused_lut_dense.ref import fused_lut_bwd_ref

_BIASED_MULT = dataclasses.replace(
    make_exact(8), name="mul8s_biased",
    fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
_BIASED_LUT = jnp.asarray(build_lut(_BIASED_MULT))


def _bwd_operands(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    sa = jnp.max(jnp.abs(a)) / 127.0
    sb = jnp.max(jnp.abs(b)) / 127.0
    return a, b, sa, sb


@pytest.mark.parametrize("shape", [(1, 1, 1), (8, 128, 8), (33, 257, 5),
                                   (64, 96, 32), (130, 70, 129)])
def test_fused_bwd_matches_ref_shapes(shape):
    """Backward-flavor kernel (both operands quantized in-kernel, per-tensor
    symmetric) vs its O(MKN) reference, odd and divisible M/K/N, eager and
    jit, bitwise."""
    a, b, sa, sb = _bwd_operands(*shape, seed=sum(shape))
    ref = fused_lut_bwd_ref(a, b, LUT.reshape(-1), 128, 256, sa, sb, bits=8)
    out = fused_lut_bwd(a, b, LUT, 128, sa, sb, bits=8, interpret=True)
    assert jnp.array_equal(out, ref)
    outj = jax.jit(lambda a, b: fused_lut_bwd(a, b, LUT, 128, sa, sb, bits=8,
                                              interpret=True))(a, b)
    assert jnp.array_equal(outj, ref)


def test_fused_bwd_k_pad_correction_biased_m00():
    """K=30 pads 98 ks; each contributes LUT[off, off] = 7 with the biased
    multiplier — the kernel must subtract them in integer space."""
    a, b, sa, sb = _bwd_operands(6, 30, 5, seed=3)
    ref = fused_lut_bwd_ref(a, b, _BIASED_LUT.reshape(-1), 128, 256, sa, sb,
                            bits=8)
    out = fused_lut_bwd(a, b, _BIASED_LUT, 128, sa, sb, bits=8,
                        interpret=True)
    assert jnp.array_equal(out, ref)


def test_fused_bwd_emit_acc_is_raw_accumulator():
    """emit_acc=True is the int32 accumulator the mesh contraction route
    psums — equal to the unfused code-GEMM, and dequantizing reproduces the
    normal output bitwise."""
    a, b, sa, sb = _bwd_operands(9, 40, 7, seed=13)
    acc = fused_lut_bwd(a, b, LUT, 128, sa, sb, bits=8, interpret=True,
                        emit_acc=True)
    assert acc.dtype == jnp.int32
    qa = jnp.clip(jnp.round(a / sa), -128, 127).astype(jnp.int32)
    qb = jnp.clip(jnp.round(b / sb), -128, 127).astype(jnp.int32)
    assert jnp.array_equal(acc, ACU._lut_matmul_jnp(qa, qb))
    out = fused_lut_bwd(a, b, LUT, 128, sa, sb, bits=8, interpret=True)
    assert jnp.array_equal(out, acc.astype(jnp.float32) * (sa * sb))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 100), k=st.integers(1, 280), n=st.integers(1, 100),
       biased=st.sampled_from([False, True]))
def test_property_fused_bwd_oracle_bitwise(m, k, n, biased):
    """Property harness: any drawn (M, K, N) — including K-pad branches —
    and either multiplier, the fused backward equals the reference
    bitwise."""
    lut = _BIASED_LUT if biased else LUT
    a, b, sa, sb = _bwd_operands(m, k, n, seed=m * 31 + k * 7 + n)
    ref = fused_lut_bwd_ref(a, b, lut.reshape(-1), 128, 256, sa, sb, bits=8)
    out = fused_lut_bwd(a, b, lut, 128, sa, sb, bits=8, interpret=True)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("shape", [(16, 32, 8), (33, 70, 21)])
def test_ste_approx_bwd_fused_equals_unfused(shape):
    """cfg.approx_bwd routes the STE grads through the ACU; the fused
    in-kernel route and the unfused quantize->code-GEMM->dequant route are
    the same computation and must agree bitwise — values AND both grads."""
    M, K, N = shape
    rng = np.random.default_rng(N)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu_f = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
    c0 = ApproxConfig(acu=ACU_PALLAS, approx_bwd=True)
    c1 = ApproxConfig(acu=acu_f, approx_bwd=True)

    def loss(cfg):
        return lambda x, w: (approx_matmul(x, w, cfg, xqp, wqp)
                             * jnp.arange(N)).sum()

    g0x, g0w = jax.grad(loss(c0), argnums=(0, 1))(x, w)
    g1x, g1w = jax.grad(loss(c1), argnums=(0, 1))(x, w)
    assert jnp.array_equal(g0x, g1x)
    assert jnp.array_equal(g0w, g1w)
    # jit agrees with eager (the scale expression is pinned against SPMD
    # rewrites)
    g2x, g2w = jax.jit(jax.grad(loss(c1), argnums=(0, 1)))(x, w)
    assert jnp.array_equal(g1x, g2x)
    assert jnp.array_equal(g1w, g2w)
