"""RNN/LSTM/GRU cells (paper §3.3.4) — exactness and approx compatibility."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.models.rnn import (gru_cell, init_gru, init_lstm, init_rnn, lstm,
                              lstm_cell, rnn_cell)

KEY = jax.random.PRNGKey(0)
APPROX = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))


def test_lstm_cell_manual():
    p = init_lstm(KEY, 4, 3)
    x = jax.random.normal(KEY, (2, 4))
    h = jnp.zeros((2, 3))
    c = jnp.zeros((2, 3))
    h1, c1 = lstm_cell(x, h, c, p, None)
    gates = x @ p["wx"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(gates, 4, -1)
    c_ref = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_ref = jax.nn.sigmoid(o) * jnp.tanh(c_ref)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c_ref), rtol=1e-5)


def test_lstm_scan_vs_loop():
    p = init_lstm(KEY, 4, 3)
    xs = jax.random.normal(KEY, (2, 5, 4))
    out = lstm(xs, p)
    h = jnp.zeros((2, 3))
    c = jnp.zeros((2, 3))
    for t in range(5):
        h, c = lstm_cell(xs[:, t], h, c, p, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), rtol=1e-5)


def test_lstm_approx_runs_and_grads():
    p = init_lstm(KEY, 8, 16)
    xs = jax.random.normal(KEY, (4, 6, 8))

    def loss(p):
        return (lstm(xs, p, APPROX) ** 2).sum()

    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))


def test_gru_and_rnn_cells():
    pg = init_gru(KEY, 4, 3)
    pr = init_rnn(KEY, 4, 3)
    x = jax.random.normal(KEY, (2, 4))
    h = jnp.zeros((2, 3))
    hg = gru_cell(x, h, pg, None)
    hr = rnn_cell(x, h, pr, None)
    assert hg.shape == (2, 3) and bool(jnp.isfinite(hg).all())
    np.testing.assert_allclose(
        np.asarray(hr), np.asarray(jnp.tanh(x @ pr["wx"] + pr["b"] + h @ pr["wh"])),
        rtol=1e-5)
