"""Per-architecture smoke tests: reduced configs, forward + train step +
decode parity (incremental decode == full forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced_config
from repro.configs.shapes import SHAPES, eligible
from repro.models import whisper as W
from repro.models.transformer import apply_model, init_cache, init_params, loss_fn

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_step(arch):
    cfg = reduced_config(arch)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.enc_dec:
        p = W.init_params(KEY, cfg)
        frames = jax.random.normal(KEY, (b, cfg.enc_ctx, cfg.d_model))
        enc = W.encode(p, frames, cfg)
        logits, _ = W.decode(p, toks, enc, cfg)
        loss, grads = jax.value_and_grad(W.loss_fn)(p, frames, toks[:, :-1],
                                                    toks[:, 1:], cfg)
    else:
        p = init_params(KEY, cfg)
        logits, _ = apply_model(p, toks, cfg)
        loss, grads = jax.value_and_grad(loss_fn)(p, toks[:, :-1], toks[:, 1:], cfg)
    assert logits.shape[-1] == cfg.vocab_padded
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if not get_config(a).enc_dec])
def test_decode_matches_full_forward(arch):
    """Prefill + incremental decode logits == full-sequence forward logits."""
    cfg = reduced_config(arch)
    b, s = 1, 8
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    p = init_params(KEY, cfg)
    full, _ = apply_model(p, toks, cfg)

    cache = init_cache(cfg, b, s + 4)
    _, cache = apply_model(p, toks[:, :s], cfg, cache=cache, cache_pos=0)
    step_logits, _ = apply_model(p, toks[:, s:s + 1], cfg, cache=cache,
                                 cache_pos=s, decode=True)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full[:, s], np.float32), rtol=2e-2, atol=2e-2)


def test_whisper_decode_matches_full():
    cfg = reduced_config("whisper-small")
    b, s = 1, 8
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    p = W.init_params(KEY, cfg)
    frames = jax.random.normal(KEY, (b, cfg.enc_ctx, cfg.d_model))
    enc = W.encode(p, frames, cfg)
    full, _ = W.decode(p, toks, enc, cfg)
    cache = W.init_cache(cfg, b, s + 4)
    _, cache = W.decode(p, toks[:, :s], enc, cfg, cache=cache, cache_pos=0)
    step, _ = W.decode(p, toks[:, s:s + 1], enc, cfg, cache=cache, cache_pos=s)
    np.testing.assert_allclose(np.asarray(step[:, 0], np.float32),
                               np.asarray(full[:, s], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_last_only_matches():
    cfg = reduced_config("smollm-135m")
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    full, _ = apply_model(p, toks, cfg)
    last, _ = apply_model(p, toks, cfg, last_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=1e-5, atol=1e-5)


def test_eligibility_matrix():
    """40 cells; long_500k runs only for sub-quadratic archs (spec)."""
    from repro.configs import all_configs, cells
    cs = cells(all_configs())
    assert len(cs) == 40
    runnable = [(a, s) for a, s, ok, _ in cs if ok]
    skipped = [(a, s) for a, s, ok, _ in cs if not ok]
    assert ("jamba-v0.1-52b", "long_500k") in runnable
    assert ("rwkv6-3b", "long_500k") in runnable
    assert len(skipped) == 8  # every pure full-attention arch skips long_500k
    assert all(s == "long_500k" for _, s in skipped)


def test_full_config_param_counts():
    """Advertised sizes: each config's param count lands near its name."""
    expect = {"smollm-135m": 0.135e9, "qwen2.5-14b": 14.8e9,
              "gemma2-27b": 27e9, "qwen2-vl-72b": 72e9,
              "command-r-plus-104b": 104e9, "jamba-v0.1-52b": 52e9,
              "rwkv6-3b": 3.1e9, "olmoe-1b-7b": 6.9e9}
    for name, n in expect.items():
        got = get_config(name).n_params()
        assert 0.8 * n < got < 1.25 * n, (name, got, n)
