"""ACU GEMM modes vs brute-force LUT accumulation oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, factorize_error, get_multiplier
from repro.core.acu import AcuMode, make_acu


def brute(lut, a, w, off):
    M, K = a.shape
    _, N = w.shape
    out = np.zeros((M, N), np.int64)
    for i in range(M):
        for j in range(N):
            out[i, j] = lut[a[i, :] + off, w[:, j] + off].astype(np.int64).sum()
    return out


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(7)
    a = rng.integers(-128, 128, (12, 23), dtype=np.int32)
    w = rng.integers(-128, 128, (23, 9), dtype=np.int32)
    return a, w


@pytest.mark.parametrize("mult", ["mul8s_1L2H", "mul8s_mitchell", "mul8s_drum6"])
def test_lut_mode_bit_exact(operands, mult):
    a, w = operands
    acu = make_acu(mult, AcuMode.LUT)
    ref = brute(build_lut(get_multiplier(mult)), a, w, 128)
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("mult", ["mul8s_1L2H", "mul8s_trunc3"])
def test_functional_mode_matches_lut(operands, mult):
    a, w = operands
    f = make_acu(mult, AcuMode.FUNCTIONAL)
    l = make_acu(mult, AcuMode.LUT)
    aj, wj = jnp.asarray(a), jnp.asarray(w)
    assert np.array_equal(np.asarray(f.matmul(aj, wj)), np.asarray(l.matmul(aj, wj)))


def test_factored_trunc_exact(operands):
    a, w = operands
    acu = make_acu("mul8s_trunc2", AcuMode.FACTORED)
    ref = brute(build_lut(get_multiplier("mul8s_trunc2")), a, w, 128)
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)


def test_lowrank_fidelity_improves_with_rank(operands):
    a, w = operands
    ref = brute(build_lut(get_multiplier("mul8s_1L2H")), a, w, 128)
    errs = []
    for r in (2, 8, 32):
        acu = make_acu("mul8s_1L2H", AcuMode.LOWRANK, rank=r)
        out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
        errs.append(np.abs(out - ref).max())
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1.0  # rank-32 is effectively exact for the BAM family


def test_lowrank_factorization_metrics():
    lr = factorize_error(get_multiplier("mul8s_1L2H"), 16)
    assert lr.rank == 16
    assert lr.energy > 0.99
    assert lr.exact_frac > 0.99


def test_large_bitwidth_lut_falls_back_to_functional():
    acu = make_acu("mul12s_2KM", AcuMode.LUT)
    assert acu.mode == AcuMode.FUNCTIONAL  # paper §3.4 fallback


def test_12bit_functional_gemm():
    rng = np.random.default_rng(3)
    a = rng.integers(-2048, 2048, (6, 11), dtype=np.int32)
    w = rng.integers(-2048, 2048, (11, 5), dtype=np.int32)
    acu = make_acu("mul12s_2KM", AcuMode.FUNCTIONAL)
    mult = get_multiplier("mul12s_2KM")
    ref = np.zeros((6, 5), np.int64)
    for i in range(6):
        for j in range(5):
            ref[i, j] = sum(int(mult(jnp.int32(a[i, k]), jnp.int32(w[k, j])))
                            for k in range(11))
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)
