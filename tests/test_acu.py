"""ACU GEMM modes vs brute-force LUT accumulation oracle, and the
``conv_plan`` fallback-audit contract: every resolution path produces its
exact audited report string, so a silent routing change can never slip
through."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, factorize_error, get_multiplier
from repro.core.acu import (CONV_VMEM_BUDGET, AcuMode, ConvSpec,
                            _conv_vmem_estimate, _fmt_vmem, conv_plan,
                            make_acu)


def brute(lut, a, w, off):
    M, K = a.shape
    _, N = w.shape
    out = np.zeros((M, N), np.int64)
    for i in range(M):
        for j in range(N):
            out[i, j] = lut[a[i, :] + off, w[:, j] + off].astype(np.int64).sum()
    return out


@pytest.fixture(scope="module")
def operands():
    rng = np.random.default_rng(7)
    a = rng.integers(-128, 128, (12, 23), dtype=np.int32)
    w = rng.integers(-128, 128, (23, 9), dtype=np.int32)
    return a, w


@pytest.mark.parametrize("mult", ["mul8s_1L2H", "mul8s_mitchell", "mul8s_drum6"])
def test_lut_mode_bit_exact(operands, mult):
    a, w = operands
    acu = make_acu(mult, AcuMode.LUT)
    ref = brute(build_lut(get_multiplier(mult)), a, w, 128)
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("mult", ["mul8s_1L2H", "mul8s_trunc3"])
def test_functional_mode_matches_lut(operands, mult):
    a, w = operands
    f = make_acu(mult, AcuMode.FUNCTIONAL)
    l = make_acu(mult, AcuMode.LUT)
    aj, wj = jnp.asarray(a), jnp.asarray(w)
    assert np.array_equal(np.asarray(f.matmul(aj, wj)), np.asarray(l.matmul(aj, wj)))


def test_factored_trunc_exact(operands):
    a, w = operands
    acu = make_acu("mul8s_trunc2", AcuMode.FACTORED)
    ref = brute(build_lut(get_multiplier("mul8s_trunc2")), a, w, 128)
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)


def test_lowrank_fidelity_improves_with_rank(operands):
    a, w = operands
    ref = brute(build_lut(get_multiplier("mul8s_1L2H")), a, w, 128)
    errs = []
    for r in (2, 8, 32):
        acu = make_acu("mul8s_1L2H", AcuMode.LOWRANK, rank=r)
        out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
        errs.append(np.abs(out - ref).max())
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1.0  # rank-32 is effectively exact for the BAM family


def test_lowrank_factorization_metrics():
    lr = factorize_error(get_multiplier("mul8s_1L2H"), 16)
    assert lr.rank == 16
    assert lr.energy > 0.99
    assert lr.exact_frac > 0.99


def test_large_bitwidth_lut_falls_back_to_functional():
    acu = make_acu("mul12s_2KM", AcuMode.LUT)
    assert acu.mode == AcuMode.FUNCTIONAL  # paper §3.4 fallback


# ---------------------------------------------------------------------------
# conv_plan fallback audit: every resolution path pins its EXACT report
# string (the silent-but-audited contract — tests lock the wording so a
# routing change can never hide behind a reworded report)
# ---------------------------------------------------------------------------

FUSED_ACU = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
SMALL_SPEC = ConvSpec(x_shape=(2, 8, 12, 12), w_shape=(8, 8, 3, 3),
                      padding=((1, 1), (1, 1)))
BIG_SPEC = ConvSpec(x_shape=(1, 64, 224, 224), w_shape=(64, 64, 3, 3),
                    padding=((1, 1), (1, 1)))


def test_conv_plan_audit_whole_image():
    """Inside the budget: fused_conv, empty report."""
    plan = conv_plan(FUSED_ACU, SMALL_SPEC, fused=True)
    assert plan.route == "fused_conv"
    assert plan.report == ()
    assert plan.tiling is None


def test_conv_plan_audit_tiled():
    """Above the budget: tiled, with the exact banding report."""
    plan = conv_plan(FUSED_ACU, BIG_SPEC, fused=True)
    est = _conv_vmem_estimate(BIG_SPEC, 256)
    assert plan.route == "tiled"
    inner, bh, bn, n_copies = plan.tiling
    assert plan.report == (
        f"image working set ~{_fmt_vmem(est)} exceeds the "
        f"{_fmt_vmem(CONV_VMEM_BUDGET)} VMEM budget; spatially tiled over "
        f"output-row bands (bands of {bh} output rows, "
        f"{-(-224 // bh)} bands, {n_copies} halo blocks/band)",)


def test_conv_plan_audit_degenerate_geometry():
    """Above the budget AND no feasible band (budget below the 256 KiB
    LUT floor): the audited eager im2col fallback remains."""
    budget = 128 << 10
    est = _conv_vmem_estimate(SMALL_SPEC, 256)
    plan = conv_plan(FUSED_ACU, SMALL_SPEC, fused=True, vmem_budget=budget)
    assert plan.route == "im2col"
    assert plan.report == (
        f"image working set ~{_fmt_vmem(est)} exceeds the "
        f"{_fmt_vmem(budget)} VMEM budget and even a one-row band does not "
        f"fit (degenerate geometry); falling back to eager im2col",)


def test_conv_plan_audit_im2col_pin():
    """route="im2col" pins the eager oracle with the exact report, even for
    a plan that would otherwise fuse — and on an over-budget image the pin
    short-circuits the budget resolution, so the report never claims a
    tiling the plan does not use."""
    plan = conv_plan(FUSED_ACU, SMALL_SPEC, fused=True, route="im2col")
    assert plan.route == "im2col"
    assert plan.fn is None
    assert plan.report == ("route pinned to eager im2col by caller",)
    big = conv_plan(FUSED_ACU, BIG_SPEC, fused=True, route="im2col")
    assert big.route == "im2col" and big.tiling is None
    assert big.report == ("route pinned to eager im2col by caller",)


def test_conv_plan_audit_tiled_pin():
    """route="tiled" on a fits-in-VMEM image records the pin."""
    plan = conv_plan(FUSED_ACU, SMALL_SPEC, fused=True, route="tiled")
    assert plan.route == "tiled"
    assert plan.tiling is not None
    assert plan.report == ("route pinned to spatially-tiled kernel by "
                           "caller",)


def test_conv_plan_audit_groups():
    """groups != 1 keeps the vmapped-GEMM route with the exact report."""
    gspec = ConvSpec(x_shape=(2, 8, 12, 12), w_shape=(8, 4, 3, 3),
                     padding=((1, 1), (1, 1)), groups=2)
    plan = conv_plan(FUSED_ACU, gspec, fused=True)
    assert plan.route == "im2col_grouped"
    assert plan.report == (
        "groups=2: fused conv serves groups=1 only; grouped route keeps "
        "the single-vmapped-GEMM semantics",)
    dspec = ConvSpec(x_shape=(2, 8, 12, 12), w_shape=(8, 1, 3, 3),
                     padding=((1, 1), (1, 1)), groups=8)
    assert conv_plan(FUSED_ACU, dspec, fused=True).route == "im2col_depthwise"


def test_conv_plan_audit_non_lut_mode():
    """Non-LUT / non-Pallas ACUs fall back with the exact report."""
    func = make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL, use_pallas=True)
    plan = conv_plan(func, SMALL_SPEC, fused=True)
    assert plan.route == "im2col"
    assert plan.report == (
        "fused conv needs LUT mode + use_pallas + a built table (have "
        "mode=functional, use_pallas=True)",)


def test_conv_plan_audit_pins_raise_when_unservable():
    """Pinned routes raise instead of silently falling back: fused_conv
    above the budget, tiled on degenerate geometry, unknown route names."""
    with pytest.raises(ValueError, match="fused_conv route unavailable"):
        conv_plan(FUSED_ACU, BIG_SPEC, fused=True, route="fused_conv")
    with pytest.raises(ValueError, match="tiled route unavailable"):
        conv_plan(FUSED_ACU, SMALL_SPEC, fused=True, route="tiled",
                  vmem_budget=128 << 10)
    with pytest.raises(ValueError, match="unknown conv route"):
        conv_plan(FUSED_ACU, SMALL_SPEC, route="warp")


def test_conv_plan_audit_unfused_request_stays_silent():
    """A plain unfused request (no fusion asked for) resolves to im2col with
    NO report — the audit only records decisions the caller asked about."""
    plan = conv_plan(FUSED_ACU, SMALL_SPEC, fused=False)
    assert plan.route == "im2col"
    assert plan.report == ()


def test_conv_plan_describe_names_tiling():
    """describe() surfaces the chosen banding for tiled plans."""
    rep = conv_plan(FUSED_ACU, BIG_SPEC, fused=True).describe()
    assert rep["route"] == "tiled"
    inner, bh, bn, n_copies = conv_plan(FUSED_ACU, BIG_SPEC,
                                        fused=True).tiling
    assert rep["tiling"] == (
        f"bands of {bh} output rows ({-(-224 // bh)} bands, "
        f"{n_copies} halo blocks/band, inner={inner} bn={bn})")
    assert conv_plan(FUSED_ACU, SMALL_SPEC, fused=True).describe()[
        "tiling"] is None


def test_12bit_functional_gemm():
    rng = np.random.default_rng(3)
    a = rng.integers(-2048, 2048, (6, 11), dtype=np.int32)
    w = rng.integers(-2048, 2048, (11, 5), dtype=np.int32)
    acu = make_acu("mul12s_2KM", AcuMode.FUNCTIONAL)
    mult = get_multiplier("mul12s_2KM")
    ref = np.zeros((6, 5), np.int64)
    for i in range(6):
        for j in range(5):
            ref[i, j] = sum(int(mult(jnp.int32(a[i, k]), jnp.int32(w[k, j])))
                            for k in range(11))
    out = np.asarray(acu.matmul(jnp.asarray(a), jnp.asarray(w)))
    assert np.array_equal(out, ref)
