"""Data pipeline: determinism, sharding, and Prefetcher liveness.

The Prefetcher regressions pinned here were both hangs:

* a producer exception used to kill the daemon thread silently, leaving the
  consumer blocked forever on ``q.get()`` — now the exception rides a
  sentinel through the queue and re-raises on the consumer thread;
* ``close()`` on a producer blocked in ``q.put`` (full queue) used to
  deadlock — the producer now waits with a timeout and re-checks the stop
  flag, and ``close`` drains until the thread exits.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.pipeline import (MarkovLM, Prefetcher, blob_task, image_task,
                                 shard_batch, text_cls_task)


def test_markov_deterministic():
    lm = MarkovLM(vocab=16, seed=3)
    a = next(lm.batches(4, 8, seed=5))
    b = next(MarkovLM(vocab=16, seed=3).batches(4, 8, seed=5))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_task_shapes():
    img = next(image_task(n_classes=4, size=8)(batch=5))
    assert img["image"].shape == (5, 3, 8, 8)
    txt = next(text_cls_task(vocab=50)(batch=3, seq=7))
    assert txt["tokens"].shape == (3, 7)
    blob = next(blob_task(size=12)(batch=6))
    assert blob["image"].shape == (6, 144)


def test_prefetcher_yields_in_order():
    src = ({"i": np.full((2,), i, np.int32)} for i in range(6))
    pf = Prefetcher(src, depth=2)
    got = [int(b["i"][0]) for b in pf]
    assert got == list(range(6))
    # exhaustion is persistent, not a hang
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()


def test_prefetcher_propagates_producer_error():
    """A crashing producer must surface on the consumer thread (it used to
    leave ``__next__`` blocked forever on an empty queue)."""
    def bad():
        yield {"x": np.zeros(1)}
        raise ValueError("producer exploded")

    pf = Prefetcher(bad(), depth=2)
    next(pf)
    with pytest.raises(ValueError, match="producer exploded"):
        # bounded wait: a regression here hangs, so run the get in the
        # timeout discipline pytest gives the whole test
        next(pf)
    # and the error is sticky — later calls re-raise instead of blocking
    with pytest.raises(ValueError, match="producer exploded"):
        next(pf)
    pf.close()


def test_prefetcher_close_unblocks_full_queue():
    """close() must terminate a producer stuck in ``put`` on a full queue."""
    def endless():
        i = 0
        while True:
            yield {"i": np.full((1,), i, np.int32)}
            i += 1

    pf = Prefetcher(endless(), depth=1)
    time.sleep(0.1)          # let the producer fill the queue and block
    assert pf.t.is_alive()
    done = threading.Event()

    def closer():
        pf.close()
        done.set()

    t = threading.Thread(target=closer, daemon=True)
    t.start()
    assert done.wait(timeout=5.0), "close() deadlocked on a full queue"
    assert not pf.t.is_alive()


def test_shard_batch_no_sharding():
    out = shard_batch({"x": np.ones((4, 2), np.float32)})
    assert out["x"].shape == (4, 2)
