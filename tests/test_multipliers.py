"""Multiplier zoo: exactness, bounds, and bit-level properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, strategies as st

from repro.core.multipliers import (REGISTRY, error_stats, get_multiplier,
                                    make_bam, make_drum, make_exact,
                                    make_mitchell, make_trunc)

i8 = st.integers(-128, 127)
i12 = st.integers(-2048, 2047)


def test_exact_is_exact():
    m = make_exact(8)
    a = np.arange(-128, 128)
    out = np.asarray(m(jnp.asarray(a[:, None]), jnp.asarray(a[None, :])))
    assert np.array_equal(out, a[:, None] * a[None, :])


@given(a=i8, w=i8, t=st.integers(1, 4))
def test_trunc_error_bound(a, w, t):
    """|trunc error| <= |a|*2^t + |w|*2^t (masked low bits of both operands)."""
    m = make_trunc(8, t)
    out = int(m(jnp.int32(a), jnp.int32(w)))
    err = abs(out - a * w)
    assert err <= (abs(a) + abs(w) + 2 ** t) * 2 ** t


@given(a=i8, w=i8)
def test_bam_underestimates_magnitude(a, w):
    """Perforation only drops positive partial products of |a|*|w|."""
    m = make_bam(8, 6)
    out = int(m(jnp.int32(a), jnp.int32(w)))
    assert abs(out) <= abs(a * w)
    assert np.sign(out) in (0, np.sign(a * w))


@given(a=i8, w=i8)
def test_bam_symmetry(a, w):
    m = make_bam(8, 6)
    assert int(m(jnp.int32(a), jnp.int32(w))) == int(m(jnp.int32(w), jnp.int32(a)))


@given(a=i8, w=i8)
def test_mitchell_relative_error(a, w):
    """Mitchell log multiplier: relative error < 11.2% (2 - 2^(x) bound)."""
    m = make_mitchell(8)
    out = int(m(jnp.int32(a), jnp.int32(w)))
    if a * w != 0:
        assert abs(out - a * w) / abs(a * w) <= 0.115 + 2.0 / abs(a * w)
    else:
        assert out == 0


@given(a=i12, w=i12)
def test_drum_relative_error(a, w):
    """DRUM k-bit windows: per-operand relative error <= 2^(1-k), so the
    product error is bounded by (1 + 2^-10)^2 - 1 = 2^-9 + 2^-20 for k=11
    (attained at exact powers of two, e.g. a = w = -2048)."""
    m = make_drum(12, 11)
    out = int(m(jnp.int32(a), jnp.int32(w)))
    if a * w != 0:
        assert abs(out - a * w) / abs(a * w) <= 2 ** -9 + 2 ** -20
    else:
        assert out == 0


@given(a=i8)
def test_zero_annihilates(a):
    """M[0, x] == M[x, 0] == 0 for every family (depthwise block-diag GEMMs
    rely on this — approx_ops.conv2d)."""
    for name, m in REGISTRY.items():
        if m.bits != 8:
            continue
        assert int(m(jnp.int32(0), jnp.int32(a))) == 0, name
        assert int(m(jnp.int32(a), jnp.int32(0))) == 0, name


def test_paper_role_stats():
    """The named stand-ins land in the paper's error regimes."""
    s8 = error_stats(get_multiplier("mul8s_1L2H"))
    assert 1.0 < s8["mre_pct"] < 10.0        # paper: 4.41%
    assert s8["mae_pct"] < 0.3               # paper: 0.081%
    s12 = error_stats(get_multiplier("mul12s_2KM"))
    assert s12["mre_pct"] < 1e-3             # paper: 4.7e-4%
    assert s12["mae_pct"] < 1e-4             # paper: 1.2e-6%


def test_registry_names():
    for name in ("mul8s_exact", "mul8s_1L2H", "mul12s_2KM", "mul8s_mitchell"):
        assert get_multiplier(name).name == name
    with pytest.raises(KeyError):
        get_multiplier("nope")
