"""Trainer fault tolerance: failure injection -> restore -> completion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import MarkovLM
from repro.optim.adamw import AdamW, SGD
from repro.train.trainer import Trainer, TrainerConfig


def make_problem():
    """Tiny linear-softmax LM on the Markov task."""
    lm = MarkovLM(vocab=32, seed=0)
    key = jax.random.PRNGKey(0)
    params = {"emb": jax.random.normal(key, (32, 16)) * 0.1,
              "out": jax.random.normal(key, (16, 32)) * 0.1}

    def loss_fn(params, batch):
        x = params["emb"][batch["tokens"]]
        logits = x @ params["out"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None], -1)[..., 0]
        return (logz - gold).mean()

    return lm, params, loss_fn


def test_training_reduces_loss(tmp_path):
    lm, params, loss_fn = make_problem()
    tr = Trainer(loss_fn, AdamW(lr=1e-2),
                 TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10,
                               log_every=5, async_ckpt=False))
    params, _ = tr.fit(params, AdamW(lr=1e-2).init(params),
                       lm.batches(16, 32), n_steps=60)
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0] - 0.3


def test_failure_injection_recovers(tmp_path):
    lm, params, loss_fn = make_problem()
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, log_every=5,
                        max_failures=3, async_ckpt=False)
    opt = AdamW(lr=1e-2)
    tr = Trainer(loss_fn, opt, cfg)
    crashed = {"n": 0}

    def fail_hook(step):
        # simulate a node failure at steps 12 and 23
        if step in (12, 23) and crashed["n"] < 2:
            crashed["n"] += 1
            raise RuntimeError("simulated node failure")

    params, _ = tr.fit(params, opt.init(params), lm.batches(16, 32),
                       n_steps=40, fail_hook=fail_hook)
    assert crashed["n"] == 2
    events = [h for h in tr.history if "event" in h]
    assert sum("restored" in e["event"] for e in events) == 2
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0]


def test_too_many_failures_raises(tmp_path):
    lm, params, loss_fn = make_problem()
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_failures=1,
                        async_ckpt=False)
    opt = SGD(lr=1e-2)
    tr = Trainer(loss_fn, opt, cfg)

    def always_fail(step):
        if step >= 5:
            raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        tr.fit(params, opt.init(params), lm.batches(8, 16), n_steps=20,
               fail_hook=always_fail)


def test_elastic_restart_resumes(tmp_path):
    """A second Trainer (fresh process stand-in) resumes from the ckpt."""
    lm, params, loss_fn = make_problem()
    opt = AdamW(lr=1e-2)
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10, async_ckpt=False)
    tr1 = Trainer(loss_fn, opt, cfg)
    tr1.fit(params, opt.init(params), lm.batches(16, 32), n_steps=20)

    tr2 = Trainer(loss_fn, opt, cfg)
    p2, o2, start, _extra = tr2.restore_or_init(params, opt.init(params))
    assert start == 20
    p2, _ = tr2.fit(p2, o2, lm.batches(16, 32), n_steps=30)
    losses = [h["loss"] for h in tr2.history if "loss" in h]
    assert losses  # continued past restore point


def _leaves(p):
    return [np.asarray(x) for x in jax.tree.leaves(p)]


def test_failure_resume_is_deterministic(tmp_path):
    """Rolled-back batches replay from the buffer: a run that crashes and
    restores must end bitwise identical to the run that never crashed."""
    lm, params, loss_fn = make_problem()
    opt = AdamW(lr=1e-2)

    def run(ckpt_dir, fail_hook=None):
        cfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=5, log_every=5,
                            max_failures=3, async_ckpt=False)
        tr = Trainer(loss_fn, opt, cfg)
        p0 = jax.tree.map(jnp.copy, params)   # fit donates its inputs
        p, _ = tr.fit(p0, opt.init(p0), lm.batches(16, 32),
                      n_steps=22, fail_hook=fail_hook)
        return p, tr

    p_clean, tr_clean = run(str(tmp_path / "clean"))

    crashed = {"n": 0}

    def fail_hook(step):
        # crash mid-interval so un-checkpointed batches must replay
        if step in (7, 13) and crashed["n"] < 2:
            crashed["n"] += 1
            raise RuntimeError("simulated node failure")

    p_crash, tr_crash = run(str(tmp_path / "crash"), fail_hook)
    assert crashed["n"] == 2
    assert tr_crash.consumed == tr_clean.consumed
    for a, b in zip(_leaves(p_clean), _leaves(p_crash)):
        np.testing.assert_array_equal(a, b)


def test_fresh_restart_matches_uninterrupted(tmp_path):
    """Kill-and-restart (new Trainer + fresh iterator) fast-forwards the
    iterator by the manifest's consumed count and lands bitwise on the
    uninterrupted run."""
    lm, params, loss_fn = make_problem()
    opt = AdamW(lr=1e-2)
    batches = lambda: lm.batches(16, 32, seed=7)

    fresh = lambda: jax.tree.map(jnp.copy, params)   # fit donates inputs

    cfg0 = TrainerConfig(ckpt_dir=str(tmp_path / "clean"), ckpt_every=10,
                         async_ckpt=False)
    tr0 = Trainer(loss_fn, opt, cfg0)
    p0 = fresh()
    p_clean, _ = tr0.fit(p0, opt.init(p0), batches(), n_steps=30)

    cfg = TrainerConfig(ckpt_dir=str(tmp_path / "killed"), ckpt_every=10,
                        async_ckpt=False)
    tr1 = Trainer(loss_fn, opt, cfg)
    p1 = fresh()
    tr1.fit(p1, opt.init(p1), batches(), n_steps=20)
    # "process dies here"; a fresh Trainer + fresh iterator resumes
    tr2 = Trainer(loss_fn, opt, cfg)
    p2 = fresh()
    p_res, _ = tr2.fit(p2, opt.init(p2), batches(), n_steps=30)
    for a, b in zip(_leaves(p_clean), _leaves(p_res)):
        np.testing.assert_array_equal(a, b)
