"""Serving engine: batched waves == per-sequence incremental reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.transformer import apply_model, init_cache, init_params
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def greedy_reference(params, cfg, prompt, n_new):
    toks = jnp.asarray(prompt)[None, :]
    cache = init_cache(cfg, 1, len(prompt) + n_new + 2)
    logits, cache = apply_model(params, toks, cfg, cache=cache, cache_pos=0)
    out = []
    cur = int(jnp.argmax(logits[0, -1]))
    pos = len(prompt)
    for _ in range(n_new):
        out.append(cur)
        logits, cache = apply_model(params, jnp.asarray([[cur]]), cfg,
                                    cache=cache, cache_pos=pos, decode=True)
        cur = int(jnp.argmax(logits[0, -1]))
        pos += 1
    return out


def test_engine_matches_reference():
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    prompt = np.asarray([5, 17, 3, 99], np.int32)
    ref = greedy_reference(params, cfg, prompt, 6)

    eng = ServeEngine(params, cfg, slots=2, max_seq=64)
    reqs = [Request(prompt=prompt, max_new_tokens=6),
            Request(prompt=prompt, max_new_tokens=6)]
    done = eng.run(reqs)
    for r in done:
        assert list(r.out) == ref


def test_mixed_length_wave_matches_solo():
    """Regression: left-pad slots must not leak into attention or shift RoPE
    positions — a short prompt decodes the same tokens whether it shares a
    wave with a much longer prompt or runs incrementally unpadded."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    short = np.asarray([7, 11, 2], np.int32)
    long = np.asarray([5, 17, 3, 99, 23, 41, 8, 1, 64, 12], np.int32)
    ref_short = greedy_reference(params, cfg, short, 6)
    ref_long = greedy_reference(params, cfg, long, 6)
    eng = ServeEngine(params, cfg, slots=2, max_seq=64)
    done = eng.run([Request(prompt=short, max_new_tokens=6),
                    Request(prompt=long, max_new_tokens=6)])
    assert list(done[0].out) == ref_short
    assert list(done[1].out) == ref_long


def test_no_trailing_decode_and_counts_unchanged():
    """Regression: the wave loop must not issue a decode step whose logits
    nothing consumes (N tokens need exactly N-1 decode calls after prefill),
    and the preallocated output buffer yields the same token counts."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_seq=8)
    calls = [0]
    inner = eng._decode

    def counting(*a, **k):
        calls[0] += 1
        return inner(*a, **k)

    eng._decode = counting
    # budget = max_seq - plen = 6 caps max_new_tokens=10: the old loop ran a
    # 7th decode after collecting the 6th token because the slot never died
    done = eng.run([Request(prompt=np.asarray([3, 1], np.int32),
                            max_new_tokens=10)])
    assert len(done[0].out) == 6
    assert calls[0] == 5


def test_engine_multiple_waves_and_lengths():
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    reqs = [Request(prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new_tokens=3 + i) for i in range(5)]
    done = ServeEngine(params, cfg, slots=2, max_seq=32).run(reqs)
    for i, r in enumerate(done):
        assert len(r.out) == 3 + i
        assert all(0 <= t < cfg.vocab_padded for t in r.out)
