"""Serving engines: batched waves == per-sequence incremental reference,
and continuous batching == waves (same greedy tokens, fewer decode steps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.transformer import apply_model, init_cache, init_params
from repro.serve.engine import (ContinuousServeEngine,
                                PagedContinuousServeEngine, Request,
                                ServeEngine, kv_block_bytes, poisson_arrivals)

KEY = jax.random.PRNGKey(0)


def greedy_reference(params, cfg, prompt, n_new, acfg=None):
    toks = jnp.asarray(prompt)[None, :]
    cache = init_cache(cfg, 1, len(prompt) + n_new + 2)
    logits, cache = apply_model(params, toks, cfg, acfg=acfg, cache=cache,
                                cache_pos=0)
    out = []
    cur = int(jnp.argmax(logits[0, -1]))
    pos = len(prompt)
    for _ in range(n_new):
        out.append(cur)
        logits, cache = apply_model(params, jnp.asarray([[cur]]), cfg,
                                    acfg=acfg, cache=cache, cache_pos=pos,
                                    decode=True)
        cur = int(jnp.argmax(logits[0, -1]))
        pos += 1
    return out


def test_engine_matches_reference():
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    prompt = np.asarray([5, 17, 3, 99], np.int32)
    ref = greedy_reference(params, cfg, prompt, 6)

    eng = ServeEngine(params, cfg, slots=2, max_seq=64)
    reqs = [Request(prompt=prompt, max_new_tokens=6),
            Request(prompt=prompt, max_new_tokens=6)]
    done = eng.run(reqs)
    for r in done:
        assert list(r.out) == ref


def test_mixed_length_wave_matches_solo():
    """Regression: left-pad slots must not leak into attention or shift RoPE
    positions — a short prompt decodes the same tokens whether it shares a
    wave with a much longer prompt or runs incrementally unpadded."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    short = np.asarray([7, 11, 2], np.int32)
    long = np.asarray([5, 17, 3, 99, 23, 41, 8, 1, 64, 12], np.int32)
    ref_short = greedy_reference(params, cfg, short, 6)
    ref_long = greedy_reference(params, cfg, long, 6)
    eng = ServeEngine(params, cfg, slots=2, max_seq=64)
    done = eng.run([Request(prompt=short, max_new_tokens=6),
                    Request(prompt=long, max_new_tokens=6)])
    assert list(done[0].out) == ref_short
    assert list(done[1].out) == ref_long


def test_no_trailing_decode_and_counts_unchanged():
    """Regression: the wave loop must not issue a decode step whose logits
    nothing consumes (N tokens need exactly N-1 decode calls after prefill),
    and the preallocated output buffer yields the same token counts."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, slots=2, max_seq=8)
    calls = [0]
    inner = eng._decode

    def counting(*a, **k):
        calls[0] += 1
        return inner(*a, **k)

    eng._decode = counting
    # budget = max_seq - plen = 6 caps max_new_tokens=10: the old loop ran a
    # 7th decode after collecting the 6th token because the slot never died
    done = eng.run([Request(prompt=np.asarray([3, 1], np.int32),
                            max_new_tokens=10)])
    assert len(done[0].out) == 6
    assert calls[0] == 5


def test_engine_multiple_waves_and_lengths():
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    reqs = [Request(prompt=np.asarray([i + 1, i + 2], np.int32),
                    max_new_tokens=3 + i) for i in range(5)]
    done = ServeEngine(params, cfg, slots=2, max_seq=32).run(reqs)
    for i, r in enumerate(done):
        assert len(r.out) == 3 + i
        assert all(0 <= t < cfg.vocab_padded for t in r.out)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def _reqs(specs):
    return [Request(prompt=np.asarray(p, np.int32), max_new_tokens=n)
            for p, n in specs]


def test_continuous_matches_wave():
    """Continuous batching is a scheduling change, not a math change: the
    exact-path greedy tokens equal the wave engine's, mixed prompt lengths
    included."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    specs = [([5, 17, 3, 99], 6), ([7, 11, 2], 4),
             ([5, 17, 3, 99, 23, 41, 8, 1, 64, 12], 5), ([9, 9], 7)]
    wave = ServeEngine(params, cfg, slots=2, max_seq=64).run(_reqs(specs))
    cont = ContinuousServeEngine(params, cfg, slots=2,
                                 max_seq=64).run(_reqs(specs))
    for w, c in zip(wave, cont):
        assert list(w.out) == list(c.out)


def test_continuous_fewer_decode_steps():
    """The point of continuous batching: a freed slot admits the next queued
    request instead of idling behind the longest row of its wave, so mixed
    short/long budgets take strictly fewer decode steps."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    specs = [([3, 1], 3), ([4, 2], 12), ([5, 3], 3), ([6, 4], 12)]

    wave_eng = ServeEngine(params, cfg, slots=2, max_seq=32)
    calls = [0]
    inner = wave_eng._decode

    def counting(*a, **k):
        calls[0] += 1
        return inner(*a, **k)

    wave_eng._decode = counting
    wave = wave_eng.run(_reqs(specs))

    cont_eng = ContinuousServeEngine(params, cfg, slots=2, max_seq=32)
    cont = cont_eng.run(_reqs(specs))
    for w, c in zip(wave, cont):
        assert list(w.out) == list(c.out)
    assert cont_eng.stats["decode_steps"] < calls[0], \
        (cont_eng.stats["decode_steps"], calls[0])
    assert cont_eng.stats["tokens"] == sum(n for _, n in specs)


def test_continuous_per_request_budget_exact():
    """Regression (per-slot max_new_tokens): every request gets exactly its
    own budget even when short and long requests share the batch — no row
    over-generates to the batch max or under-generates to the batch min."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    budgets = [1, 9, 2, 7, 3]
    reqs = _reqs([([i + 1, i + 2], b) for i, b in enumerate(budgets)])
    eng = ContinuousServeEngine(params, cfg, slots=3, max_seq=32)
    done = eng.run(reqs)
    assert [len(r.out) for r in done] == budgets
    assert eng.stats["tokens"] == sum(budgets)


def test_continuous_approx_matches_straightline_decode():
    """ACU route end to end: a slots=1 continuous engine with a LUT-Pallas
    acfg emits exactly the tokens of straight-line apply_model calls using
    the same bucketed-prefill semantics (per-tensor activation scales depend
    on padding, so the reference pads identically)."""
    from repro.core.acu import make_acu
    from repro.core.approx_ops import ApproxConfig
    from repro.serve.engine import _bucket
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    acfg = ApproxConfig(acu=make_acu("mul8s_1L2H", use_pallas=True,
                                     fused=True))
    prompt, n_new, max_seq = [5, 17, 3, 99, 23], 5, 32

    bucket = _bucket(len(prompt))
    off = bucket - len(prompt)
    toks = np.zeros((1, bucket), np.int32)
    toks[0, off:] = prompt
    valid = np.zeros((1, max_seq), bool)
    valid[0, off:] = True
    cache = init_cache(cfg, 1, max_seq)
    logits, cache = apply_model(params, jnp.asarray(toks), cfg, acfg=acfg,
                                cache=cache, cache_pos=0,
                                pos_offset=jnp.asarray([off], jnp.int32),
                                pad_mask=jnp.asarray(valid), last_only=True)
    ref, cur, pos = [], int(jnp.argmax(logits[0, -1])), bucket
    for _ in range(n_new - 1):
        ref.append(cur)
        logits, cache = apply_model(
            params, jnp.asarray([[cur]]), cfg, acfg=acfg, cache=cache,
            cache_pos=jnp.asarray([pos], jnp.int32), decode=True,
            pos_offset=jnp.asarray([off], jnp.int32),
            pad_mask=jnp.asarray(valid))
        cur = int(jnp.argmax(logits[0, -1]))
        pos += 1
    ref.append(cur)

    eng = ContinuousServeEngine(params, cfg, slots=1, max_seq=max_seq,
                                acfg=acfg)
    done = eng.run(_reqs([(prompt, n_new)]))
    assert list(done[0].out) == ref


# ---------------------------------------------------------------------------
# paged KV + prefix reuse
# ---------------------------------------------------------------------------

def _fused_acfg():
    from repro.core.acu import make_acu
    from repro.core.approx_ops import ApproxConfig
    return ApproxConfig(acu=make_acu("mul8s_1L2H", use_pallas=True,
                                     fused=True))


def test_paged_matches_reference_exact():
    """Paged scheduling (block pool, chunked prefill, per-slot page tables)
    is invisible on the exact path: greedy tokens equal the incremental
    per-sequence reference, mixed prompt lengths included."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    specs = [([5, 17, 3, 99], 6), ([7, 11, 2], 4),
             ([5, 17, 3, 99, 23, 41, 8, 1, 64, 12], 5), ([9, 9], 7)]
    eng = PagedContinuousServeEngine(params, cfg, slots=2, max_seq=32,
                                     block_size=8)
    done = eng.run(_reqs(specs))
    for (p, n), r in zip(specs, done):
        assert list(r.out) == greedy_reference(
            params, cfg, np.asarray(p, np.int32), n)
    assert eng.stats["tokens"] == sum(n for _, n in specs)


def test_paged_prefix_reuse_bitwise():
    """The prefix-cache contract on the ACU route: a warm admission (full or
    partial prefix hit) emits tokens bit-identical to a cold run in a fresh
    engine — shared blocks hold exactly the KV a cold prefill would write,
    and the CoW'd full-prompt tail snapshot replays the cached first token."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    acfg = _fused_acfg()
    rng = np.random.default_rng(2)
    base = rng.integers(1, cfg.vocab_size, 20).astype(np.int32).tolist()
    ext = base + rng.integers(1, cfg.vocab_size, 5).astype(np.int32).tolist()

    def mk():
        return PagedContinuousServeEngine(params, cfg, slots=2, max_seq=64,
                                          block_size=8, acfg=acfg)

    cold_a = list(mk().run(_reqs([(base, 6)]))[0].out)
    cold_b = list(mk().run(_reqs([(ext, 6)]))[0].out)
    eng = mk()
    done = eng.run(_reqs([(base, 6), (base, 6), (ext, 6)]))
    assert list(done[0].out) == cold_a
    assert list(done[1].out) == cold_a          # full-prompt hit: zero prefill
    assert list(done[2].out) == cold_b          # partial hit: replayed tail
    assert eng.stats["full_prompt_hits"] == 1
    assert eng.stats["prefix_hit_blocks"] > 0


def test_over_length_rejected_both_engines():
    """Regression: a prompt longer than max_seq must be rejected at
    admission with an empty output (not crash an assert mid-run), and must
    not disturb the requests sharing its batch."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    ok = [5, 17, 3]
    ref = greedy_reference(params, cfg, np.asarray(ok, np.int32), 4)
    too_long = np.arange(1, 20, dtype=np.int32)     # 19 > max_seq = 16
    for mk in (lambda: ContinuousServeEngine(params, cfg, slots=2,
                                             max_seq=16),
               lambda: PagedContinuousServeEngine(params, cfg, slots=2,
                                                  max_seq=16, block_size=8)):
        eng = mk()
        done = eng.run([Request(prompt=too_long, max_new_tokens=4),
                        Request(prompt=np.asarray(ok, np.int32),
                                max_new_tokens=4)])
        assert len(done[0].out) == 0
        assert eng.stats["rejected"] == 1
        assert list(done[1].out) == ref


def test_paged_preemption_resumes_exactly():
    """Memory pressure: when the pool cannot grow a decoding row, the
    youngest request is preempted keeping its emitted tokens and re-queued
    with prompt+emitted — greedy decode is deterministic, so every output
    still equals the never-preempted reference."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, 15).astype(np.int32)
               for _ in range(4)]
    refs = [greedy_reference(params, cfg, p, 20) for p in prompts]
    # 7 blocks total = null + scratch + 5 usable; each finished request
    # spans 5 blocks (35 tokens / 8), so two slots cannot both finish
    # without preempting
    eng = PagedContinuousServeEngine(
        params, cfg, slots=2, max_seq=40, block_size=8, prefix_cache=False,
        hbm_budget=7 * kv_block_bytes(cfg, 8))
    done = eng.run([Request(prompt=p, max_new_tokens=20) for p in prompts])
    for r, ref in zip(done, refs):
        assert list(r.out) == ref
    assert eng.stats["preemptions"] > 0


def test_paged_packs_more_rows_than_contiguous():
    """The point of paging: under the HBM budget of two contiguous rows, the
    paged engine still serves four short requests concurrently (occupancy
    above two slots) because rows only pin the blocks they actually use."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    budget = 2 * (64 // 8) * kv_block_bytes(cfg, 8)
    eng = PagedContinuousServeEngine(params, cfg, slots=4, max_seq=64,
                                     block_size=8, hbm_budget=budget)
    specs = [([i + 1, i + 2, i + 3], 6) for i in range(4)]
    done = eng.run(_reqs(specs))
    for (p, n), r in zip(specs, done):
        assert list(r.out) == greedy_reference(
            params, cfg, np.asarray(p, np.int32), n)
    assert eng.stats["occupancy"] > 2.0
    assert eng.stats["peak_blocks"] <= 2 * (64 // 8)


@pytest.mark.tier2
def test_paged_memory_pressure_trace():
    """Long staggered trace under real pressure on the ACU route: 10
    requests sharing a 32-token prefix against a budget of two contiguous
    rows for four slots — evictions and preemptions fire, yet every request
    gets its exact budget and the shared prefix keeps hitting."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(6)
    shared = rng.integers(1, cfg.vocab_size, 32).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
                [shared, rng.integers(1, cfg.vocab_size, 4).astype(np.int32)]),
                    max_new_tokens=8) for _ in range(10)]
    budget = 2 * (64 // 8) * kv_block_bytes(cfg, 8)
    eng = PagedContinuousServeEngine(params, cfg, slots=4, max_seq=64,
                                     block_size=8, acfg=_fused_acfg(),
                                     hbm_budget=budget)
    done = eng.run(reqs, arrivals=poisson_arrivals(len(reqs), rate=0.5,
                                                   seed=7))
    assert all(len(r.out) == 8 for r in done)
    assert eng.stats["prefix_hit_rate"] > 0.3
    assert eng.stats["peak_blocks"] <= 2 * (64 // 8)
    # determinism under pressure: same trace, fresh engine, same tokens
    reqs2 = [Request(prompt=r.prompt, max_new_tokens=8) for r in reqs]
    eng2 = PagedContinuousServeEngine(params, cfg, slots=4, max_seq=64,
                                      block_size=8, acfg=_fused_acfg(),
                                      hbm_budget=budget)
    done2 = eng2.run(reqs2, arrivals=poisson_arrivals(len(reqs), rate=0.5,
                                                      seed=7))
    for a, b in zip(done, done2):
        assert list(a.out) == list(b.out)


@pytest.mark.tier2
def test_continuous_poisson_trace():
    """Long staggered trace: every request served with its exact budget,
    arrivals respected (a request never produces tokens before it arrives),
    and the batch refills — occupancy above one slot on average."""
    cfg = reduced_config("smollm-135m")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(0)
    n = 16
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(2, 9)).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 10)))
            for _ in range(n)]
    budgets = [r.max_new_tokens for r in reqs]
    arrivals = poisson_arrivals(n, rate=0.6, seed=3)
    eng = ContinuousServeEngine(params, cfg, slots=4, max_seq=32)
    done = eng.run(reqs, arrivals=arrivals)
    assert [len(r.out) for r in done] == budgets
    assert eng.stats["prefills"] == n
    assert eng.stats["occupancy"] > 1.0
    # same requests, all-at-once: tokens identical (arrival times only
    # reorder work, they cannot change any request's greedy decode)
    reqs2 = [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens)
             for r in reqs]
    done2 = ContinuousServeEngine(params, cfg, slots=4, max_seq=32).run(reqs2)
    for a, b in zip(done, done2):
        assert list(a.out) == list(b.out)
