"""LUT vs FUNCTIONAL parity on exhaustive operand grids, plus direct coverage
of the K-padding correction branches in the pure-jnp GEMMs.

The exhaustive sweep encodes the paper's core invariant: the LUT engine is a
*bit-exact* tabulation of the functional multiplier, so the two modes must
agree on every (a, w) operand pair, for every registered 8-bit multiplier.
The pair grid is driven through the GEMM path (constant-row x constant-column
operands), so out[i, j] = 256 * M[code_i, code_j] — any single-pair
disagreement surfaces as a mismatched entry.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, get_multiplier, make_acu
from repro.core.acu import Acu, AcuMode
from repro.core.multipliers import REGISTRY, make_exact
from repro.kernels.lut_matmul.ops import lut_matmul
from repro.kernels.lut_matmul.ref import lut_matmul_ref

EIGHT_BIT = sorted(n for n, m in REGISTRY.items() if m.bits == 8)

CODES = jnp.arange(-128, 128, dtype=jnp.int32)
A_GRID = jnp.tile(CODES[:, None], (1, 256))   # a[m, k] = code_m
W_GRID = jnp.tile(CODES[None, :], (256, 1))   # w[k, n] = code_n


@pytest.mark.tier2
@pytest.mark.parametrize("name", EIGHT_BIT)
def test_exhaustive_grid_lut_equals_functional(name):
    """Full 256 x 256 operand grid: LUT mode == FUNCTIONAL mode == the table
    itself, for every registered 8-bit multiplier."""
    lut = build_lut(get_multiplier(name))
    expected = 256 * lut.astype(np.int64)     # fits int32: |M| <= 2^14
    out_lut = np.asarray(make_acu(name, AcuMode.LUT).matmul(A_GRID, W_GRID),
                         np.int64)
    out_fun = np.asarray(
        make_acu(name, AcuMode.FUNCTIONAL).matmul(A_GRID, W_GRID), np.int64)
    assert np.array_equal(out_lut, expected), name
    assert np.array_equal(out_fun, expected), name


@pytest.mark.parametrize("name", ["mul8s_1L2H", "mul8s_mitchell"])
def test_subsampled_grid_lut_equals_functional(name):
    """Tier-1 spot check of the same invariant on a stride-16 code subgrid."""
    codes = CODES[::16]
    a = jnp.tile(codes[:, None], (1, 16))
    w = jnp.tile(codes[None, :], (16, 1))
    lut = build_lut(get_multiplier(name))
    expected = 16 * lut[::16, ::16].astype(np.int64)
    out_lut = np.asarray(make_acu(name, AcuMode.LUT).matmul(a, w), np.int64)
    out_fun = np.asarray(make_acu(name, AcuMode.FUNCTIONAL).matmul(a, w),
                         np.int64)
    assert np.array_equal(out_lut, expected)
    assert np.array_equal(out_fun, expected)


# ---------------------------------------------------------------------------
# K-padding correction branches (K % chunk != 0, nonzero M[0, 0])
# ---------------------------------------------------------------------------

def _biased_mult(bias: int = 7):
    """Synthetic multiplier with M[0, 0] = bias != 0 — every registered
    family annihilates zero, leaving the pad-correction term untested."""
    return dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + bias)


def _brute(lut, a, w, off):
    M, K = a.shape
    _, N = w.shape
    out = np.zeros((M, N), np.int64)
    for i in range(M):
        for j in range(N):
            out[i, j] = lut[a[i, :] + off, w[:, j] + off].astype(np.int64).sum()
    return out


@pytest.fixture(scope="module")
def biased():
    mult = _biased_mult()
    return mult, build_lut(mult)


@pytest.fixture(scope="module")
def odd_operands():
    rng = np.random.default_rng(13)
    a = rng.integers(-128, 128, (5, 30), dtype=np.int32)   # K=30: 30 % 16 != 0
    w = rng.integers(-128, 128, (30, 4), dtype=np.int32)
    return a, w


def test_lut_matmul_jnp_k_pad_correction(biased, odd_operands):
    """_lut_matmul_jnp with K % k_chunk != 0 must subtract pad * M[0, 0]."""
    mult, lut = biased
    a, w = odd_operands
    acu = Acu(multiplier=mult, mode=AcuMode.LUT, lut=lut)
    ref = _brute(lut, a, w, 128)
    out = np.asarray(acu._lut_matmul_jnp(jnp.asarray(a), jnp.asarray(w),
                                         k_chunk=16), np.int64)
    assert np.array_equal(out, ref)


def test_functional_matmul_jnp_k_pad_correction(biased, odd_operands):
    """_functional_matmul_jnp pads with zero operands; nonzero M[0, 0] makes
    the z0 correction term observable (K=30, k_chunk=16 -> pad=2)."""
    mult, lut = biased
    a, w = odd_operands
    acu = Acu(multiplier=mult, mode=AcuMode.FUNCTIONAL)
    ref = _brute(lut, a, w, 128)
    out = np.asarray(acu._functional_matmul_jnp(jnp.asarray(a), jnp.asarray(w),
                                                k_chunk=16), np.int64)
    assert np.array_equal(out, ref)


def test_pallas_lut_matmul_k_pad_correction(biased, odd_operands):
    """The Pallas wrapper's post-kernel pk * LUT[off, off] correction, with
    a table where that term is nonzero (K=30 pads to 128 -> pk=98)."""
    mult, lut = biased
    a, w = odd_operands
    ref = _brute(lut, a, w, 128)
    out = np.asarray(lut_matmul(jnp.asarray(a), jnp.asarray(w),
                                jnp.asarray(lut), 128, interpret=True),
                     np.int64)
    assert np.array_equal(out, ref)


def test_lut_matmul_jnp_chunk_larger_than_k(biased, odd_operands):
    """k_chunk > K: chunk clamps to K, no padding branch, still exact."""
    mult, lut = biased
    a, w = odd_operands
    acu = Acu(multiplier=mult, mode=AcuMode.LUT, lut=lut)
    ref = _brute(lut, a, w, 128)
    out = np.asarray(acu._lut_matmul_jnp(jnp.asarray(a), jnp.asarray(w),
                                         k_chunk=512), np.int64)
    assert np.array_equal(out, ref)


def test_baseline_lut_chunk0_matches_ref(biased, odd_operands):
    """lut_chunk=0 (paper's unoptimized baseline) routes through the O(MKN)
    reference gather and agrees with the chunked path."""
    mult, lut = biased
    a, w = odd_operands
    base = Acu(multiplier=mult, mode=AcuMode.LUT, lut=lut, lut_chunk=0)
    ref = lut_matmul_ref(jnp.asarray(a), jnp.asarray(w),
                         jnp.asarray(lut).reshape(-1), 128, 256)
    out = base.matmul(jnp.asarray(a), jnp.asarray(w))
    assert jnp.array_equal(out, ref)
