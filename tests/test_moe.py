"""MoE dispatch correctness: capacity scatter == dense masked computation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.moe import moe_block, router_aux_loss
from repro.models.transformer import _init_moe

CFG = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=16, vocab_size=64, pattern=("attn_moe",),
                  n_experts=4, moe_top_k=2, moe_capacity=8.0,  # ample capacity
                  dtype="float32")
KEY = jax.random.PRNGKey(0)


def dense_reference(x, p, cfg):
    """Compute every expert for every token, combine with top-k weights."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    ye = jnp.stack(outs, 1)            # (T, E, D)
    w = jnp.zeros((t, cfg.n_experts)).at[
        jnp.arange(t)[:, None], top_e].set(top_p)
    return (w[..., None] * ye).sum(1).reshape(b, s, d)


def test_moe_matches_dense_reference():
    p = jax.tree.map(lambda a: a[0], _init_moe(KEY, CFG, 1))
    x = jax.random.normal(KEY, (2, 8, 32))
    out = moe_block(x, p, CFG, None)
    ref = dense_reference(x, p, CFG)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens():
    """With capacity < perfect balance, output differs but stays finite."""
    cfg = dataclasses.replace(CFG, moe_capacity=0.25)
    p = jax.tree.map(lambda a: a[0], _init_moe(KEY, cfg, 1))
    x = jax.random.normal(KEY, (2, 8, 32))
    out = moe_block(x, p, cfg, None)
    assert bool(jnp.isfinite(out).all())


def test_router_aux_loss_balanced_lower():
    """A balanced random router scores lower aux loss than a skewed one."""
    t = 512
    # positive-mean features so a constant-column router reliably skews
    x = jnp.abs(jax.random.normal(KEY, (1, t, 32))) + 0.5
    balanced = jax.random.normal(jax.random.PRNGKey(1), (32, 4)) * 0.1
    skewed = jnp.zeros((32, 4)).at[:, 0].set(1.0).at[:, 1].set(0.5)
    l_b = router_aux_loss(x, balanced, 4, 2)
    l_s = router_aux_loss(x, skewed, 4, 2)
    assert float(l_b) < float(l_s)


def test_moe_grads():
    p = jax.tree.map(lambda a: a[0], _init_moe(KEY, CFG, 1))
    x = jax.random.normal(KEY, (2, 8, 32))

    def loss(p):
        return (moe_block(x, p, CFG, None) ** 2).sum()

    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["w_gate"]).max()) > 0
