"""Sharding planner invariants (no real mesh needed — specs only)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.launch.specs import abstract_params, pick_microbatches
from repro.parallel import planner
from repro.parallel.sharding import MeshContext, DEFAULT_RULES

pytestmark = pytest.mark.skipif(
    len(jax.devices()) not in (1,), reason="host test")


class FakeMesh:
    """Duck-typed mesh for planner unit tests (shape/axis_names only)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisibility(specs, params):
    flat_s = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    flat_p = jax.tree.leaves(params)
    for sp, leaf in zip(flat_s, flat_p):
        for i, part in enumerate(sp):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            n = int(np.prod([MESH.shape.get(a, MESH_MP.shape.get(a, 1))
                             for a in axes]))
            assert leaf.shape[i] % n == 0, (sp, leaf.shape, i)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_divisible(arch, mode):
    """Every sharded dim divides the axis product — for all 10 archs."""
    cfg = get_config(arch)
    params = abstract_params(cfg)
    plan = planner.param_specs(cfg, params, MESH, mode=mode)
    _check_divisibility(plan.specs, params)


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "qwen2.5-14b",
                                  "smollm-135m", "whisper-small"])
def test_nondivisible_heads_reported(arch):
    cfg = get_config(arch)
    plan = planner.param_specs(cfg, abstract_params(cfg), MESH, mode="train")
    assert any("heads" in r for r in plan.report)


def test_batch_spec_fallbacks():
    assert planner.batch_spec(MESH, 256) == P(("data",), None)
    assert planner.batch_spec(MESH_MP, 256) == P(("pod", "data"), None)
    assert planner.batch_spec(MESH, 1) == P(None, None)       # long_500k
    assert planner.batch_spec(MESH_MP, 32) == P(("pod", "data"), None)


def test_mesh_context_dedupes_axes():
    """One mesh axis may appear at most once per spec (MoE regression)."""
    mesh = FakeMesh({"data": 4, "model": 4})
    ctx = MeshContext(mesh=mesh, rules=dict(DEFAULT_RULES))
    sp = ctx.spec("experts", None, "expert_mlp", dim_sizes=(8, 3, 8))
    flat = [a for part in sp if part for a in
            ((part,) if isinstance(part, str) else part)]
    assert len(flat) == len(set(flat))


def test_mesh_context_divisibility_fallback():
    mesh = FakeMesh({"data": 4, "model": 4})
    ctx = MeshContext(mesh=mesh, rules=dict(DEFAULT_RULES))
    assert ctx.spec("heads", dim_sizes=(9,)) == P(None)   # 9 % 4 != 0
    assert ctx.spec("heads", dim_sizes=(8,)) == P("model")


def test_microbatch_policy():
    cfg = get_config("qwen2-vl-72b")
    n = pick_microbatches(cfg, 256, 4096, MESH)
    assert n >= 8                       # 80L x 8192d needs accumulation
    assert 256 % n == 0
    small = pick_microbatches(get_config("smollm-135m"), 256, 4096, MESH)
    assert small == 1                   # tiny model: no accumulation


def test_acu_gemm_partition_defaults():
    """Default ACU rules: rows over (pod,)data, cols over model, K
    replicated — and the specs shard_map consumes."""
    ctx = MeshContext(mesh=MESH, rules=dict(DEFAULT_RULES))
    part, report = planner.acu_gemm_partition(ctx)
    assert (part.rows, part.cols, part.k) == (("data",), ("model",), ())
    assert (part.n_rows, part.n_cols, part.n_k) == (16, 16, 1)
    assert part.a_spec() == P("data", None)
    assert part.w_spec() == P(None, "model")
    assert part.out_spec() == P("data", "model")
    assert not report
    mp, _ = planner.acu_gemm_partition(
        MeshContext(mesh=MESH_MP, rules=dict(DEFAULT_RULES)))
    assert mp.rows == ("pod", "data") and mp.n_rows == 32


def test_acu_gemm_partition_contracting_claims_model():
    """acu_k wins the model axis; cols fall back with an audited report."""
    rules = dict(DEFAULT_RULES, acu_k=("model",))
    part, report = planner.acu_gemm_partition(
        MeshContext(mesh=MESH, rules=rules))
    assert part.k == ("model",) and part.cols == ()
    assert part.a_spec() == P("data", "model")
    assert part.w_spec() == P("model", None)
    assert any("contraction" in r for r in report)


def test_acu_gemm_partition_lowrank_drops_k():
    """Float accumulators (LOWRANK) cannot psum bit-exactly -> K replicated."""
    rules = dict(DEFAULT_RULES, acu_k=("model",))
    part, report = planner.acu_gemm_partition(
        MeshContext(mesh=MESH, rules=rules), float_accum=True)
    assert part.k == () and part.cols == ("model",)
    assert any("LOWRANK" in r for r in report)
    assert part.report == tuple(report)   # surfaced on the dispatch path


def test_use_mesh_context_verbatim():
    """use_mesh_context must not re-merge DEFAULT_RULES: a context whose
    rules omit a key means 'replicated there'."""
    from repro.parallel.sharding import current_mesh_context, use_mesh_context
    ctx = MeshContext(mesh=MESH, rules={"acu_rows": ("data",)})
    with use_mesh_context(ctx):
        active = current_mesh_context()
        assert active is ctx
        assert active.axes_for("acu_cols") == ()   # omitted -> replicated
    assert current_mesh_context() is None


def test_serve_fsdp_threshold():
    big = get_config("command-r-plus-104b")
    plan = planner.param_specs(big, abstract_params(big), MESH, mode="serve")
    assert any("ZeRO-inference" in r for r in plan.report)
    small = get_config("gemma2-27b")
    plan2 = planner.param_specs(small, abstract_params(small), MESH, mode="serve")
    assert not any("ZeRO-inference" in r for r in plan2.report)
