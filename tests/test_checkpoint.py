"""Checkpoint: roundtrip, retention, async, mesh-agnostic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as C


def make_tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": (jnp.ones(3), jnp.zeros(()))}}


def test_roundtrip(tmp_path):
    tree = make_tree(jax.random.PRNGKey(0))
    C.save(str(tmp_path), 7, tree)
    assert C.latest_step(str(tmp_path)) == 7
    restored, man = C.restore(str(tmp_path), 7, tree)
    assert man["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp_path):
    tree = {"x": jnp.ones(2)}
    for s in (1, 2, 3, 4, 5):
        C.save(str(tmp_path), s, tree, keep=2)
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_saver(tmp_path):
    saver = C.AsyncSaver()
    tree = make_tree(jax.random.PRNGKey(1))
    saver.submit(str(tmp_path), 3, tree)
    saver.submit(str(tmp_path), 4, tree)   # supersedes queued older writes
    saver.wait()
    assert C.latest_step(str(tmp_path)) == 4


def test_async_saver_submit_drain_race(tmp_path):
    """Stress the submit/drain handoff: the drainer used to decide to exit
    (pending empty) while still reading as alive, so a submit landing in that
    window parked its snapshot in the pending slot with no thread to write it
    — ``wait()`` then returned with the newest step missing on disk. Many
    rapid submit/wait cycles make that window land reliably."""
    saver = C.AsyncSaver()
    tree = {"x": jnp.ones(2)}
    for step in range(1, 120):
        saver.submit(str(tmp_path), step, tree, keep=3)
        if step % 3 == 0:
            saver.wait()
            assert C.latest_step(str(tmp_path)) == step, step
    saver.wait()
    assert C.latest_step(str(tmp_path)) == 119
    assert saver.last_saved_step == 119


def test_restore_with_shardings(tmp_path):
    """Elastic restart: restore onto explicit (single-device) shardings."""
    tree = make_tree(jax.random.PRNGKey(2))
    C.save(str(tmp_path), 1, tree)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = C.restore(str(tmp_path), 1, tree, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left(tmp_path):
    tree = {"x": jnp.ones(4)}
    C.save(str(tmp_path), 9, tree)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
