"""Block-level invariants: mamba/rwkv recurrences agree across formulations."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.mamba import MambaState, mamba_block
from repro.models.rwkv import RwkvState, rwkv_block
from repro.models.transformer import _init_mamba, _init_rwkv

KEY = jax.random.PRNGKey(0)

MCFG = ModelConfig(name="m", family="hybrid", n_layers=1, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                   pattern=("mamba",), mamba_d_state=4, mamba_d_conv=3,
                   dtype="float32")

RCFG = ModelConfig(name="r", family="ssm", n_layers=1, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab_size=64, pattern=("rwkv",),
                   rwkv_head_dim=8, rwkv_chunk=4, rope="none", dtype="float32")


def test_mamba_parallel_vs_stepwise():
    """Associative-scan (train) == token-by-token recurrent (decode)."""
    p = jax.tree.map(lambda a: a[0], _init_mamba(KEY, MCFG, 1))
    x = jax.random.normal(KEY, (2, 6, 32))
    y_par, st_par = mamba_block(x, p, MCFG, None)

    st = MambaState(conv=jnp.zeros((2, MCFG.mamba_d_conv - 1, MCFG.mamba_d_inner)),
                    ssm=jnp.zeros((2, MCFG.mamba_d_inner, MCFG.mamba_d_state)))
    outs = []
    for t in range(6):
        y, st = mamba_block(x[:, t:t + 1], p, MCFG, None, state=st, decode=True)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_par.ssm), np.asarray(st.ssm),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_vs_stepwise():
    """Chunked nested-scan (train) == token-by-token recurrent (decode)."""
    p = jax.tree.map(lambda a: a[0], _init_rwkv(KEY, RCFG, 1))
    x = jax.random.normal(KEY, (2, 8, 32)) * 0.5
    y_par, st_par = rwkv_block(x, p, RCFG, None)

    h, hd = RCFG.rwkv_n_heads, RCFG.rwkv_head_dim
    st = RwkvState(tm_shift=jnp.zeros((2, 1, 32)),
                   wkv=jnp.zeros((2, h, hd, hd)),
                   cm_shift=jnp.zeros((2, 1, 32)))
    outs = []
    for t in range(8):
        y, st = rwkv_block(x[:, t:t + 1], p, RCFG, None, state=st, decode=True)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_par.wkv), np.asarray(st.wkv),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunk_size_invariance():
    """WKV output must not depend on the remat chunk size."""
    p = jax.tree.map(lambda a: a[0], _init_rwkv(KEY, RCFG, 1))
    x = jax.random.normal(KEY, (1, 8, 32)) * 0.5
    y1, _ = rwkv_block(x, p, RCFG, None)
    y2, _ = rwkv_block(x, p, dataclasses.replace(RCFG, rwkv_chunk=8), None)
    y3, _ = rwkv_block(x, p, dataclasses.replace(RCFG, rwkv_chunk=2), None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), rtol=1e-5, atol=1e-5)


def test_mamba_state_carries_context():
    """Prefix processed through state == processing the full sequence."""
    p = jax.tree.map(lambda a: a[0], _init_mamba(KEY, MCFG, 1))
    x = jax.random.normal(KEY, (1, 10, 32))
    y_full, _ = mamba_block(x, p, MCFG, None)
    _, st = mamba_block(x[:, :6], p, MCFG, None,
                        state=MambaState(
                            conv=jnp.zeros((1, 2, MCFG.mamba_d_inner)),
                            ssm=jnp.zeros((1, MCFG.mamba_d_inner, 4))))
    y_tail, _ = mamba_block(x[:, 6:], p, MCFG, None, state=st, decode=True)
    np.testing.assert_allclose(np.asarray(y_full[:, 6:]), np.asarray(y_tail),
                               rtol=2e-3, atol=2e-3)
