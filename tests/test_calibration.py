"""Calibration: percentile/MSE/entropy calibrators + histogram rebinning."""
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (HistogramObserver, calibrate_activation,
                                    calibrate_weight)
from repro.core.quantization import dequantize, quantize


def test_percentile_excludes_outliers(rng):
    obs = HistogramObserver()
    x = rng.normal(size=20000).astype(np.float32)
    x[:5] = 1000.0  # outliers
    obs.update(x)
    cmax = obs.percentile_max(99.9)
    assert cmax < 10.0          # clip bound ignores the 1000s
    assert cmax > 2.5           # but covers the bulk


def test_rebinning_consistency(rng):
    """Feeding data in growing-range chunks ~= feeding it at once."""
    a = rng.normal(size=5000).astype(np.float32)
    b = (rng.normal(size=5000) * 8).astype(np.float32)
    one = HistogramObserver()
    one.update(np.concatenate([a, b]))
    two = HistogramObserver()
    two.update(a)   # small range first -> forces rebinning on b
    two.update(b)
    p1 = one.percentile_max(99.0)
    p2 = two.percentile_max(99.0)
    assert abs(p1 - p2) / p1 < 0.15


def test_mse_and_entropy_return_sane_bounds(rng):
    obs = HistogramObserver()
    obs.update(rng.normal(size=8000).astype(np.float32))
    for m in (obs.mse_max(8), obs.entropy_max(8)):
        assert 0 < m <= obs.range * 1.001


def test_calibrated_quantization_low_error(rng):
    x = rng.normal(size=8000).astype(np.float32)
    obs = HistogramObserver()
    obs.update(x)
    qp = calibrate_activation(obs, 8, method="percentile")
    back = dequantize(quantize(jnp.asarray(x), qp), qp)
    rel = float(jnp.abs(back - x).mean() / jnp.abs(jnp.asarray(x)).mean())
    assert rel < 0.02  # paper: < 0.1% top-1 loss for 8-bit CNNs


def test_calibrate_weight_per_channel(rng):
    w = rng.normal(size=(32, 6)).astype(np.float32)
    qp = calibrate_weight(jnp.asarray(w), 8, axis=1)
    assert qp.scale.shape == (6,)
    assert qp.axis == 1


def test_observer_min_max_tracking(rng):
    obs = HistogramObserver()
    obs.update(np.asarray([-3.0, 7.0], np.float32))
    assert obs.xmin == -3.0 and obs.xmax == 7.0
