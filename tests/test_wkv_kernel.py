"""WKV-6 Pallas kernel vs lax.scan oracle (+ consistency with the model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv.ops import wkv
from repro.kernels.wkv.ref import wkv_ref


@pytest.mark.parametrize("shape", [(1, 8, 1, 4), (2, 16, 3, 8), (2, 33, 2, 16)])
def test_wkv_matches_ref(shape):
    B, T, H, hd = shape
    rng = np.random.default_rng(T)
    r, k, v = (jnp.asarray(rng.normal(size=shape), jnp.float32) for _ in range(3))
    w = jnp.asarray(rng.uniform(0.4, 0.999, shape), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)), jnp.float32)
    out, sT = wkv(r, k, v, w, u, s0, interpret=True)
    for h in range(H):
        o_ref, s_ref = wkv_ref(r[:, :, h], k[:, :, h], v[:, :, h], w[:, :, h],
                               u[h], s0[:, h])
        np.testing.assert_allclose(np.asarray(out[:, :, h]), np.asarray(o_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sT[:, h]), np.asarray(s_ref),
                                   rtol=1e-5, atol=1e-5)


def test_wkv_zero_state_decay_one():
    """w == 1 (no decay), u == 0: out_t = r_t . (sum_{s<t} k_s^T v_s)."""
    B, T, H, hd = 1, 5, 1, 4
    rng = np.random.default_rng(0)
    r, k, v = (jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
               for _ in range(3))
    w = jnp.ones((B, T, H, hd), jnp.float32)
    u = jnp.zeros((H, hd), jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    out, _ = wkv(r, k, v, w, u, s0, interpret=True)
    s = np.zeros((hd, hd), np.float32)
    for t in range(T):
        expect = np.asarray(r[0, t, 0]) @ s
        np.testing.assert_allclose(np.asarray(out[0, t, 0]), expect,
                                   rtol=1e-4, atol=1e-5)
        s = s + np.outer(np.asarray(k[0, t, 0]), np.asarray(v[0, t, 0]))
