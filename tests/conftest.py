import numpy as np
import pytest
from hypothesis import settings

# fast hypothesis profile: CI-sized example counts
settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
