import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, settings

# fast hypothesis profile: CI-sized example counts (the offline fallback shim
# honors the same profile API — see _hypothesis_compat.py)
settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")


def pytest_report_header(config):
    return f"hypothesis: {'real' if HAVE_HYPOTHESIS else 'offline fallback shim'}"


@pytest.fixture
def rng():
    return np.random.default_rng(0)
