"""Offline-safe ``hypothesis`` shim.

The real library is used whenever it is importable. When it is not (this
container has no network), a minimal fallback expands each ``@given`` into a
fixed, deterministically-seeded sample of examples: boundary values of every
strategy first (lo / hi / 0 / each ``sampled_from`` member), then pseudo-random
draws seeded from the test's qualified name. No shrinking, no database — just
enough of the API surface for this repo's property tests to run and stay
reproducible offline.

Usage (tests and conftest import from here, never from ``hypothesis``):

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, strategies
"""
from __future__ import annotations

try:
    from hypothesis import assume, given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as np

    class _AssumeViolation(Exception):
        """Raised by :func:`assume`; the current example is skipped."""

    def assume(condition) -> bool:
        if not condition:
            raise _AssumeViolation()
        return True

    class _Strategy:
        """A value source: fixed edge cases first, then seeded random draws."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self._edges = tuple(edges)

        def example_at(self, rng: np.random.Generator, i: int):
            if i < len(self._edges):
                return self._edges[i]
            return self._draw(rng)

    class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            edges = [v for v in dict.fromkeys(
                (min_value, max_value, 0, 1, -1, min_value + 1, max_value - 1))
                if min_value <= v <= max_value]
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                edges=edges)

        @staticmethod
        def floats(min_value: float, max_value: float, *, allow_nan=False,
                   width: int = 64, allow_subnormal=True,
                   allow_infinity=False) -> _Strategy:
            cast = (lambda v: float(np.float32(v))) if width == 32 else float
            edges = [cast(v) for v in dict.fromkeys(
                (min_value, max_value, 0.0, min_value / 2, max_value / 2))
                if min_value <= v <= max_value]
            return _Strategy(
                lambda rng: cast(rng.uniform(min_value, max_value)),
                edges=edges)

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))],
                edges=elements)

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example_at(rng, len(elements._edges) + j)
                        for j in range(n)]

            edges = []
            if min_size <= 1 <= max_size and elements._edges:
                edges = [[e] for e in elements._edges]
            return _Strategy(draw, edges=edges)

    class settings:  # noqa: N801 — mimics `hypothesis.settings`
        _profiles: dict = {"default": {"max_examples": 25}}
        _current: str = "default"

        def __init__(self, max_examples=None, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            if self.max_examples is not None:
                fn._compat_max_examples = self.max_examples
            return fn

        @classmethod
        def register_profile(cls, name: str, max_examples: int = 25,
                             deadline=None, **_kw) -> None:
            cls._profiles[name] = {"max_examples": max_examples}

        @classmethod
        def load_profile(cls, name: str) -> None:
            cls._current = name

        @classmethod
        def _profile_max_examples(cls) -> int:
            return cls._profiles[cls._current]["max_examples"]

    def given(*args, **strategies_by_name):
        assert not args, "fallback @given supports keyword strategies only"

        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*wargs, **wkw):
                n = (getattr(wrapper, "_compat_max_examples", None)
                     or getattr(fn, "_compat_max_examples", None)
                     or settings._profile_max_examples())
                rng = np.random.default_rng(
                    zlib.adler32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {k: s.example_at(rng, i)
                             for k, s in strategies_by_name.items()}
                    try:
                        fn(*wargs, **drawn, **wkw)
                    except _AssumeViolation:
                        continue
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsifying example (#{i}): {drawn!r}") from e
            # pytest resolves fixtures through __wrapped__'s signature; the
            # strategy-drawn parameters must not be mistaken for fixtures
            del wrapper.__wrapped__
            return wrapper
        return decorate
