"""approx_ops: conv-as-GEMM correctness, groups, separable, QAT gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import (ApproxConfig, approx_dense, conv2d,
                                   separable_conv2d)

EXACT8 = ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT), a_bits=8, w_bits=8)
EXACT12 = ApproxConfig(acu=make_acu("mul12s_exact", AcuMode.EXACT), a_bits=12, w_bits=12)
APPROX = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))


def lax_conv(x, w, stride=(1, 1), padding="SAME", groups=1, dilation=(1, 1)):
    return jax.lax.conv_general_dilated(
        x, w, stride, padding, rhs_dilation=dilation, feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


@pytest.mark.parametrize("stride,padding,dilation", [
    ((1, 1), "SAME", (1, 1)), ((2, 2), "SAME", (1, 1)),
    ((1, 1), "VALID", (1, 1)), ((1, 1), "SAME", (2, 2)), ((2, 1), "VALID", (1, 1))])
def test_conv2d_im2col_matches_lax(rng, stride, padding, dilation):
    x = jnp.asarray(rng.normal(size=(2, 3, 12, 12)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 3, 3, 3)), jnp.float32)
    # exact fp path (cfg=None) vs im2col path through an exact 8-bit-free GEMM:
    # run the im2col branch by passing a cfg with the exact ACU and wide bits
    cfg = ApproxConfig(acu=make_acu("mul12s_exact", AcuMode.EXACT),
                       a_bits=12, w_bits=12)
    ours = conv2d(x, w, stride=stride, padding=padding, dilation=dilation, cfg=cfg)
    ref = lax_conv(x, w, stride, padding, dilation=dilation)
    # quantized to 12 bits -> small relative error only
    rel = float(jnp.abs(ours - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-3, rel


def test_conv2d_exact_path_matches_lax(rng):
    x = jnp.asarray(rng.normal(size=(2, 4, 9, 9)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(6, 4, 3, 3)), jnp.float32)
    np.testing.assert_allclose(np.asarray(conv2d(x, w)),
                               np.asarray(lax_conv(x, w)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_conv(rng, groups):
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 8 // groups, 3, 3)), jnp.float32)
    cfg = ApproxConfig(acu=make_acu("mul12s_exact", AcuMode.EXACT),
                       a_bits=12, w_bits=12)
    ours = conv2d(x, w, groups=groups, cfg=cfg)
    ref = lax_conv(x, w, groups=groups)
    rel = float(jnp.abs(ours - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-3


def test_depthwise_blockdiag(rng):
    x = jnp.asarray(rng.normal(size=(2, 6, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(6, 1, 3, 3)), jnp.float32)
    cfg = ApproxConfig(acu=make_acu("mul12s_exact", AcuMode.EXACT),
                       a_bits=12, w_bits=12)
    ours = conv2d(x, w, groups=6, cfg=cfg)
    ref = lax_conv(x, w, groups=6)
    rel = float(jnp.abs(ours - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-3


def test_separable_conv(rng):
    x = jnp.asarray(rng.normal(size=(1, 4, 8, 8)), jnp.float32)
    wdw = jnp.asarray(rng.normal(size=(4, 1, 3, 3)), jnp.float32)
    wpw = jnp.asarray(rng.normal(size=(6, 4, 1, 1)), jnp.float32)
    out = separable_conv2d(x, wdw, wpw)
    ref = lax_conv(lax_conv(x, wdw, groups=4), wpw, padding="VALID")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_qat_gradients_flow(rng):
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)

    def loss(w):
        return (approx_dense(x, w, None, APPROX) ** 2).sum()

    g = jax.grad(loss)(w)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0
    # STE gradient approximates the exact-matmul gradient
    g_exact = jax.grad(lambda w: ((x @ w) ** 2).sum())(w)
    cos = jnp.sum(g * g_exact) / (jnp.linalg.norm(g) * jnp.linalg.norm(g_exact))
    assert float(cos) > 0.95


def test_approx_forward_deviates_backward_clean(rng):
    """Forward uses the ACU (output differs from exact); backward is STE."""
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    y_approx = approx_dense(x, w, None, APPROX)
    y_exact = x @ w
    assert float(jnp.abs(y_approx - y_exact).max()) > 1e-4  # ACU visible
