"""Paper Table-2 claim, end to end (reduced): post-training quantization
keeps accuracy; a lossy 8-bit ACU degrades it; approx-aware retraining (QAT
through the ACU forward / STE backward) recovers most of the loss; the
near-exact 12-bit ACU never degrades.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.data.pipeline import image_task
from repro.models.vision import cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)


def accuracy(params, batches, acfg=None, n=4):
    correct = total = 0
    it = iter(batches)
    for _ in range(n):
        b = next(it)
        logits = cnn_forward(params, jnp.asarray(b["image"]), acfg)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(b["label"])).sum())
        total += len(b["label"])
    return correct / total


def train(params, batches, steps, lr=3e-3, acfg=None):
    def loss_fn(p, img, lab):
        logits = cnn_forward(p, img, acfg)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]
        return (logz - gold).mean()

    step = jax.jit(lambda p, img, lab: jax.tree.map(
        lambda w, g: w - lr * g, p,
        jax.grad(loss_fn)(p, img, lab)))
    it = iter(batches)
    for _ in range(steps):
        b = next(it)
        params = step(params, jnp.asarray(b["image"]), jnp.asarray(b["label"]))
    return params


@pytest.mark.slow
def test_qat_recovery_flow():
    task0 = image_task(n_classes=4, size=16)
    task = lambda b, seed=1: task0(b, noise=0.45, seed=seed)
    params = init_cnn(KEY, n_classes=4, width=8, in_ch=3, img=16)
    params = train(params, task(64, seed=1), steps=100)

    acc_fp32 = accuracy(params, task(64, seed=99))
    assert acc_fp32 > 0.9, f"fp32 baseline too weak: {acc_fp32}"

    # 8-bit exact quantization: ~no loss (paper: ~0.1%)
    q8 = ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT))
    acc_q8 = accuracy(params, task(64, seed=99), q8)
    assert acc_q8 > acc_fp32 - 0.05

    # lossy 8-bit ACU degrades
    ap8 = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))
    acc_ap8 = accuracy(params, task(64, seed=99), ap8)

    # approx-aware retraining recovers (paper: ResNet50 82.7% -> 93.4%)
    recovered = train(params, task(64, seed=2), steps=40, lr=1e-3, acfg=ap8)
    acc_rec = accuracy(recovered, task(64, seed=99), ap8)
    assert acc_rec >= acc_ap8 - 0.02
    assert acc_rec > acc_fp32 - 0.15, (acc_fp32, acc_ap8, acc_rec)

    # near-exact 12-bit ACU: no degradation without any retraining
    ap12 = ApproxConfig(acu=make_acu("mul12s_2KM", AcuMode.FUNCTIONAL),
                        a_bits=12, w_bits=12)
    acc_ap12 = accuracy(params, task(64, seed=99), ap12)
    assert acc_ap12 > acc_fp32 - 0.05
