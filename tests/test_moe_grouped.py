"""Grouped ragged fused LUT-GEMM: kernel edge cases, plan routes, MoE wiring.

The bit-exactness oracle everywhere is the per-expert composition — either
``fused_lut_dense`` per group (kernel level) or ``approx_dense`` per expert
(approx level) — with the SAME pinned shared activation scale and the SAME
multiply-form (inline) weight scales the grouped path uses, masked to each
group's live rows. "Equal" is ``jnp.array_equal``, not allclose.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import build_lut, get_multiplier, make_acu
from repro.core.acu import AcuMode, GroupedSpec, grouped_plan
from repro.core.approx_ops import ApproxConfig, approx_dense, approx_grouped_dense
from repro.core.multipliers import make_exact
from repro.core.quantization import (QParams, acu_operand,
                                     inline_symmetric_scale, quantize,
                                     symmetric_qparams)
from repro.kernels.fused_lut_dense.ops import fused_lut_dense
from repro.kernels.fused_lut_grouped.ops import fused_lut_grouped
from repro.models.moe import dispatch_geometry, moe_block, router_aux_loss
from repro.models.transformer import _init_moe

LUT = jnp.asarray(build_lut(get_multiplier("mul8s_1L2H")))
# biased multiplier: M[0, 0] = 7, so an all-zero row still accumulates
# K * LUT[0, w] != 0 — masking dead rows is observably different from
# never computing them
BIASED = dataclasses.replace(
    make_exact(8), name="mul8s_biased",
    fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
BLUT = jnp.asarray(build_lut(BIASED))

ACU = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
CFG_A = ApproxConfig(acu=ACU)
KEY = jax.random.PRNGKey(0)


def _grouped_operands(G, E, C, K, N, seed=0, counts=None):
    """Random operands with dispatch-style dead rows zeroed past counts."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(G, C, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    if counts is None:
        counts = rng.integers(0, C + 1, size=(G,))
    counts = jnp.asarray(counts, jnp.int32)
    mask = jnp.arange(C)[None, :] < counts[:, None]
    x = x * mask[..., None]
    return x, w, counts, mask


def _quantized(x, w):
    """Pinned shared activation qparams + per-expert weight codes/scales."""
    E = w.shape[0]
    xqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(x)), 1e-6), 8)
    qps = [symmetric_qparams(
        jnp.maximum(jnp.max(jnp.abs(w[e]), axis=0), 1e-9), 8, axis=1)
        for e in range(E)]
    wq = jnp.stack([acu_operand(quantize(w[e], qps[e]), qps[e])
                    for e in range(E)])
    ws = jnp.stack([qp.scale for qp in qps])
    return xqp, wq, ws


def _kernel_oracle(x, wq, lut, xqp, ws, mask):
    """Per-group fused_lut_dense with the shared scale, dead rows zeroed."""
    G, E = x.shape[0], wq.shape[0]
    refs = []
    for g in range(G):
        r = fused_lut_dense(x[g], wq[g % E], lut, 128, xqp.scale,
                            xqp.zero_point, ws[g % E], bits=8, interpret=True)
        refs.append(jnp.where(mask[g][:, None], r, 0.0))
    return jnp.stack(refs)


# ---------------------------------------------------------------------------
# kernel-level ragged edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    # (G, E, C, K, N, biased, counts)
    (4, 4, 24, 33, 14, False, None),            # one block, ragged counts
    (8, 4, 24, 33, 14, False, None),            # nb=2 dispatch blocks
    (4, 4, 24, 33, 14, True, None),             # biased M00 + dead rows
    (4, 4, 24, 600, 14, False, None),           # K > 512 -> k-tiled grid
    (6, 3, 16, 40, 9, True, [0, 16, 3, 0, 16, 5]),  # empty experts
    (4, 4, 32, 40, 9, False, [32, 0, 0, 0]),    # all tokens to one expert
], ids=["ragged", "blocks", "biased_m00", "ktile", "empty_experts",
        "all_to_one"])
def test_grouped_kernel_bitwise_vs_per_expert(case):
    G, E, C, K, N, biased, counts = case
    lut = BLUT if biased else LUT
    x, w, counts, mask = _grouped_operands(G, E, C, K, N,
                                           seed=sum((G, C, K, N)),
                                           counts=counts)
    xqp, wq, ws = _quantized(x, w)
    out = fused_lut_grouped(x, wq, lut, 128, xqp.scale, xqp.zero_point, ws,
                            counts, bits=8, interpret=True)
    ref = _kernel_oracle(x, wq, lut, xqp, ws, mask)
    assert jnp.array_equal(out, ref)


def test_grouped_kernel_biased_dead_rows_exact_zero():
    """Rows past a group's count are never accumulated, not masked after
    the fact: under the biased multiplier a computed-then-masked zero row
    would carry sum(LUT[0, w]) != 0 before the mask, and the int32
    accumulator (emit_acc) shows the row really is zero in integer space."""
    x, w, counts, mask = _grouped_operands(4, 2, 16, 40, 9, seed=3,
                                           counts=[3, 16, 0, 7])
    xqp, wq, ws = _quantized(x, w)
    acc = fused_lut_grouped(x, wq, BLUT, 128, xqp.scale, xqp.zero_point, ws,
                            counts, bits=8, interpret=True, emit_acc=True)
    assert acc.dtype == jnp.int32
    assert bool(jnp.all(jnp.where(mask[..., None], 0, acc) == 0))
    # and the fused dequant output equals the one combined-scale multiply
    out = fused_lut_grouped(x, wq, BLUT, 128, xqp.scale, xqp.zero_point, ws,
                            counts, bits=8, interpret=True)
    dq = acc.astype(jnp.float32) * (xqp.scale * ws[:, None, :])[
        jnp.arange(4) % 2]
    assert jnp.array_equal(out, jnp.where(mask[..., None], dq, 0.0))


def test_grouped_kernel_jit_parity():
    x, w, counts, _ = _grouped_operands(4, 4, 24, 33, 14, seed=11)
    xqp, wq, ws = _quantized(x, w)

    def f(x, wq, ws, counts):
        return fused_lut_grouped(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                                 ws, counts, bits=8, interpret=True)

    assert jnp.array_equal(f(x, wq, ws, counts),
                           jax.jit(f)(x, wq, ws, counts))


# ---------------------------------------------------------------------------
# approx_grouped_dense: routes, oracle, STE
# ---------------------------------------------------------------------------

def _approx_operands(nb=2, E=4, C=24, K=33, N=14, seed=0):
    return _grouped_operands(nb * E, E, C, K, N, seed=seed)


def test_approx_grouped_routes_bitwise():
    """Fused grouped == pinned vmap fallback == per-expert approx_dense
    driven with the same pinned shared xqp + inline per-expert wqp."""
    x, w, counts, mask = _approx_operands()
    y_f = approx_grouped_dense(x, w, CFG_A, counts)
    y_v = approx_grouped_dense(x, w, CFG_A, counts, route="vmap")
    assert jnp.array_equal(y_f, y_v)

    E, N = w.shape[0], w.shape[2]
    xqp = QParams(scale=inline_symmetric_scale(
        jnp.maximum(jnp.max(jnp.abs(x)), 1e-6), 8),
        zero_point=jnp.zeros((), jnp.float32), bits=8)
    wscale = inline_symmetric_scale(
        jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-9), 8)
    refs = []
    for g in range(x.shape[0]):
        e = g % E
        wqp = QParams(scale=wscale[e], zero_point=jnp.zeros((), jnp.float32),
                      bits=8, axis=1)
        r = approx_dense(x[g], w[e], None, CFG_A, xqp=xqp, wqp=wqp)
        refs.append(jnp.where(mask[g][:, None], r, 0.0))
    assert jnp.array_equal(y_f, jnp.stack(refs))


def test_approx_grouped_jit_eager_bitwise():
    """Default qparams are computed in multiply (inline) form, so the jitted
    layer equals the eager one bitwise — no reciprocal-multiply scale
    drift."""
    x, w, counts, _ = _approx_operands(seed=5)
    y = approx_grouped_dense(x, w, CFG_A, counts)
    y_j = jax.jit(lambda x, w, c: approx_grouped_dense(x, w, CFG_A, c))(
        x, w, counts)
    assert jnp.array_equal(y, y_j)


def test_approx_grouped_ste_grads():
    """STE grads agree between routes; dead rows carry no gradient."""
    x, w, counts, mask = _approx_operands(seed=7)
    N = w.shape[2]

    def loss(route):
        return lambda x, w: (approx_grouped_dense(
            x, w, CFG_A, counts, route=route) * jnp.arange(N)).sum()

    gfx, gfw = jax.grad(loss(None), argnums=(0, 1))(x, w)
    gvx, gvw = jax.grad(loss("vmap"), argnums=(0, 1))(x, w)
    assert jnp.array_equal(gfx, gvx) and jnp.array_equal(gfw, gvw)
    assert bool(jnp.all(jnp.isfinite(gfx)))
    assert float(jnp.abs(gfw).sum()) > 0
    assert bool(jnp.all(jnp.where(mask[..., None], 0.0, gfx) == 0))


def test_approx_grouped_fallback_and_pin():
    """Non-fusable ACU silently falls back (audited), a pinned route
    raises, and describe() reports the resolved geometry."""
    x, w, counts, _ = _approx_operands(seed=9)
    acu_np = make_acu("mul8s_1L2H", AcuMode.LUT)     # no pallas -> no fuse
    y_np = approx_grouped_dense(x, w, ApproxConfig(acu=acu_np), counts)
    assert jnp.array_equal(y_np, approx_grouped_dense(x, w, CFG_A, counts))

    spec = GroupedSpec(n_experts=4, cap=24, d_in=33, d_out=14, n_blocks=2)
    plan = grouped_plan(ACU, spec)
    d = plan.describe()
    assert d["route"] == "fused_grouped"
    assert (d["experts"], d["cap"], d["n_blocks"]) == (4, 24, 2)
    fb = grouped_plan(acu_np, spec)
    assert fb.route == "vmap" and fb.report
    with pytest.raises(ValueError, match="fused_grouped route unavailable"):
        grouped_plan(acu_np, spec, route="fused_grouped")


def test_approx_grouped_rejects_fake_quant_only():
    x, w, counts, _ = _approx_operands(seed=1)
    cfg = ApproxConfig(acu=ACU, fake_quant_only=True)
    with pytest.raises(ValueError, match="fake-quant"):
        approx_grouped_dense(x, w, cfg, counts)


# ---------------------------------------------------------------------------
# MoE layer wiring
# ---------------------------------------------------------------------------

CFG_MOE = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=64,
                      pattern=("attn_moe",), n_experts=4, moe_top_k=2,
                      moe_capacity=8.0, dtype="float32")


def _moe_params(cfg):
    return jax.tree.map(lambda a: a[0], _init_moe(KEY, cfg, 1))


def test_moe_block_grouped_vs_exact_lut():
    """With an exact-multiplier LUT ACU the grouped approx MoE matches the
    float MoE within quantization error — the full dispatch -> grouped
    GEMM -> combine path is wired correctly."""
    p = _moe_params(CFG_MOE)
    x = jax.random.normal(KEY, (2, 8, 32)) * 0.1
    acfg = ApproxConfig(
        acu=make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=True))
    out = moe_block(x, p, CFG_MOE, acfg)
    ref = moe_block(x, p, CFG_MOE, None)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0.1, atol=0.05)


def test_moe_block_grouped_grads():
    p = _moe_params(CFG_MOE)
    x = jax.random.normal(KEY, (2, 8, 32))

    def loss(p):
        return (moe_block(x, p, CFG_MOE, CFG_A) ** 2).sum()

    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["w_gate"]).max()) > 0


def test_moe_nonpow2_tokens_block_fallback():
    """t=24 does not divide the default 16 dispatch blocks: the pow-2
    fallback resolves nb=8, the geometry helper reports it, and the plan's
    describe() carries the resolved block count end to end."""
    geo = dispatch_geometry(CFG_MOE, 24)
    assert geo["n_blocks"] == 8 and geo["tokens_per_block"] == 3
    spec = GroupedSpec(n_experts=CFG_MOE.n_experts, cap=geo["capacity"],
                       d_in=32, d_out=16, n_blocks=geo["n_blocks"])
    assert grouped_plan(ACU, spec).describe()["n_blocks"] == 8
    # and the layer actually runs at that shape through the grouped path
    p = _moe_params(CFG_MOE)
    x = jax.random.normal(KEY, (2, 12, 32))        # t = 24
    out = moe_block(x, p, CFG_MOE, CFG_A)
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_aux_loss_reuses_routing_bitwise():
    """moe_block's aux_loss stat == the standalone router_aux_loss == the
    pre-refactor standalone formula, bitwise."""
    p = _moe_params(CFG_MOE)
    x = jax.random.normal(KEY, (2, 8, 32))
    _, stats = moe_block(x, p, CFG_MOE, None, return_stats=True)

    # the old standalone implementation, verbatim
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ \
        p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, top_e = jax.lax.top_k(probs, CFG_MOE.moe_top_k)
    frac_tokens = jax.nn.one_hot(top_e, CFG_MOE.n_experts).mean(axis=(0, 1))
    old = CFG_MOE.n_experts * jnp.sum(frac_tokens * probs.mean(0))

    new = router_aux_loss(x, p["router"], CFG_MOE.n_experts,
                          CFG_MOE.moe_top_k)
    assert jnp.array_equal(new, old)
    assert jnp.array_equal(stats["aux_loss"], old)


def test_dropped_frac_pinned_at_low_capacity():
    """moe_capacity=0.25 forces drops; dropped_frac matches an independent
    first-come-first-served replay of the routing decisions."""
    cfg = dataclasses.replace(CFG_MOE, moe_capacity=0.25)
    p = _moe_params(cfg)
    x = jax.random.normal(KEY, (2, 12, 32))    # t=24 -> nb=8, 3 tokens/block
    out, stats = moe_block(x, p, cfg, None, return_stats=True)
    assert bool(jnp.isfinite(out).all())

    # independent replay: greedy in-order slot grab per (block, expert)
    b, s, d = x.shape
    t = b * s
    geo = dispatch_geometry(cfg, t)
    nb, tb, cap = geo["n_blocks"], geo["tokens_per_block"], geo["capacity"]
    xf = np.asarray(x.reshape(t, d), np.float32)
    logits = xf @ np.asarray(p["router"], np.float32)
    top_e = np.asarray(jax.lax.top_k(jnp.asarray(logits),
                                     cfg.moe_top_k)[1])
    flat = top_e.reshape(nb, tb * cfg.moe_top_k)
    dropped = 0
    for blk in range(nb):
        used = np.zeros(cfg.n_experts, np.int64)
        for e in flat[blk]:
            if used[e] >= cap:
                dropped += 1
            used[e] += 1
    expect = dropped / (t * cfg.moe_top_k)
    assert dropped > 0                      # the capacity really binds
    assert float(stats["dropped_frac"]) == pytest.approx(expect, abs=1e-7)


def test_dropped_frac_zero_with_ample_capacity():
    p = _moe_params(CFG_MOE)                # moe_capacity = 8.0
    x = jax.random.normal(KEY, (2, 8, 32))
    _, stats = moe_block(x, p, CFG_MOE, None, return_stats=True)
    assert float(stats["dropped_frac"]) == 0.0


# ---------------------------------------------------------------------------
# partition resolver (no real mesh needed — planner unit tests)
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        return int(np.prod(list(self.shape.values())))


def _ctx(shape, rules=None):
    from repro.parallel.sharding import DEFAULT_RULES, MeshContext
    return MeshContext(mesh=FakeMesh(shape),
                       rules=dict(DEFAULT_RULES, **(rules or {})))


def test_grouped_partition_defaults():
    from repro.parallel import planner
    part, report = planner.acu_grouped_partition(
        _ctx({"data": 2, "model": 4}), n_experts=40, n_blocks=2)
    assert (part.rows, part.cols, part.k) == (("data",), ("model",), ())
    assert (part.n_rows, part.n_cols, part.n_k) == (2, 4, 1)
    assert not report


def test_grouped_partition_nondividing_experts_drop():
    from repro.parallel import planner
    part, report = planner.acu_grouped_partition(
        _ctx({"data": 2, "model": 4}), n_experts=6, n_blocks=2)
    assert part.cols == () and part.n_cols == 1
    assert any("whole experts" in r for r in report)
    assert part.report == tuple(report)


def test_grouped_partition_k_claims_axis():
    from repro.parallel import planner
    part, report = planner.acu_grouped_partition(
        _ctx({"data": 2, "model": 4},
             {"acu_grouped_k": ("model",)}),
        n_experts=4, n_blocks=2)
    assert part.k == ("model",) and part.cols == ()
    assert any("contraction" in r for r in report)


def test_grouped_partition_nondividing_blocks_drop():
    from repro.parallel import planner
    part, report = planner.acu_grouped_partition(
        _ctx({"data": 4, "model": 2}), n_experts=4, n_blocks=3)
    assert part.rows == () and part.n_rows == 1
    assert any("blocks" in r for r in report)


# ---------------------------------------------------------------------------
# 2x4 (data, model) mesh: expert parallelism — needs 8 host devices
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_multi_mesh
    return make_host_multi_mesh((2, 4))


@needs_mesh
def test_grouped_mesh_expert_parallel_bitwise(mesh):
    """Experts shard over model, dispatch blocks over data; the sharded
    grouped plan equals the single-device one bitwise, eager and jitted."""
    from repro.parallel.sharding import use_mesh
    x, w, counts, _ = _approx_operands(seed=13)
    ref = approx_grouped_dense(x, w, CFG_A, counts)
    with use_mesh(mesh):
        plan = grouped_plan(ACU, GroupedSpec(
            n_experts=4, cap=24, d_in=33, d_out=14, n_blocks=2))
        assert plan.partition is not None
        assert plan.describe()["partition"].startswith("blocks('data',)")
        out = approx_grouped_dense(x, w, CFG_A, counts)
        out_j = jax.jit(lambda x, w, c: approx_grouped_dense(
            x, w, CFG_A, c))(x, w, counts)
    assert jnp.array_equal(out, ref)
    assert jnp.array_equal(out_j, ref)


@needs_mesh
@pytest.mark.tier2
@pytest.mark.parametrize("case", [
    # (nb, E, C, K, N): divisible experts, nondividing experts,
    # nondividing blocks, K>bk tiling under the mesh
    (2, 4, 24, 33, 14),
    (2, 6, 24, 33, 14),
    (3, 4, 16, 40, 9),
    (2, 8, 16, 300, 9),
], ids=["div", "nondiv_experts", "nondiv_blocks", "ktile"])
def test_grouped_mesh_sweep_bitwise(mesh, case):
    from repro.parallel.sharding import use_mesh
    nb, E, C, K, N = case
    x, w, counts, _ = _grouped_operands(nb * E, E, C, K, N, seed=sum(case))
    ref = approx_grouped_dense(x, w, CFG_A, counts)
    with use_mesh(mesh):
        out = approx_grouped_dense(x, w, CFG_A, counts)
    assert jnp.array_equal(out, ref)


@needs_mesh
@pytest.mark.tier2
def test_grouped_mesh_k_sharded_biased_m00(mesh):
    """Opt-in contraction sharding: int32 partials psum, the global K-pad
    correction lands once (biased M00 would expose double counting), and
    dead rows stay exactly zero after the correction un-zeroes them."""
    from repro.parallel.sharding import use_mesh
    x, w, counts, mask = _grouped_operands(8, 4, 24, 33, 14, seed=17)
    acu_b = dataclasses.replace(
        make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=True),
        multiplier=BIASED, lut=build_lut(BIASED))
    cfg_b = ApproxConfig(acu=acu_b)
    ref = approx_grouped_dense(x, w, cfg_b, counts)
    rules = {"acu_grouped_k": ("model",), "acu_grouped_experts": (),
             "acu_grouped_rows": ("data",)}
    with use_mesh(mesh, rules):
        plan = grouped_plan(acu_b, GroupedSpec(
            n_experts=4, cap=24, d_in=33, d_out=14, n_blocks=2))
        assert plan.partition.k == ("model",)
        out = approx_grouped_dense(x, w, cfg_b, counts)
    assert jnp.array_equal(out, ref)
    assert bool(jnp.all(jnp.where(mask[..., None], 0.0, out) == 0))


@needs_mesh
@pytest.mark.tier2
def test_grouped_mesh_ste_grads_bitwise(mesh):
    from repro.parallel.sharding import use_mesh
    x, w, counts, _ = _approx_operands(seed=19)
    N = w.shape[2]

    def loss(x, w):
        return (approx_grouped_dense(x, w, CFG_A, counts)
                * jnp.arange(N)).sum()

    gx_r, gw_r = jax.grad(loss, argnums=(0, 1))(x, w)
    from repro.parallel.sharding import use_mesh
    with use_mesh(mesh):
        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert jnp.array_equal(gx, gx_r)
    assert jnp.array_equal(gw, gw_r)


def test_build_step_meta_surfaces_moe_dispatch():
    """build_step records the resolved dispatch geometry for MoE configs so
    the dry-run can report it per cell (non-MoE configs get no entry)."""
    from repro.configs.shapes import ShapeSpec
    from repro.launch.mesh import compat_make_mesh
    from repro.launch.specs import build_step
    mesh = compat_make_mesh((1,), ("data",))
    shape = ShapeSpec("tiny", 8, 2, "train")
    bundle = build_step(CFG_MOE, shape, mesh)
    geo = bundle.meta["moe_dispatch"]
    assert geo["n_experts"] == CFG_MOE.n_experts
    assert geo["n_blocks"] >= 1 and geo["capacity"] >= 1
    assert (geo["n_blocks"] * geo["tokens_per_block"]
            == shape.global_batch * shape.seq_len)

    dense = dataclasses.replace(CFG_MOE, name="d", family="llama",
                                pattern=("attn_mlp",), n_experts=0,
                                moe_top_k=0)
    assert "moe_dispatch" not in build_step(dense, shape, mesh).meta
