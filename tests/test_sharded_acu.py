"""Sharded <-> single-device bit-exactness for every ``matmul_plan`` route.

These run on a 2x4 host-platform ``(data, model)`` mesh and need
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exported before jax
initializes (the multi-device CI job does exactly that); with fewer devices
the whole module skips.

"Bit-exact" is literal equality — ``jnp.array_equal`` on the int32
accumulators and on the dequantized float outputs — including K-pad branches
and M/N that do not divide the mesh axes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, make_acu, matmul_plan
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig, approx_dense, approx_matmul, conv2d
from repro.core.multipliers import make_exact
from repro.core.quantization import symmetric_qparams
from repro.parallel.sharding import use_mesh

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_multi_mesh
    return make_host_multi_mesh((2, 4))


def _int_operands(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-120, 120, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-120, 120, (K, N)), jnp.int32)
    return a, w


ALL_MODE_ACUS = [
    ("lut_jnp", lambda: make_acu("mul8s_1L2H", AcuMode.LUT)),
    ("lut_pallas", lambda: make_acu("mul8s_1L2H", AcuMode.LUT,
                                    use_pallas=True)),
    ("functional", lambda: make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL)),
    ("factored", lambda: make_acu("mul8s_trunc2", AcuMode.FACTORED)),
    ("lowrank", lambda: make_acu("mul8s_1L2H", AcuMode.LOWRANK)),
    ("exact", lambda: make_acu("mul8s_exact", AcuMode.EXACT)),
]


@pytest.mark.parametrize("name,mk", ALL_MODE_ACUS, ids=[n for n, _ in ALL_MODE_ACUS])
@pytest.mark.parametrize("shape", [(32, 64, 16), (36, 70, 21)])
def test_unfused_modes_bit_exact(mesh, name, mk, shape):
    """Every AcuMode, divisible and non-divisible M/N: the sharded plan's
    accumulator equals the single-device one element-for-element."""
    acu = mk()
    a, w = _int_operands(*shape, seed=sum(shape))
    ref = matmul_plan(acu, mesh=False)(a, w)
    with use_mesh(mesh):
        plan = matmul_plan(acu)
        assert plan.partition is not None and plan.partition.total == 8
        out = jax.jit(plan.fn)(a, w)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("shape", [(32, 128, 16), (33, 70, 21), (1, 257, 3)])
def test_fused_sharded_bit_exact(mesh, shape):
    """Fused quantize->LUT-GEMM->dequant under the mesh, incl. in-kernel
    K-pad branches and odd M/N that don't divide the mesh."""
    M, K, N = shape
    rng = np.random.default_rng(K)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
    cfg = ApproxConfig(acu=acu)
    ref = approx_matmul(x, w, cfg, xqp, wqp)
    with use_mesh(mesh):
        out = approx_matmul(x, w, cfg, xqp, wqp)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_jit_regime_parity(mesh, fused):
    """Compiled parity: jit(approx_dense) under the mesh equals the flat
    single-device jit bitwise, fused and unfused, with the activation
    qparams computed *inside* the program (the pinned-rounding guarantee
    from core/quantization.pin_rounding — see docs/sharding.md)."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4, 37, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 48)), jnp.float32)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    cfg = ApproxConfig(acu=acu, fused=fused)
    ref = jax.jit(lambda x: approx_dense(x, w, None, cfg))(x)
    with use_mesh(mesh):
        out = jax.jit(lambda x: approx_dense(x, w, None, cfg))(x)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_contracting_shard_kpad_once(mesh, fused):
    """K sharded over model (``acu_k`` rule): partial int32 accumulators
    psum, and the K shard-padding correction lands exactly once globally.
    Uses a biased multiplier (M[0, 0] = 7) so a per-shard correction — or a
    missing one — would show up as an integer offset."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = build_lut(biased)
    acu = dataclasses.replace(
        make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=fused),
        multiplier=biased, lut=lut)
    assert acu.m00() == 7
    rules = {"acu_k": ("model",), "acu_cols": ()}
    M, K, N = 12, 70, 9          # K=70: pads to 72 across 4 shards
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = ApproxConfig(acu=acu, fused=fused)
    ref = approx_dense(x, w, None, cfg)
    with use_mesh(mesh, rules):
        plan = matmul_plan(acu, fused=fused)
        assert plan.partition.k == ("model",)
        out = approx_dense(x, w, None, cfg)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_ste_backward_bitwise(mesh, fused):
    """QAT: sharded STE gradients (for activations AND weights) are bitwise
    identical to single-device ones, fused and unfused."""
    M, K, N = 18, 40, 11
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    cfg = ApproxConfig(acu=acu, fused=fused)

    def loss(x, w):
        return (approx_matmul(x, w, cfg, xqp, wqp) ** 2).sum()

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w)
    with use_mesh(mesh):
        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)


def test_grouped_conv_sharded(mesh):
    """The vmapped grouped-conv GEMM also runs under the mesh, matching the
    single-device result bitwise."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, 6, 6)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 4, 3, 3)), jnp.float32)
    cfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))
    ref = conv2d(x, w, groups=2, cfg=cfg)
    with use_mesh(mesh):
        out = conv2d(x, w, groups=2, cfg=cfg)
    assert jnp.array_equal(out, ref)


def test_serve_engine_mesh_parity(mesh):
    """ServeEngine(mesh=...) decodes the same tokens as the replicated
    engine — sharded plans change where tiles run, not what they compute."""
    from repro.configs import reduced_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 17, 3], np.int32)
    ref = ServeEngine(params, cfg, slots=2, max_seq=32).run(
        [Request(prompt=prompt, max_new_tokens=4)])
    out = ServeEngine(params, cfg, slots=2, max_seq=32, mesh=mesh).run(
        [Request(prompt=prompt, max_new_tokens=4)])
    assert list(out[0].out) == list(ref[0].out)


def test_acu_matmul_mesh_aware(mesh):
    """Acu.matmul itself resolves against the active mesh."""
    acu = make_acu("mul8s_1L2H", AcuMode.LUT)
    a, w = _int_operands(10, 30, 6, seed=1)
    ref = acu.matmul(a, w)
    with use_mesh(mesh):
        out = acu.matmul(a, w)
    assert jnp.array_equal(out, ref)
