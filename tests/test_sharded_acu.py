"""Sharded <-> single-device bit-exactness for every ``matmul_plan`` route.

These run on a 2x4 host-platform ``(data, model)`` mesh and need
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exported before jax
initializes (the multi-device CI job does exactly that); with fewer devices
the whole module skips.

"Bit-exact" is literal equality — ``jnp.array_equal`` on the int32
accumulators and on the dequantized float outputs — including K-pad branches
and M/N that do not divide the mesh axes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_lut, make_acu, matmul_plan
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig, approx_dense, approx_matmul, conv2d
from repro.core.multipliers import make_exact
from repro.core.quantization import symmetric_qparams
from repro.parallel.sharding import use_mesh

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_multi_mesh
    return make_host_multi_mesh((2, 4))


def _int_operands(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-120, 120, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-120, 120, (K, N)), jnp.int32)
    return a, w


ALL_MODE_ACUS = [
    ("lut_jnp", lambda: make_acu("mul8s_1L2H", AcuMode.LUT)),
    ("lut_pallas", lambda: make_acu("mul8s_1L2H", AcuMode.LUT,
                                    use_pallas=True)),
    ("functional", lambda: make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL)),
    ("factored", lambda: make_acu("mul8s_trunc2", AcuMode.FACTORED)),
    ("lowrank", lambda: make_acu("mul8s_1L2H", AcuMode.LOWRANK)),
    ("exact", lambda: make_acu("mul8s_exact", AcuMode.EXACT)),
]


@pytest.mark.parametrize("name,mk", ALL_MODE_ACUS, ids=[n for n, _ in ALL_MODE_ACUS])
@pytest.mark.parametrize("shape", [(32, 64, 16), (36, 70, 21)])
def test_unfused_modes_bit_exact(mesh, name, mk, shape):
    """Every AcuMode, divisible and non-divisible M/N: the sharded plan's
    accumulator equals the single-device one element-for-element."""
    acu = mk()
    a, w = _int_operands(*shape, seed=sum(shape))
    ref = matmul_plan(acu, mesh=False)(a, w)
    with use_mesh(mesh):
        plan = matmul_plan(acu)
        assert plan.partition is not None and plan.partition.total == 8
        out = jax.jit(plan.fn)(a, w)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("shape", [(32, 128, 16), (33, 70, 21), (1, 257, 3)])
def test_fused_sharded_bit_exact(mesh, shape):
    """Fused quantize->LUT-GEMM->dequant under the mesh, incl. in-kernel
    K-pad branches and odd M/N that don't divide the mesh."""
    M, K, N = shape
    rng = np.random.default_rng(K)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
    cfg = ApproxConfig(acu=acu)
    ref = approx_matmul(x, w, cfg, xqp, wqp)
    with use_mesh(mesh):
        out = approx_matmul(x, w, cfg, xqp, wqp)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_jit_regime_parity(mesh, fused):
    """Compiled parity: jit(approx_dense) under the mesh equals the flat
    single-device jit bitwise, fused and unfused, with the activation
    qparams computed *inside* the program (the pinned-rounding guarantee
    from core/quantization.pin_rounding — see docs/sharding.md)."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4, 37, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 48)), jnp.float32)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    cfg = ApproxConfig(acu=acu, fused=fused)
    ref = jax.jit(lambda x: approx_dense(x, w, None, cfg))(x)
    with use_mesh(mesh):
        out = jax.jit(lambda x: approx_dense(x, w, None, cfg))(x)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_contracting_shard_kpad_once(mesh, fused):
    """K sharded over model (``acu_k`` rule): partial int32 accumulators
    psum, and the K shard-padding correction lands exactly once globally.
    Uses a biased multiplier (M[0, 0] = 7) so a per-shard correction — or a
    missing one — would show up as an integer offset."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = build_lut(biased)
    acu = dataclasses.replace(
        make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=fused),
        multiplier=biased, lut=lut)
    assert acu.m00() == 7
    rules = {"acu_k": ("model",), "acu_cols": ()}
    M, K, N = 12, 70, 9          # K=70: pads to 72 across 4 shards
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    cfg = ApproxConfig(acu=acu, fused=fused)
    ref = approx_dense(x, w, None, cfg)
    with use_mesh(mesh, rules):
        plan = matmul_plan(acu, fused=fused)
        assert plan.partition.k == ("model",)
        out = approx_dense(x, w, None, cfg)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("fused", [False, True])
def test_ste_backward_bitwise(mesh, fused):
    """QAT: sharded STE gradients (for activations AND weights) are bitwise
    identical to single-device ones, fused and unfused."""
    M, K, N = 18, 40, 11
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    cfg = ApproxConfig(acu=acu, fused=fused)

    def loss(x, w):
        return (approx_matmul(x, w, cfg, xqp, wqp) ** 2).sum()

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w)
    with use_mesh(mesh):
        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)


def test_grouped_conv_sharded(mesh):
    """The vmapped grouped-conv GEMM also runs under the mesh, matching the
    single-device result bitwise."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, 6, 6)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 4, 3, 3)), jnp.float32)
    cfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))
    ref = conv2d(x, w, groups=2, cfg=cfg)
    with use_mesh(mesh):
        out = conv2d(x, w, groups=2, cfg=cfg)
    assert jnp.array_equal(out, ref)


def test_serve_engine_mesh_parity(mesh):
    """ServeEngine(mesh=...) decodes the same tokens as the replicated
    engine — sharded plans change where tiles run, not what they compute."""
    from repro.configs import reduced_config
    from repro.models.transformer import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray([5, 17, 3], np.int32)
    ref = ServeEngine(params, cfg, slots=2, max_seq=32).run(
        [Request(prompt=prompt, max_new_tokens=4)])
    out = ServeEngine(params, cfg, slots=2, max_seq=32, mesh=mesh).run(
        [Request(prompt=prompt, max_new_tokens=4)])
    assert list(out[0].out) == list(ref[0].out)


def test_acu_matmul_mesh_aware(mesh):
    """Acu.matmul itself resolves against the active mesh."""
    acu = make_acu("mul8s_1L2H", AcuMode.LUT)
    a, w = _int_operands(10, 30, 6, seed=1)
    ref = acu.matmul(a, w)
    with use_mesh(mesh):
        out = acu.matmul(a, w)
    assert jnp.array_equal(out, ref)


# ---------------------------------------------------------------------------
# conv_plan routes (the acu_conv partition rule)
# ---------------------------------------------------------------------------

FUSED_CONV_ACU = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                          fused=True)


@pytest.mark.parametrize("geom", [
    ((3, 5, 9, 9), (9, 5, 3, 3), dict()),                       # odd N, Cout
    ((2, 8, 10, 10), (8, 8, 3, 3), dict(stride=(2, 2))),
    ((4, 6, 7, 7), (12, 6, 3, 3), dict(dilation=(2, 2))),
])
def test_fused_conv_sharded_bit_exact(mesh, geom):
    """The patch-streaming fused conv under the mesh (batch over data,
    output channels over model, LUT replicated) equals the single-device
    result bitwise — incl. batch/Cout that don't divide the axes, eager
    (with bias) and jit (without: the SPMD partitioner can FMA-contract the
    bias add by 1 ulp — the same documented caveat as the dense layer,
    docs/sharding.md; the GEMM+dequant itself is always bitwise)."""
    shape, wshape, kw_ = geom
    rng = np.random.default_rng(sum(shape))
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    w = jnp.asarray(rng.normal(size=wshape), jnp.float32)
    b = jnp.asarray(rng.normal(size=(wshape[0],)), jnp.float32)
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    ref = conv2d(x, w, b, cfg=cfg, **kw_)
    ref_j = jax.jit(lambda x, w: conv2d(x, w, None, cfg=cfg, **kw_))(x, w)
    with use_mesh(mesh):
        from repro.core.acu import ConvSpec, conv_plan, resolve_conv_padding
        pad = resolve_conv_padding(kw_.get("padding", "SAME"), shape, wshape,
                                   kw_.get("stride", (1, 1)),
                                   kw_.get("dilation", (1, 1)))
        plan = conv_plan(FUSED_CONV_ACU, ConvSpec(
            x_shape=shape, w_shape=wshape, padding=pad,
            stride=kw_.get("stride", (1, 1)),
            dilation=kw_.get("dilation", (1, 1))))
        assert plan.route == "fused_conv"
        assert plan.partition is not None and plan.partition.total == 8
        out = conv2d(x, w, b, cfg=cfg, **kw_)
        out_j = jax.jit(lambda x, w: conv2d(x, w, None, cfg=cfg, **kw_))(x, w)
    assert jnp.array_equal(out, ref)
    assert jnp.array_equal(out_j, ref_j)


def test_fused_conv_channel_contraction_kpad_once(mesh):
    """Input channels sharded over model (``acu_conv_k`` rule): partial
    int32 accumulators psum, and the channel-shard-padding correction lands
    exactly once globally. Biased multiplier (M[0, 0] = 7) so a per-shard —
    or missing — correction shows up as an integer offset."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = build_lut(biased)
    acu = dataclasses.replace(
        make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=True),
        multiplier=biased, lut=lut)
    assert acu.m00() == 7
    cfg = ApproxConfig(acu=acu)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(2, 6, 7, 7)), jnp.float32)  # C=6 -> pad 2
    w = jnp.asarray(rng.normal(size=(5, 6, 3, 3)), jnp.float32)
    ref = conv2d(x, w, None, cfg=cfg)
    rules = {"acu_conv_k": ("model",), "acu_conv_cols": ()}
    with use_mesh(mesh, rules):
        from repro.core.acu import ConvSpec, conv_plan
        plan = conv_plan(acu, ConvSpec(
            x_shape=(2, 6, 7, 7), w_shape=(5, 6, 3, 3),
            padding=((1, 1), (1, 1))))
        assert plan.partition.k == ("model",)
        out = conv2d(x, w, None, cfg=cfg)
    assert jnp.array_equal(out, ref)


def test_fused_conv_ste_backward_bitwise(mesh):
    """Sharded QAT conv gradients (activations AND weights) are bitwise
    identical to single-device ones."""
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 3, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(5, 3, 3, 3)), jnp.float32)

    def loss(x, w):
        return (conv2d(x, w, None, cfg=cfg) ** 2).sum()

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w)
    with use_mesh(mesh):
        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)


# ---------------------------------------------------------------------------
# spatially-tiled conv under the mesh (PR 4): batch x band over
# ("pod", "data"), cols over ("model",), opt-in acu_conv_k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("geom", [
    ((1, 8, 17, 13), (9, 8, 3, 3), dict()),          # batch 1 -> 2-way bands
    ((1, 6, 11, 9), (5, 6, 3, 3), dict(stride=(2, 2))),
    ((1, 5, 14, 8), (7, 5, 3, 3), dict(dilation=(2, 2))),
    ((2, 8, 10, 10), (8, 8, 3, 3), dict()),          # batch fills rows axes
])
def test_tiled_conv_sharded_bit_exact(mesh, geom):
    """The spatially-tiled kernel under the mesh: batch x output-row bands
    over the ``acu_conv_rows`` axes (a single image splits into halo'd
    bands so the spare rows-axis devices compute spatial bands instead of
    padding), output channels over ``acu_conv_cols`` — bitwise identical to
    the single-device tiled route, eager and jit."""
    shape, wshape, kw_ = geom
    rng = np.random.default_rng(sum(shape))
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    w = jnp.asarray(rng.normal(size=wshape), jnp.float32)
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    ref = conv2d(x, w, None, cfg=cfg, route="tiled", **kw_)
    ref_j = jax.jit(lambda x, w: conv2d(x, w, None, cfg=cfg, route="tiled",
                                        **kw_))(x, w)
    with use_mesh(mesh):
        from repro.core.acu import ConvSpec, conv_plan, resolve_conv_padding
        pad = resolve_conv_padding(kw_.get("padding", "SAME"), shape, wshape,
                                   kw_.get("stride", (1, 1)),
                                   kw_.get("dilation", (1, 1)))
        plan = conv_plan(FUSED_CONV_ACU, ConvSpec(
            x_shape=shape, w_shape=wshape, padding=pad,
            stride=kw_.get("stride", (1, 1)),
            dilation=kw_.get("dilation", (1, 1))), route="tiled")
        assert plan.route == "tiled"
        assert plan.partition is not None and plan.partition.total == 8
        out = conv2d(x, w, None, cfg=cfg, route="tiled", **kw_)
        out_j = jax.jit(lambda x, w: conv2d(x, w, None, cfg=cfg,
                                            route="tiled", **kw_))(x, w)
    assert jnp.array_equal(out, ref)
    assert jnp.array_equal(out_j, ref_j)


def test_tiled_conv_channel_contraction_kpad_once(mesh):
    """Tiled route with input channels sharded over model (``acu_conv_k``):
    each shard's tiled kernel emits its int32 partial, partials psum, and
    the channel-shard-padding correction lands exactly once. Biased
    multiplier (M[0, 0] = 7) so a per-shard — or missing — correction shows
    up as an integer offset."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = build_lut(biased)
    acu = dataclasses.replace(
        make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=True),
        multiplier=biased, lut=lut)
    assert acu.m00() == 7
    cfg = ApproxConfig(acu=acu)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(2, 6, 9, 9)), jnp.float32)  # C=6 -> pad 2
    w = jnp.asarray(rng.normal(size=(5, 6, 3, 3)), jnp.float32)
    ref = conv2d(x, w, None, cfg=cfg, route="tiled")
    rules = {"acu_conv_k": ("model",), "acu_conv_cols": ()}
    with use_mesh(mesh, rules):
        from repro.core.acu import ConvSpec, conv_plan
        plan = conv_plan(acu, ConvSpec(
            x_shape=(2, 6, 9, 9), w_shape=(5, 6, 3, 3),
            padding=((1, 1), (1, 1))), route="tiled")
        assert plan.partition.k == ("model",)
        out = conv2d(x, w, None, cfg=cfg, route="tiled")
    assert jnp.array_equal(out, ref)


def test_tiled_conv_banded_ste_backward_bitwise(mesh):
    """Sharded QAT gradients through the banded tiled forward (batch 1:
    forward bands over data, backward GEMMs row/col-sharded) are bitwise
    identical to single-device ones."""
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(1, 5, 12, 10)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(6, 5, 3, 3)), jnp.float32)

    def loss(x, w):
        return (conv2d(x, w, None, cfg=cfg, route="tiled") ** 2).sum()

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w)
    with use_mesh(mesh):
        gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)


@pytest.mark.slow
def test_imagenet_scale_tiled_sharded_bit_exact(mesh):
    """The PR 4 acceptance geometry on the mesh: 1x64x224x224 resolves to
    route="tiled" (band sharding over data: one image, two halo'd 112-row
    bands; cols over model) and is bitwise identical to the single-device
    tiled output — which the single-device slow test pins against the eager
    im2col + fused_lut_dense oracle."""
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    rng = np.random.default_rng(224)
    x = jnp.asarray(rng.normal(size=(1, 64, 224, 224)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 64, 3, 3)), jnp.float32)
    ref = conv2d(x, w, None, cfg=cfg)
    with use_mesh(mesh):
        from repro.core.acu import ConvSpec, conv_plan
        plan = conv_plan(FUSED_CONV_ACU, ConvSpec(
            x_shape=(1, 64, 224, 224), w_shape=(64, 64, 3, 3),
            padding=((1, 1), (1, 1))))
        assert plan.route == "tiled"
        assert plan.partition is not None
        out = conv2d(x, w, None, cfg=cfg)
    assert jnp.array_equal(out, ref)


def test_vision_serve_engine_mesh_parity(mesh):
    """VisionServeEngine(mesh=...) produces the same logits as the
    replicated engine — the conv plans change where tiles run, not what
    they compute."""
    from repro.models.vision import cnn_forward, init_cnn
    from repro.serve.engine import VisionServeEngine

    params = init_cnn(jax.random.PRNGKey(0), width=8)
    cfg = ApproxConfig(acu=FUSED_CONV_ACU)
    imgs = np.random.default_rng(1).normal(size=(6, 3, 32, 32)).astype(
        np.float32)
    ref = VisionServeEngine(params, cnn_forward, slots=4, acfg=cfg).run(imgs)
    out = VisionServeEngine(params, cnn_forward, slots=4, acfg=cfg,
                            mesh=mesh).run(imgs)
    assert np.array_equal(out, ref)
    rep = VisionServeEngine(params, cnn_forward, slots=4, acfg=cfg,
                            mesh=mesh).plan_report(
        (4, 3, 32, 32), (8, 3, 3, 3), cfg)
    assert rep["route"] == "fused_conv"
    assert rep["partition"] is not None
    # ImageNet-scale serving no longer reports the eager-im2col fallback:
    # the plan resolves to the spatially-tiled kernel (PR 4)
    rep224 = VisionServeEngine(params, cnn_forward, slots=4, acfg=cfg,
                               mesh=mesh).plan_report(
        (4, 64, 224, 224), (64, 64, 3, 3), cfg)
    assert rep224["route"] == "tiled"
    assert rep224["tiling"] is not None
    assert rep224["partition"] is not None
    assert not any("falling back" in r for r in rep224["report"])


# ---------------------------------------------------------------------------
# approximate backward (QAT grads through the ACU) under the mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(32, 64, 16), (33, 70, 21)])
@pytest.mark.parametrize("k_sharded", [False, True])
def test_dense_approx_bwd_grads_bit_exact(mesh, shape, k_sharded):
    """cfg.approx_bwd dense STE: sharded grads (fused in-kernel backward,
    int32 psum + exactly-once pad correction) == single-device, bitwise —
    default rules and the contraction-sharded ``acu_k`` rules."""
    M, K, N = shape
    rng = np.random.default_rng(M + K)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                            8, axis=1)
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
    cfg = ApproxConfig(acu=acu, approx_bwd=True)

    def loss(x, w):
        return (approx_matmul(x, w, cfg, xqp, wqp)
                * jnp.arange(N, dtype=jnp.float32)).sum()

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w)
    scope = (use_mesh(mesh, {"acu_k": ("model",), "acu_cols": ()})
             if k_sharded else use_mesh(mesh))
    with scope:
        gx, gw = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, w)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)


@pytest.mark.parametrize("geom", [
    # batch fills the rows axes / band_ways path (n=1) / odd splits
    ((8, 3, 9, 11), (8, 3, 3, 3), (1, 1), "SAME", (1, 1)),
    ((1, 4, 12, 10), (8, 4, 3, 2), (2, 1), "VALID", (1, 2)),
    ((2, 2, 16, 8), (12, 2, 2, 2), (2, 2), "SAME", (1, 1)),
])
def test_conv_approx_bwd_grads_bit_exact(mesh, geom):
    """cfg.approx_bwd conv STE on the 2x4 mesh: the banded weight-grad
    (band-slab shards psum int32 partials over the rows axes) and the
    per-band gx GEMM (contraction over ``cols`` + once-only pad correction)
    reproduce the single-device grads bitwise."""
    x_shape, w_shape, stride, padding, dil = geom
    rng = np.random.default_rng(x_shape[0] + w_shape[0])
    cfg = ApproxConfig(acu=FUSED_CONV_ACU, approx_bwd=True)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal(w_shape), jnp.float32)

    def f(x, w):
        return conv2d(x, w, stride=stride, padding=padding, dilation=dil,
                      cfg=cfg)

    y_ref, vjp = jax.vjp(f, x, w)
    g = jnp.asarray(rng.standard_normal(y_ref.shape), jnp.float32)
    gx_ref, gw_ref = vjp(g)

    with use_mesh(mesh):
        gx, gw = jax.jit(lambda x, w, g: jax.vjp(f, x, w)[1](g))(x, w, g)
    assert jnp.array_equal(gx, gx_ref)
    assert jnp.array_equal(gw, gw_ref)
