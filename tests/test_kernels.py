"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import build_lut, factorize_error, get_multiplier
from repro.kernels.err_matmul.ops import err_matmul
from repro.kernels.err_matmul.ref import err_matmul_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lut_matmul.ops import lut_matmul
from repro.kernels.lut_matmul.ref import lut_matmul_ref
from repro.kernels.quantize.ops import quantize_op
from repro.kernels.quantize.ref import quantize_ref

MULT = get_multiplier("mul8s_1L2H")
LUT = jnp.asarray(build_lut(MULT))
LR = factorize_error(MULT, 8)


@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (130, 70, 50),
                                   (1, 257, 3), (256, 8, 384)])
def test_lut_matmul_shapes(shape):
    M, K, N = shape
    rng = np.random.default_rng(M * K + N)
    a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int32)
    out = lut_matmul(a, w, LUT, 128, interpret=True)
    ref = lut_matmul_ref(a, w, LUT.reshape(-1), 128, 256)
    assert jnp.array_equal(out, ref)


@given(m=st.integers(1, 40), k=st.integers(1, 50), n=st.integers(1, 30))
@settings(max_examples=10)
def test_lut_matmul_hypothesis(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int32)
    out = lut_matmul(a, w, LUT, 128, interpret=True)
    ref = lut_matmul_ref(a, w, LUT.reshape(-1), 128, 256)
    assert jnp.array_equal(out, ref)


@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (130, 70, 50)])
def test_err_matmul_shapes(shape):
    M, K, N = shape
    rng = np.random.default_rng(K)
    a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int32)
    f, g = jnp.asarray(LR.f), jnp.asarray(LR.g)
    out = err_matmul(a, w, f, g, 128, interpret=True)
    ref = err_matmul_ref(a, w, f, g, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 64, None), (True, None, 30.0),
    (False, None, None)])
def test_flash_attention(dtype, causal, window, softcap):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(4, 256, 32)), dtype)
    k = jnp.asarray(rng.normal(size=(4, 256, 32)), dtype)
    v = jnp.asarray(rng.normal(size=(4, 256, 32)), dtype)
    out = flash_attention(q[:, None].transpose(0, 1, 2, 3).reshape(1, 4, 256, 32),
                          k.reshape(1, 4, 256, 32), v.reshape(1, 4, 256, 32),
                          causal=causal, window=window, softcap=softcap,
                          bq=128, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out.reshape(4, 256, 32), np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_gqa():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 8, 128, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 128, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 128, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    kk = jnp.repeat(k, 4, 1).reshape(16, 128, 16)
    vv = jnp.repeat(v, 4, 1).reshape(16, 128, 16)
    ref = attention_ref(q.reshape(16, 128, 16), kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(out).reshape(16, 128, 16),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


@given(n=st.integers(1, 5000), bits=st.sampled_from([4, 8]))
@settings(max_examples=10)
def test_quantize_kernel(n, bits):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)) * 3, jnp.float32)
    out = quantize_op(x, 0.05, 2.0, bits=bits, interpret=True)
    ref = quantize_ref(x, 0.05, 2.0, bits=bits)
    assert jnp.array_equal(out, ref)


def test_quantize_kernel_2d():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(33, 77)), jnp.float32)
    assert jnp.array_equal(quantize_op(x, 0.02, -1.0, bits=8),
                           quantize_ref(x, 0.02, -1.0, bits=8))
