"""Approximate flash attention: kernel == unfused oracle, bitwise.

The contract under test (kernels/flash_attention/approx.py): the fused
Pallas kernel — per-tensor quantize of Q/K/V in-kernel, QK^T and PV as int32
LUT-gather GEMMs inside the streaming softmax, pad corrections in integer
space, dequant folded into the running rescale — is bit-identical to
``approx_attention_ref``, the unfused jnp composition driving the same
shared per-KV-block core. Plus the planning layer (core/acu.attn_plan):
route resolution, audited dense fallback, the end-aligned default rowinfo,
and the model-level wiring through ``attention_block``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acu import AttnSpec, attn_plan, make_acu
from repro.core.approx_ops import ApproxConfig, approx_attention
from repro.core.lut import build_lut
from repro.core.multipliers import get_multiplier
from repro.kernels.flash_attention.approx import approx_flash_attention
from repro.kernels.flash_attention.ref import approx_attention_ref

MULT = "mul8s_1L2H"      # biased approximate multiplier: LUT[0, x] != 0 for
                         # some x, so masked-key and pad-correction semantics
                         # are observable, not vacuously zero


def _lut(name=MULT):
    return build_lut(get_multiplier(name))


def _qkv(bh, sq, sk, d, bh_kv=None, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh_kv or bh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh_kv or bh, sk, d)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    return q, k, v, s


CASES = [
    # (sq, sk, d, rep, causal, window, softcap, bq, bk)
    (128, 128, 32, 1, True, None, None, 64, 64),
    (128, 256, 32, 1, False, None, None, 64, 64),     # multi-kv-block
    (128, 128, 32, 4, True, None, None, 64, 64),      # GQA
    (96, 203, 24, 1, True, 17, 30.0, 64, 64),         # odd S + window+softcap
    (1, 131, 32, 2, True, None, None, 64, 64),        # decode step, odd Sk
    (64, 64, 20, 1, True, 9, None, 32, 32),           # odd head dim
]


@pytest.mark.parametrize("sq,sk,d,rep,causal,window,softcap,bq,bk", CASES)
def test_kernel_matches_oracle_bitwise(sq, sk, d, rep, causal, window,
                                       softcap, bq, bk):
    lut = _lut()
    bh_kv = 2
    q, k, v, (qs, ks, vs) = _qkv(bh_kv * rep, sq, sk, d, bh_kv, seed=sq + sk)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=causal,
                                 window=window, softcap=softcap, bq=bq, bk=bk)
    ref = approx_attention_ref(q, k, v, lut, 128, qs, ks, vs, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk)
    assert out.dtype == jnp.float32 and out.shape == (bh_kv * rep, sq, d)
    assert jnp.array_equal(out, ref), float(jnp.max(jnp.abs(out - ref)))


def test_outer_jit_bitwise():
    """Embedding the kernel call in an outer jit (the serving decode step)
    must not perturb a single bit vs the direct call."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(4, 64, 192, 32, 2, seed=7)
    fn = lambda q, k, v, qs, ks, vs: approx_flash_attention(
        q, k, v, lut, 128, qs, ks, vs, causal=True, bq=64, bk=64)
    direct = fn(q, k, v, qs, ks, vs)
    jitted = jax.jit(fn)(q, k, v, qs, ks, vs)
    assert jnp.array_equal(direct, jitted)


def test_gqa_equals_explicit_repeat():
    """Folded-GQA (k/v indexed via b // rep in the BlockSpec) == physically
    repeating K/V to rep=1 — the layout optimization must be invisible."""
    lut = _lut()
    rep = 4
    q, k, v, (qs, ks, vs) = _qkv(2 * rep, 96, 160, 32, 2, seed=11)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                                 bq=64, bk=64)
    kr = jnp.repeat(k, rep, axis=0)
    vr = jnp.repeat(v, rep, axis=0)
    ref = approx_flash_attention(q, kr, vr, lut, 128, qs, ks, vs, causal=True,
                                 bq=64, bk=64)
    assert jnp.array_equal(out, ref)


def test_default_rowinfo_is_end_aligned():
    """rowinfo=None == explicit [sk-sq, 0, sk] rows (decode convention)."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(3, 32, 96, 16, seed=3)
    info = jnp.broadcast_to(jnp.array([64, 0, 96], jnp.int32), (3, 3))
    a = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               bq=32, bk=32)
    b = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(a, b)


def test_heterogeneous_rowinfo_bitwise():
    """Per-row [q_base, kv_start, kv_len] (the continuous-batching serving
    state: every slot at its own cache offset with its own left-pad) — the
    kernel matches the oracle bit for bit."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(3, 1, 96, 16, seed=5)
    info = jnp.array([[95, 13, 96],     # left-padded slot, full cache
                      [40, 0, 41],      # young slot: short written prefix
                      [7, 3, 8]], jnp.int32)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                                 rowinfo=info, bq=32, bk=32)
    ref = approx_attention_ref(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(out, ref)
    # the young slot must not read keys past kv_len: perturbing them there
    # cannot change its row
    k2 = k.at[1, 41:].set(99.0)
    v2 = v.at[1, 41:].set(-99.0)
    out2 = approx_flash_attention(q, k2, v2, lut, 128, qs, ks, vs,
                                  causal=True, rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(out[1], out2[1])


# ---------------------------------------------------------------------------
# planning layer
# ---------------------------------------------------------------------------

def test_attn_plan_routes_and_audits():
    spec = AttnSpec(hq=8, hkv=2)
    fused = attn_plan(make_acu(MULT, use_pallas=True), spec)
    assert fused.route == "fused_attn" and fused.fn is not None
    d = fused.describe()
    assert d["route"] == "fused_attn" and "rep=4" in d["heads"]

    # every way an ACU fails the fused contract resolves to audited "dense"
    for acu in (make_acu(MULT),                          # no pallas
                make_acu(MULT, mode="functional", use_pallas=True),
                make_acu("mul12s_exact", use_pallas=True)):  # >10b: no LUT
        plan = attn_plan(acu, spec)
        assert plan.route == "dense" and plan.fn is None
        assert any("attention stays exact" in r for r in plan.report)
        with pytest.raises(ValueError, match="fused_attn route unavailable"):
            attn_plan(acu, spec, route="fused_attn")

    pinned = attn_plan(make_acu(MULT, use_pallas=True), spec, route="dense")
    assert pinned.route == "dense"
    with pytest.raises(ValueError, match="unknown attn route"):
        attn_plan(make_acu(MULT, use_pallas=True), spec, route="bogus")
    with pytest.raises(ValueError, match="not a multiple"):
        attn_plan(make_acu(MULT, use_pallas=True), AttnSpec(hq=6, hkv=4))


def test_attn_plan_fn_matches_kernel():
    """The plan's (B, H, S, D) fn is exactly the folded kernel call."""
    acu = make_acu(MULT, use_pallas=True)
    plan = attn_plan(acu, AttnSpec(hq=4, hkv=2, bq=32, bk=32))
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 96, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 96, 16)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    out = plan(q, k, v, *s)
    ref = approx_flash_attention(
        q.reshape(8, 32, 16), k.reshape(4, 96, 16), v.reshape(4, 96, 16),
        jnp.asarray(acu.lut), acu.offset, *s, causal=True, bq=32, bk=32)
    assert jnp.array_equal(out, ref.reshape(2, 4, 32, 16))


def test_approx_attention_helper_routes():
    """approx_ops.approx_attention: fused plan -> output, dense -> None."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 4, 16, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 48, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 48, 16)), jnp.float32)
    fused_cfg = ApproxConfig(acu=make_acu(MULT, use_pallas=True))
    out = approx_attention(q, k, v, fused_cfg)
    assert out is not None and out.shape == (1, 4, 16, 16)
    dense_cfg = ApproxConfig(acu=make_acu(MULT))
    assert approx_attention(q, k, v, dense_cfg) is None


def test_decode_vector_cache_pos_matches_scalar():
    """Continuous batching plumbing: a (B,) cache_pos vector with equal
    entries decodes bitwise the same logits as the scalar path, on both the
    exact substrate and the ACU route."""
    from repro.configs import reduced_config
    from repro.models.transformer import apply_model, init_cache, init_params
    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[5, 17, 3, 99], [5, 17, 3, 99]], jnp.int32)
    for acfg in (None, ApproxConfig(acu=make_acu(MULT, use_pallas=True,
                                                 fused=True))):
        cache_s = init_cache(cfg, 2, 32)
        _, cache_s = apply_model(params, toks, cfg, acfg=acfg, cache=cache_s)
        cache_v = jax.tree.map(jnp.copy, cache_s)
        tok = jnp.asarray([[7], [7]], jnp.int32)
        ls, _ = apply_model(params, tok, cfg, acfg=acfg, cache=cache_s,
                            cache_pos=4, decode=True)
        lv, _ = apply_model(params, tok, cfg, acfg=acfg, cache=cache_v,
                            cache_pos=jnp.asarray([4, 4], jnp.int32),
                            decode=True)
        assert jnp.array_equal(ls, lv)


def test_model_decode_rides_acu_route(monkeypatch):
    """attention_block must dispatch decode through approx_attention when
    the plan fuses — and fall back cleanly when it audits to dense."""
    from repro.configs import reduced_config
    from repro.models import layers as L
    from repro.models.transformer import apply_model, init_cache, init_params
    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    calls = {"n": 0}
    real = L.approx_attention

    def counting(*a, **kw):
        out = real(*a, **kw)
        calls["n"] += 1 if out is not None else 0
        return out

    monkeypatch.setattr(L, "approx_attention", counting)
    acfg = ApproxConfig(acu=make_acu(MULT, use_pallas=True, fused=True))
    cache = init_cache(cfg, 1, 16)
    toks = jnp.asarray([[5, 17, 3]], jnp.int32)
    apply_model(params, toks, cfg, acfg=acfg, cache=cache, cache_pos=0)
    assert calls["n"] > 0
    calls["n"] = 0
    dense = ApproxConfig(acu=make_acu(MULT))   # no pallas: dense fallback
    apply_model(params, toks, cfg, acfg=dense, cache=init_cache(cfg, 1, 16),
                cache_pos=0)
    assert calls["n"] == 0


# ---------------------------------------------------------------------------
# sharded == single-device (2x4 host mesh; skips below 8 devices)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
@pytest.mark.parametrize("b,hq,hkv", [(4, 8, 4), (2, 4, 1), (3, 8, 2)])
def test_sharded_attn_bit_exact(b, hq, hkv):
    """Batch over ("data",) rows and KV heads over ("model",): the sharded
    plan output equals the single-device plan bit for bit — including batch
    and head counts that do not divide the mesh axes."""
    from repro.launch.mesh import make_host_multi_mesh
    from repro.parallel.sharding import use_mesh
    mesh = make_host_multi_mesh((2, 4))
    acu = make_acu(MULT, use_pallas=True)
    spec = AttnSpec(hq=hq, hkv=hkv, bq=32, bk=32)
    rng = np.random.default_rng(b + hq)
    q = jnp.asarray(rng.normal(size=(b, hq, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, 96, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, 96, 16)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    ref = attn_plan(acu, spec, mesh=False)(q, k, v, *s)
    with use_mesh(mesh):
        plan = attn_plan(acu, spec)
        out = plan(q, k, v, *s)
    assert jnp.array_equal(out, ref)


# ---------------------------------------------------------------------------
# paged KV: block-pool gather == contiguous layout, bitwise
# ---------------------------------------------------------------------------

from repro.kernels.flash_attention.approx import approx_flash_attention_paged
from repro.kernels.flash_attention.ref import approx_attention_paged_ref


def _paged_setup(b, hkv, rep, sq, d, kv_lens, bk, seed=0):
    """Contiguous per-row K/V plus the same values scattered into a shared
    physical block pool through a shuffled per-row page table. Block 0 is
    left unreferenced (the engine's null block)."""
    rng = np.random.default_rng(seed)
    hq = hkv * rep
    n_logical = max(-(-kl // bk) for kl in kv_lens)
    sk = n_logical * bk
    q, k, v, s = _qkv(b * hq, sq, sk, d, b * hkv, seed=seed + 1)
    n_phys = 1 + b * n_logical
    phys = 1 + rng.permutation(b * n_logical).reshape(b, n_logical)
    kp = np.zeros((hkv, n_phys, bk, d), np.float32)
    vp = np.zeros((hkv, n_phys, bk, d), np.float32)
    for bi in range(b):
        for h in range(hkv):
            for j in range(n_logical):
                kp[h, phys[bi, j]] = k[bi * hkv + h, j * bk:(j + 1) * bk]
                vp[h, phys[bi, j]] = v[bi * hkv + h, j * bk:(j + 1) * bk]
    info = np.stack([np.repeat([kl - sq for kl in kv_lens], hq),
                     np.zeros(b * hq, np.int64),
                     np.repeat(kv_lens, hq)], axis=1).astype(np.int32)
    pt = np.repeat(phys, hq, axis=0).astype(np.int32)
    return (q, k, v, s, jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(info), jnp.asarray(pt))


PAGED_CASES = [
    # (b, hkv, rep, sq, d, kv_lens, causal, window, softcap, bq, bk)
    (2, 2, 1, 1, 32, (48, 33), True, None, None, 32, 16),   # decode, partial
    (1, 2, 2, 64, 32, (64,), True, None, None, 32, 32),     # prefill + GQA
    (2, 1, 4, 1, 24, (17, 40), True, 9, 20.0, 32, 8),       # window+softcap
    (3, 2, 2, 8, 16, (64, 23, 8), True, None, None, 32, 16),  # chunk rows
    (2, 2, 2, 1, 32, (31, 64), False, None, None, 32, 16),  # non-causal
]


@pytest.mark.parametrize("b,hkv,rep,sq,d,kv_lens,causal,window,softcap,bq,bk",
                         PAGED_CASES)
def test_paged_matches_contiguous_and_oracle_bitwise(
        b, hkv, rep, sq, d, kv_lens, causal, window, softcap, bq, bk):
    """The tentpole contract: reading KV through a per-row page table over a
    shared block pool is invisible — the paged kernel equals its unfused jnp
    oracle AND the contiguous kernel on the gathered values, bit for bit,
    across GQA, windows, partially-filled tail blocks and per-row extents."""
    lut = _lut()
    q, k, v, (qs, ks, vs), kp, vp, info, pt = _paged_setup(
        b, hkv, rep, sq, d, kv_lens, bk, seed=sq + bk)
    kw = dict(causal=causal, window=window, softcap=softcap)
    out = approx_flash_attention_paged(q, kp, vp, lut, 128, qs, ks, vs,
                                       rowinfo=info, page_table=pt, rep=rep,
                                       bq=bq, **kw)
    ref = approx_attention_paged_ref(q, kp, vp, lut, 128, qs, ks, vs,
                                     rowinfo=info, page_table=pt, rep=rep,
                                     bq=bq, **kw)
    cont = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs,
                                  rowinfo=info, bq=bq, bk=bk, **kw)
    assert out.shape == (b * hkv * rep, sq, d)
    assert jnp.array_equal(out, ref), float(jnp.max(jnp.abs(out - ref)))
    assert jnp.array_equal(out, cont), float(jnp.max(jnp.abs(out - cont)))


def test_paged_outer_jit_bitwise():
    """Embedding the paged kernel call in an outer jit (the paged engine's
    decode step) must not perturb a single bit vs the direct call."""
    lut = _lut()
    b, hkv, rep, sq, d, bk = 2, 2, 2, 1, 32, 16
    q, _, _, (qs, ks, vs), kp, vp, info, pt = _paged_setup(
        b, hkv, rep, sq, d, (48, 33), bk, seed=21)
    fn = lambda q, kp, vp, qs, ks, vs, info, pt: approx_flash_attention_paged(
        q, kp, vp, lut, 128, qs, ks, vs, rowinfo=info, page_table=pt,
        rep=rep, bq=32)
    direct = fn(q, kp, vp, qs, ks, vs, info, pt)
    jitted = jax.jit(fn)(q, kp, vp, qs, ks, vs, info, pt)
    assert jnp.array_equal(direct, jitted)


def test_paged_unreferenced_blocks_are_dead():
    """Physical blocks no page table row points at (the null block) and pool
    content past a row's kv_len must be unreachable: perturbing them cannot
    change a single bit of the output."""
    lut = _lut()
    b, hkv, rep, sq, d, bk = 2, 2, 2, 1, 32, 16
    q, _, _, (qs, ks, vs), kp, vp, info, pt = _paged_setup(
        b, hkv, rep, sq, d, (33, 48), bk, seed=9)
    kw = dict(rowinfo=info, page_table=pt, rep=rep, bq=32)
    out = approx_flash_attention_paged(q, kp, vp, lut, 128, qs, ks, vs, **kw)
    # null block (never referenced) + masked tail of row 0's last block
    # (kv_len=33 -> only position 0 of logical block 2 is live)
    tail_phys = int(pt[0, 2])
    kp2 = kp.at[:, 0].set(99.0).at[:, tail_phys, 1:].set(-77.0)
    vp2 = vp.at[:, 0].set(-99.0).at[:, tail_phys, 1:].set(77.0)
    out2 = approx_flash_attention_paged(q, kp2, vp2, lut, 128, qs, ks, vs,
                                        **kw)
    assert jnp.array_equal(out[:hkv * rep], out2[:hkv * rep])


def test_attn_plan_paged_routes_and_audits():
    """kv_layout is a planning axis: paged specs route to fused_attn_paged,
    audit to dense with a gather note when the ACU can't fuse, and honor /
    reject route pins exactly like the contiguous axis."""
    spec = AttnSpec(hq=8, hkv=2, kv_layout="paged", bk=16)
    plan = attn_plan(make_acu(MULT, use_pallas=True), spec)
    assert plan.route == "fused_attn_paged" and plan.fn is not None
    d = plan.describe()
    assert d["kv_layout"] == "paged (block=16)"

    dense = attn_plan(make_acu(MULT), spec)          # no pallas -> dense
    assert dense.route == "dense" and dense.fn is None
    assert any("gathers pool blocks" in r for r in dense.report)
    with pytest.raises(ValueError, match="route unavailable"):
        attn_plan(make_acu(MULT), spec, route="fused_attn_paged")
    # pinning the contiguous fused route on a paged spec is a mismatch
    with pytest.raises(ValueError):
        attn_plan(make_acu(MULT, use_pallas=True), spec, route="fused_attn")
    with pytest.raises(ValueError, match="kv_layout"):
        attn_plan(make_acu(MULT, use_pallas=True),
                  AttnSpec(hq=8, hkv=2, kv_layout="ragged"))


def test_attn_plan_paged_fn_matches_contiguous_plan():
    """The paged plan's (B, Hq, S, D) fn == the contiguous plan on the same
    values in a contiguous layout, bitwise — the pool indirection composes
    with head folding and the plan-level reshapes."""
    acu = make_acu(MULT, use_pallas=True)
    b, hkv, rep, sq, d, bk = 2, 2, 2, 8, 16, 16
    hq = hkv * rep
    kv_lens = (64, 23)
    q, k, v, s, kp, vp, info, pt = _paged_setup(b, hkv, rep, sq, d, kv_lens,
                                                bk, seed=13)
    qs4 = q.reshape(b, hq, sq, d)
    sk = k.shape[1]
    paged = attn_plan(acu, AttnSpec(hq=hq, hkv=hkv, bq=32, bk=bk,
                                    kv_layout="paged"), mesh=False)
    cont = attn_plan(acu, AttnSpec(hq=hq, hkv=hkv, bq=32, bk=bk), mesh=False)
    # plan-level rowinfo/page_table are per batch row, not per folded head
    info_b = info[::hq]
    pt_b = pt[::hq]
    out = paged(qs4, kp, vp, *s, info_b, pt_b)
    ref = cont(qs4, k.reshape(b, hkv, sk, d), v.reshape(b, hkv, sk, d), *s,
               info_b)
    assert jnp.array_equal(out, ref)


def test_approx_attention_paged_helper_routes():
    """approx_ops.approx_attention_paged: fused plan -> output matching the
    paged oracle with gathered-block scales, dense -> None."""
    from repro.core.approx_ops import approx_attention_paged
    b, hkv, rep, sq, d, bk = 1, 2, 2, 1, 16, 16
    hq = hkv * rep
    q, _, _, _, kp, vp, info, pt = _paged_setup(b, hkv, rep, sq, d, (20,),
                                                bk, seed=17)
    q4 = q.reshape(b, hq, sq, d)
    info_b, pt_b = info[::hq], pt[::hq]
    fused_cfg = ApproxConfig(acu=make_acu(MULT, use_pallas=True, fused=True))
    out = approx_attention_paged(q4, kp, vp, fused_cfg, page_table=pt_b,
                                 rowinfo=info_b)
    assert out is not None and out.shape == (b, hq, sq, d)
    dense_cfg = ApproxConfig(acu=make_acu(MULT))
    assert approx_attention_paged(q4, kp, vp, dense_cfg, page_table=pt_b,
                                  rowinfo=info_b) is None


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
@pytest.mark.parametrize("b,hq,hkv", [(4, 8, 4), (2, 4, 1), (3, 8, 2)])
def test_sharded_paged_attn_bit_exact(b, hq, hkv):
    """Paged plan under the 2x4 host mesh (batch rows over ("data",), KV
    heads over ("model",), pool + page table replicated where needed) ==
    the single-device paged plan bit for bit, batch/head counts that do not
    divide the mesh axes included."""
    from repro.launch.mesh import make_host_multi_mesh
    from repro.parallel.sharding import use_mesh
    mesh = make_host_multi_mesh((2, 4))
    acu = make_acu(MULT, use_pallas=True)
    rep = hq // hkv
    bk = 16
    kv_lens = tuple(17 + 11 * i for i in range(b))
    q, _, _, s, kp, vp, info, pt = _paged_setup(b, hkv, rep, 1, 16, kv_lens,
                                                bk, seed=b + hq)
    q4 = q.reshape(b, hq, 1, 16)
    info_b, pt_b = info[::hq], pt[::hq]
    spec = AttnSpec(hq=hq, hkv=hkv, bq=32, bk=bk, kv_layout="paged")
    ref = attn_plan(acu, spec, mesh=False)(q4, kp, vp, *s, info_b, pt_b)
    with use_mesh(mesh):
        plan = attn_plan(acu, spec)
        assert plan.route == "fused_attn_paged"
        out = plan(q4, kp, vp, *s, info_b, pt_b)
    assert jnp.array_equal(out, ref)
