"""Approximate flash attention: kernel == unfused oracle, bitwise.

The contract under test (kernels/flash_attention/approx.py): the fused
Pallas kernel — per-tensor quantize of Q/K/V in-kernel, QK^T and PV as int32
LUT-gather GEMMs inside the streaming softmax, pad corrections in integer
space, dequant folded into the running rescale — is bit-identical to
``approx_attention_ref``, the unfused jnp composition driving the same
shared per-KV-block core. Plus the planning layer (core/acu.attn_plan):
route resolution, audited dense fallback, the end-aligned default rowinfo,
and the model-level wiring through ``attention_block``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.acu import AttnSpec, attn_plan, make_acu
from repro.core.approx_ops import ApproxConfig, approx_attention
from repro.core.lut import build_lut
from repro.core.multipliers import get_multiplier
from repro.kernels.flash_attention.approx import approx_flash_attention
from repro.kernels.flash_attention.ref import approx_attention_ref

MULT = "mul8s_1L2H"      # biased approximate multiplier: LUT[0, x] != 0 for
                         # some x, so masked-key and pad-correction semantics
                         # are observable, not vacuously zero


def _lut(name=MULT):
    return build_lut(get_multiplier(name))


def _qkv(bh, sq, sk, d, bh_kv=None, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh_kv or bh, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh_kv or bh, sk, d)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    return q, k, v, s


CASES = [
    # (sq, sk, d, rep, causal, window, softcap, bq, bk)
    (128, 128, 32, 1, True, None, None, 64, 64),
    (128, 256, 32, 1, False, None, None, 64, 64),     # multi-kv-block
    (128, 128, 32, 4, True, None, None, 64, 64),      # GQA
    (96, 203, 24, 1, True, 17, 30.0, 64, 64),         # odd S + window+softcap
    (1, 131, 32, 2, True, None, None, 64, 64),        # decode step, odd Sk
    (64, 64, 20, 1, True, 9, None, 32, 32),           # odd head dim
]


@pytest.mark.parametrize("sq,sk,d,rep,causal,window,softcap,bq,bk", CASES)
def test_kernel_matches_oracle_bitwise(sq, sk, d, rep, causal, window,
                                       softcap, bq, bk):
    lut = _lut()
    bh_kv = 2
    q, k, v, (qs, ks, vs) = _qkv(bh_kv * rep, sq, sk, d, bh_kv, seed=sq + sk)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=causal,
                                 window=window, softcap=softcap, bq=bq, bk=bk)
    ref = approx_attention_ref(q, k, v, lut, 128, qs, ks, vs, causal=causal,
                               window=window, softcap=softcap, bq=bq, bk=bk)
    assert out.dtype == jnp.float32 and out.shape == (bh_kv * rep, sq, d)
    assert jnp.array_equal(out, ref), float(jnp.max(jnp.abs(out - ref)))


def test_outer_jit_bitwise():
    """Embedding the kernel call in an outer jit (the serving decode step)
    must not perturb a single bit vs the direct call."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(4, 64, 192, 32, 2, seed=7)
    fn = lambda q, k, v, qs, ks, vs: approx_flash_attention(
        q, k, v, lut, 128, qs, ks, vs, causal=True, bq=64, bk=64)
    direct = fn(q, k, v, qs, ks, vs)
    jitted = jax.jit(fn)(q, k, v, qs, ks, vs)
    assert jnp.array_equal(direct, jitted)


def test_gqa_equals_explicit_repeat():
    """Folded-GQA (k/v indexed via b // rep in the BlockSpec) == physically
    repeating K/V to rep=1 — the layout optimization must be invisible."""
    lut = _lut()
    rep = 4
    q, k, v, (qs, ks, vs) = _qkv(2 * rep, 96, 160, 32, 2, seed=11)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                                 bq=64, bk=64)
    kr = jnp.repeat(k, rep, axis=0)
    vr = jnp.repeat(v, rep, axis=0)
    ref = approx_flash_attention(q, kr, vr, lut, 128, qs, ks, vs, causal=True,
                                 bq=64, bk=64)
    assert jnp.array_equal(out, ref)


def test_default_rowinfo_is_end_aligned():
    """rowinfo=None == explicit [sk-sq, 0, sk] rows (decode convention)."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(3, 32, 96, 16, seed=3)
    info = jnp.broadcast_to(jnp.array([64, 0, 96], jnp.int32), (3, 3))
    a = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               bq=32, bk=32)
    b = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(a, b)


def test_heterogeneous_rowinfo_bitwise():
    """Per-row [q_base, kv_start, kv_len] (the continuous-batching serving
    state: every slot at its own cache offset with its own left-pad) — the
    kernel matches the oracle bit for bit."""
    lut = _lut()
    q, k, v, (qs, ks, vs) = _qkv(3, 1, 96, 16, seed=5)
    info = jnp.array([[95, 13, 96],     # left-padded slot, full cache
                      [40, 0, 41],      # young slot: short written prefix
                      [7, 3, 8]], jnp.int32)
    out = approx_flash_attention(q, k, v, lut, 128, qs, ks, vs, causal=True,
                                 rowinfo=info, bq=32, bk=32)
    ref = approx_attention_ref(q, k, v, lut, 128, qs, ks, vs, causal=True,
                               rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(out, ref)
    # the young slot must not read keys past kv_len: perturbing them there
    # cannot change its row
    k2 = k.at[1, 41:].set(99.0)
    v2 = v.at[1, 41:].set(-99.0)
    out2 = approx_flash_attention(q, k2, v2, lut, 128, qs, ks, vs,
                                  causal=True, rowinfo=info, bq=32, bk=32)
    assert jnp.array_equal(out[1], out2[1])


# ---------------------------------------------------------------------------
# planning layer
# ---------------------------------------------------------------------------

def test_attn_plan_routes_and_audits():
    spec = AttnSpec(hq=8, hkv=2)
    fused = attn_plan(make_acu(MULT, use_pallas=True), spec)
    assert fused.route == "fused_attn" and fused.fn is not None
    d = fused.describe()
    assert d["route"] == "fused_attn" and "rep=4" in d["heads"]

    # every way an ACU fails the fused contract resolves to audited "dense"
    for acu in (make_acu(MULT),                          # no pallas
                make_acu(MULT, mode="functional", use_pallas=True),
                make_acu("mul12s_exact", use_pallas=True)):  # >10b: no LUT
        plan = attn_plan(acu, spec)
        assert plan.route == "dense" and plan.fn is None
        assert any("attention stays exact" in r for r in plan.report)
        with pytest.raises(ValueError, match="fused_attn route unavailable"):
            attn_plan(acu, spec, route="fused_attn")

    pinned = attn_plan(make_acu(MULT, use_pallas=True), spec, route="dense")
    assert pinned.route == "dense"
    with pytest.raises(ValueError, match="unknown attn route"):
        attn_plan(make_acu(MULT, use_pallas=True), spec, route="bogus")
    with pytest.raises(ValueError, match="not a multiple"):
        attn_plan(make_acu(MULT, use_pallas=True), AttnSpec(hq=6, hkv=4))


def test_attn_plan_fn_matches_kernel():
    """The plan's (B, H, S, D) fn is exactly the folded kernel call."""
    acu = make_acu(MULT, use_pallas=True)
    plan = attn_plan(acu, AttnSpec(hq=4, hkv=2, bq=32, bk=32))
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 96, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 96, 16)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    out = plan(q, k, v, *s)
    ref = approx_flash_attention(
        q.reshape(8, 32, 16), k.reshape(4, 96, 16), v.reshape(4, 96, 16),
        jnp.asarray(acu.lut), acu.offset, *s, causal=True, bq=32, bk=32)
    assert jnp.array_equal(out, ref.reshape(2, 4, 32, 16))


def test_approx_attention_helper_routes():
    """approx_ops.approx_attention: fused plan -> output, dense -> None."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 4, 16, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 48, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 48, 16)), jnp.float32)
    fused_cfg = ApproxConfig(acu=make_acu(MULT, use_pallas=True))
    out = approx_attention(q, k, v, fused_cfg)
    assert out is not None and out.shape == (1, 4, 16, 16)
    dense_cfg = ApproxConfig(acu=make_acu(MULT))
    assert approx_attention(q, k, v, dense_cfg) is None


def test_decode_vector_cache_pos_matches_scalar():
    """Continuous batching plumbing: a (B,) cache_pos vector with equal
    entries decodes bitwise the same logits as the scalar path, on both the
    exact substrate and the ACU route."""
    from repro.configs import reduced_config
    from repro.models.transformer import apply_model, init_cache, init_params
    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[5, 17, 3, 99], [5, 17, 3, 99]], jnp.int32)
    for acfg in (None, ApproxConfig(acu=make_acu(MULT, use_pallas=True,
                                                 fused=True))):
        cache_s = init_cache(cfg, 2, 32)
        _, cache_s = apply_model(params, toks, cfg, acfg=acfg, cache=cache_s)
        cache_v = jax.tree.map(jnp.copy, cache_s)
        tok = jnp.asarray([[7], [7]], jnp.int32)
        ls, _ = apply_model(params, tok, cfg, acfg=acfg, cache=cache_s,
                            cache_pos=4, decode=True)
        lv, _ = apply_model(params, tok, cfg, acfg=acfg, cache=cache_v,
                            cache_pos=jnp.asarray([4, 4], jnp.int32),
                            decode=True)
        assert jnp.array_equal(ls, lv)


def test_model_decode_rides_acu_route(monkeypatch):
    """attention_block must dispatch decode through approx_attention when
    the plan fuses — and fall back cleanly when it audits to dense."""
    from repro.configs import reduced_config
    from repro.models import layers as L
    from repro.models.transformer import apply_model, init_cache, init_params
    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    calls = {"n": 0}
    real = L.approx_attention

    def counting(*a, **kw):
        out = real(*a, **kw)
        calls["n"] += 1 if out is not None else 0
        return out

    monkeypatch.setattr(L, "approx_attention", counting)
    acfg = ApproxConfig(acu=make_acu(MULT, use_pallas=True, fused=True))
    cache = init_cache(cfg, 1, 16)
    toks = jnp.asarray([[5, 17, 3]], jnp.int32)
    apply_model(params, toks, cfg, acfg=acfg, cache=cache, cache_pos=0)
    assert calls["n"] > 0
    calls["n"] = 0
    dense = ApproxConfig(acu=make_acu(MULT))   # no pallas: dense fallback
    apply_model(params, toks, cfg, acfg=dense, cache=init_cache(cfg, 1, 16),
                cache_pos=0)
    assert calls["n"] == 0


# ---------------------------------------------------------------------------
# sharded == single-device (2x4 host mesh; skips below 8 devices)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
@pytest.mark.parametrize("b,hq,hkv", [(4, 8, 4), (2, 4, 1), (3, 8, 2)])
def test_sharded_attn_bit_exact(b, hq, hkv):
    """Batch over ("data",) rows and KV heads over ("model",): the sharded
    plan output equals the single-device plan bit for bit — including batch
    and head counts that do not divide the mesh axes."""
    from repro.launch.mesh import make_host_multi_mesh
    from repro.parallel.sharding import use_mesh
    mesh = make_host_multi_mesh((2, 4))
    acu = make_acu(MULT, use_pallas=True)
    spec = AttnSpec(hq=hq, hkv=hkv, bq=32, bk=32)
    rng = np.random.default_rng(b + hq)
    q = jnp.asarray(rng.normal(size=(b, hq, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, 96, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, 96, 16)), jnp.float32)
    s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
    ref = attn_plan(acu, spec, mesh=False)(q, k, v, *s)
    with use_mesh(mesh):
        plan = attn_plan(acu, spec)
        out = plan(q, k, v, *s)
    assert jnp.array_equal(out, ref)
