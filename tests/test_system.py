"""End-to-end system tests: LM training with ACU emulation + the dry-run
entry point in a subprocess (reduced device count)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.data.pipeline import MarkovLM, Prefetcher
from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_lm_training_loss_decreases(tmp_path):
    """Reduced smollm trains on the synthetic Markov stream end to end
    (data pipeline -> trainer -> checkpoints)."""
    cfg = dataclasses.replace(reduced_config("smollm-135m"), n_layers=2)
    lm = MarkovLM(vocab=cfg.vocab_size, seed=0)
    params = init_params(KEY, cfg)
    opt = AdamW(lr=cosine_schedule(3e-3, 5, 100))

    def batch_loss(p, batch):
        return loss_fn(p, batch["tokens"], batch["labels"], cfg)

    tr = Trainer(batch_loss, opt,
                 TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=20,
                               log_every=5, async_ckpt=False))
    data = Prefetcher(lm.batches(8, 32), depth=2)
    params, _ = tr.fit(params, opt.init(params), data, n_steps=40)
    data.close()
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0] - 0.2, losses


@pytest.mark.slow
def test_lm_training_with_acu_emulation():
    """The paper's technique on the LM substrate: forward through the lossy
    8-bit ACU, STE backward — loss still decreases."""
    cfg = dataclasses.replace(reduced_config("smollm-135m"), n_layers=1)
    acfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))
    lm = MarkovLM(vocab=cfg.vocab_size, seed=0)
    params = init_params(KEY, cfg)
    opt = AdamW(lr=3e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, st, toks, labs):
        loss, g = jax.value_and_grad(
            lambda p: loss_fn(p, toks, labs, cfg, acfg))(p)
        p, st = opt.update(g, st, p)
        return p, st, loss

    it = lm.batches(4, 16)
    losses = []
    for _ in range(30):
        b = next(it)
        params, state, l = step(params, state,
                                jnp.asarray(b["tokens"]), jnp.asarray(b["labels"]))
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


@pytest.mark.slow
def test_dryrun_subprocess_mini():
    """The real dry-run entry point compiles a cell (512 host devices) and
    emits a well-formed record."""
    out = os.path.join(REPO, "test_dryrun_mini.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--mesh", "pod", "--no-probe", "--out", out],
        capture_output=True, text=True, env=env, timeout=560, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    recs = json.load(open(out))
    os.remove(out)
    assert recs and "t_compute" in recs[0] and recs[0]["bottleneck"]
