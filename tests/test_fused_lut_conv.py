"""Patch-streaming fused conv: bit-exactness vs the eager im2col +
``fused_lut_dense`` oracle — the exact path the kernel retired.

"Bit-exact" is literal float equality: the fused kernel must perform the
same per-pixel quantize, the same int32 accumulate (taps and channel chunks
add associatively; channel padding corrected in *integer* space), and the
same single combined-scale dequant as the eager route. ``conv2d(...,
route="im2col")`` pins that oracle with the same quantizers, so the two
public routes are comparable end to end — eager and jit, with bias, and
through the STE backward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import assume, given, settings, strategies as st
from repro.core import build_lut, get_multiplier, make_acu
from repro.core.acu import (AcuMode, ConvSpec, conv_plan,
                            resolve_conv_padding)
from repro.core.approx_ops import ApproxConfig, conv2d, conv_plan_report
from repro.core.multipliers import make_exact
from repro.core.quantization import acu_operand, quantize, symmetric_qparams
from repro.kernels.fused_lut_conv.ops import (fused_lut_conv,
                                              fused_lut_conv_tiled)
from repro.kernels.fused_lut_conv.ref import fused_lut_conv_ref

MULT = get_multiplier("mul8s_1L2H")
LUT = jnp.asarray(build_lut(MULT))
ACU_FUSED = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True)
CFG = ApproxConfig(acu=ACU_FUSED)


def _conv_operands(shape, wshape, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    w = jnp.asarray(rng.normal(size=wshape), jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# kernel vs its own pure-jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("geom", [
    # (x_shape, w_shape, stride, padding, dilation)
    ((2, 3, 12, 12), (5, 3, 3, 3), (1, 1), "SAME", (1, 1)),
    ((1, 8, 9, 9), (4, 8, 3, 3), (2, 2), "SAME", (1, 1)),      # stride > 1
    ((2, 5, 10, 10), (6, 5, 3, 3), (1, 1), "SAME", (2, 2)),    # dilation > 1
    ((1, 6, 11, 5), (9, 6, 3, 3), (2, 1), "VALID", (1, 1)),    # mixed stride
    ((1, 4, 7, 7), (3, 4, 1, 1), (1, 1), "VALID", (1, 1)),     # pointwise
    ((2, 40, 6, 6), (7, 40, 3, 3), (1, 1), "SAME", (1, 1)),    # C pad to inner
    ((1, 3, 13, 13), (5, 3, 5, 5), (3, 3), "SAME", (1, 1)),    # 5x5, stride 3
])
def test_kernel_matches_ref(geom):
    """Edge geometry sweep: stride>1, dilation>1, non-divisible spatial
    tiles, channel padding — kernel output equals the im2col oracle
    bitwise."""
    shape, wshape, stride, padding, dilation = geom
    x, w = _conv_operands(shape, wshape, seed=sum(shape))
    pad = resolve_conv_padding(padding, shape, wshape, stride, dilation)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(
        jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2, 3)), 1e-9), 8, axis=0)
    wq = acu_operand(quantize(w, wqp), wqp)
    out = fused_lut_conv(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                         wqp.scale, stride=stride, padding=pad,
                         dilation=dilation, bits=8, interpret=True)
    ref = fused_lut_conv_ref(x, wq, LUT.reshape(-1), 128, 256, xqp.scale,
                             xqp.zero_point, wqp.scale, stride=stride,
                             padding=pad, dilation=dilation, bits=8)
    assert jnp.array_equal(out, ref)


def test_kernel_biased_m00_channel_pad():
    """Channel padding contributes kh*kw * LUT[off, off] = kh*kw * M[0, 0]
    per padded channel; the kernel must subtract it in integer space.
    Exercised with a synthetic multiplier whose M[0, 0] = 7 (every
    registered family has M[0, 0] == 0) at C=5, which pads to the gather
    chunk."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = jnp.asarray(build_lut(biased))
    assert int(lut[128, 128]) == 7
    x, w = _conv_operands((2, 5, 7, 7), (4, 5, 3, 3), seed=5)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(
        jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2, 3)), 1e-9), 8, axis=0)
    wq = acu_operand(quantize(w, wqp), wqp)
    pad = ((1, 1), (1, 1))
    out = fused_lut_conv(x, wq, lut, 128, xqp.scale, xqp.zero_point,
                         wqp.scale, padding=pad, bits=8, interpret=True)
    ref = fused_lut_conv_ref(x, wq, lut.reshape(-1), 128, 256, xqp.scale,
                             xqp.zero_point, wqp.scale, padding=pad, bits=8)
    assert jnp.array_equal(out, ref)


def test_kernel_emit_acc_is_raw_accumulator():
    """emit_acc=True returns the int32 accumulator (channel padding already
    corrected) — what the channel-contraction route psums — and dequantizing
    it reproduces the normal output bitwise."""
    x, w = _conv_operands((1, 6, 8, 8), (5, 6, 3, 3), seed=13)
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(
        jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2, 3)), 1e-9), 8, axis=0)
    wq = acu_operand(quantize(w, wqp), wqp)
    pad = ((1, 1), (1, 1))
    acc = fused_lut_conv(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                         wqp.scale, padding=pad, bits=8, interpret=True,
                         emit_acc=True)
    assert acc.dtype == jnp.int32
    out = fused_lut_conv(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                         wqp.scale, padding=pad, bits=8, interpret=True)
    dq = acc.astype(jnp.float32) * \
        (xqp.scale * wqp.scale.reshape(1, 1, 1, -1))
    assert jnp.array_equal(out, dq)


# ---------------------------------------------------------------------------
# public conv2d: fused route vs the pinned eager-im2col oracle route
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("geom", [
    ((2, 3, 12, 12), (5, 3, 3, 3), dict()),
    ((1, 8, 9, 9), (4, 8, 3, 3), dict(stride=(2, 2))),
    ((2, 5, 10, 10), (6, 5, 3, 3), dict(dilation=(2, 2))),
    ((1, 6, 11, 5), (9, 6, 3, 3), dict(stride=(2, 1), padding="VALID")),
    ((1, 4, 7, 7), (3, 4, 1, 1), dict(padding="VALID")),
])
def test_conv2d_fused_equals_im2col_route(geom):
    """End to end with bias, eager AND jit: conv2d through the fused plan
    equals conv2d pinned to the eager im2col route, bitwise, within each
    execution regime."""
    shape, wshape, kw_ = geom
    x, w = _conv_operands(shape, wshape, seed=sum(shape) + 1)
    b = jnp.asarray(np.random.default_rng(9).normal(size=(wshape[0],)),
                    jnp.float32)
    y_f = conv2d(x, w, b, cfg=CFG, **kw_)
    y_o = conv2d(x, w, b, cfg=CFG, route="im2col", **kw_)
    assert jnp.array_equal(y_f, y_o)
    j_f = jax.jit(lambda x, w, b: conv2d(x, w, b, cfg=CFG, **kw_))(x, w, b)
    j_o = jax.jit(lambda x, w, b: conv2d(x, w, b, cfg=CFG, route="im2col",
                                         **kw_))(x, w, b)
    assert jnp.array_equal(j_f, j_o)


def test_conv2d_grouped_keeps_vmapped_gemm_route():
    """groups>1 resolves to the single-vmapped-GEMM route (PR 2 semantics)
    and still matches lax.conv to quantization tolerance."""
    x, w = _conv_operands((2, 8, 8, 8), (8, 4, 3, 3), seed=3)
    spec = ConvSpec(x_shape=(2, 8, 8, 8), w_shape=(8, 4, 3, 3),
                    padding=((1, 1), (1, 1)), groups=2)
    plan = conv_plan(ACU_FUSED, spec, fused=True)
    assert plan.route == "im2col_grouped"
    assert any("groups" in r for r in plan.report)
    cfg12 = ApproxConfig(acu=make_acu("mul12s_exact", AcuMode.EXACT),
                         a_bits=12, w_bits=12)
    ours = conv2d(x, w, groups=2, cfg=cfg12)
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", feature_group_count=2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    rel = float(jnp.abs(ours - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-3


def test_conv2d_ste_backward_matches_im2col_route():
    """QAT: gradients through the fused forward are bitwise identical to the
    eager route's STE gradients (same fake-quant residuals, same GEMMs)."""
    x, w = _conv_operands((2, 3, 8, 8), (5, 3, 3, 3), seed=4)

    def loss(x, w, route):
        return (conv2d(x, w, None, cfg=CFG, route=route) ** 2).sum()

    gx_f, gw_f = jax.grad(loss, argnums=(0, 1))(x, w, None)
    gx_o, gw_o = jax.grad(loss, argnums=(0, 1))(x, w, "im2col")
    assert jnp.array_equal(gx_f, gx_o)
    assert jnp.array_equal(gw_f, gw_o)


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------

def test_conv_plan_routing():
    spec = ConvSpec(x_shape=(2, 3, 12, 12), w_shape=(5, 3, 3, 3),
                    padding=((1, 1), (1, 1)))
    assert conv_plan(ACU_FUSED, spec).route == "fused_conv"
    assert conv_plan(ACU_FUSED, spec, fused=False).route == "im2col"
    # non-Pallas LUT: audited fallback
    jnp_acu = make_acu("mul8s_1L2H", AcuMode.LUT)
    plan = conv_plan(jnp_acu, spec, fused=True)
    assert plan.route == "im2col"
    assert any("use_pallas" in r for r in plan.report)
    # FUNCTIONAL mode can't fuse either
    func = make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL, use_pallas=True)
    assert conv_plan(func, spec, fused=True).route == "im2col"
    # depthwise keeps its block-diagonal route
    dspec = ConvSpec(x_shape=(2, 6, 8, 8), w_shape=(6, 1, 3, 3),
                     padding=((1, 1), (1, 1)), groups=6)
    assert conv_plan(ACU_FUSED, dspec).route == "im2col_depthwise"
    # pinning fused_conv on an unservable request raises instead of falling
    with pytest.raises(ValueError):
        conv_plan(jnp_acu, spec, route="fused_conv")


def test_conv2d_route_pin_fused_on_unfused_cfg():
    """route="fused_conv" forces the fused kernel even when the config
    doesn't default to fusion — and matches the fused-by-default result
    bitwise (same plan, same quantizers)."""
    x, w = _conv_operands((1, 3, 8, 8), (4, 3, 3, 3), seed=6)
    plain = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT,
                                      use_pallas=True))  # fused=False default
    y_pin = conv2d(x, w, None, cfg=plain, route="fused_conv")
    y_def = conv2d(x, w, None, cfg=CFG)
    assert jnp.array_equal(y_pin, y_def)


def test_conv2d_fake_quant_only_never_hits_the_integer_kernel():
    """fake_quant_only must run the fake-quant QAT forward on every route:
    the default pins the eager path, and pinning the fused route explicitly
    is a caller error, not a silent integer-GEMM forward."""
    x, w = _conv_operands((1, 3, 6, 6), (4, 3, 3, 3), seed=2)
    fq = ApproxConfig(acu=ACU_FUSED, fake_quant_only=True)
    y = conv2d(x, w, None, cfg=fq)
    y_ref = conv2d(x, w, None, cfg=ApproxConfig(
        acu=make_acu("mul8s_1L2H", AcuMode.LUT), fake_quant_only=True))
    assert jnp.array_equal(y, y_ref)
    with pytest.raises(ValueError):
        conv2d(x, w, None, cfg=fq, route="fused_conv")


def test_conv_plan_vmem_resolves_tiled():
    """Images whose whole-image working set exceeds the VMEM budget resolve
    to the spatially-tiled kernel (NOT the eager fallback) with an audited
    report naming the chosen banding."""
    spec = ConvSpec(x_shape=(1, 64, 224, 224), w_shape=(64, 64, 3, 3),
                    padding=((1, 1), (1, 1)))
    plan = conv_plan(ACU_FUSED, spec, fused=True)
    assert plan.route == "tiled"
    assert plan.tiling is not None
    assert plan.fn is not None
    assert any("spatially tiled" in r for r in plan.report)
    assert not any("im2col" in r for r in plan.report)


def test_conv_plan_report_shape():
    rep = conv_plan_report((2, 3, 12, 12), (5, 3, 3, 3), CFG)
    assert rep["route"] == "fused_conv" and rep["fused"]
    assert rep["partition"] is None          # no active mesh
    assert rep["gemm"] == "M=288 K=27 N=5"


def test_resolve_conv_padding_matches_xla_same():
    """Our SAME split must agree with XLA's (lo = total // 2) so the fused
    kernel, the eager patches route, and lax.conv see identical geometry."""
    for (hw, k, s, d) in [((12, 12), 3, (1, 1), (1, 1)),
                          ((9, 9), 3, (2, 2), (1, 1)),
                          ((10, 7), 5, (2, 3), (2, 1))]:
        x_shape = (1, 2, *hw)
        w_shape = (3, 2, k, k)
        pad = resolve_conv_padding("SAME", x_shape, w_shape, s, d)
        x = jnp.zeros(x_shape)
        w = jnp.zeros(w_shape)
        args = dict(window_strides=s, rhs_dilation=d,
                    dimension_numbers=("NCHW", "OIHW", "NCHW"))
        ref = jax.lax.conv_general_dilated(x, w, padding="SAME", **args)
        ours = jax.lax.conv_general_dilated(x, w, padding=pad, **args)
        assert ours.shape == ref.shape, (hw, k, s, d, pad)


# ---------------------------------------------------------------------------
# spatially-tiled kernel (PR 4): tiled == whole-image == eager oracle
# ---------------------------------------------------------------------------

def _quantized_operands(x, w):
    xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
    wqp = symmetric_qparams(
        jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2, 3)), 1e-9), 8, axis=0)
    return xqp, wqp, acu_operand(quantize(w, wqp), wqp)


def test_tiled_kernel_matches_whole_and_ref_across_band_heights():
    """Any band height is bit-identical: int32 tap accumulation is
    order-independent, so tiling only moves work between grid steps."""
    x, w = _conv_operands((2, 5, 13, 11), (6, 5, 3, 3), seed=21)
    xqp, wqp, wq = _quantized_operands(x, w)
    pad = ((1, 1), (1, 1))
    ref = fused_lut_conv_ref(x, wq, LUT.reshape(-1), 128, 256, xqp.scale,
                             xqp.zero_point, wqp.scale, padding=pad, bits=8)
    whole = fused_lut_conv(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                           wqp.scale, padding=pad, bits=8, interpret=True)
    assert jnp.array_equal(whole, ref)
    for bh in (1, 2, 3, 5, 13):
        tiled = fused_lut_conv_tiled(x, wq, LUT, 128, xqp.scale,
                                     xqp.zero_point, wqp.scale, padding=pad,
                                     bits=8, bh=bh, interpret=True)
        assert jnp.array_equal(tiled, ref), bh


def test_tiled_kernel_biased_m00_channel_pad():
    """The tiled kernel's integer-space channel-pad correction, exercised
    with a synthetic M[0, 0] = 7 multiplier at C=5 (pads to the gather
    chunk)."""
    biased = dataclasses.replace(
        make_exact(8), name="mul8s_biased",
        fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
    lut = jnp.asarray(build_lut(biased))
    x, w = _conv_operands((2, 5, 9, 7), (4, 5, 3, 3), seed=23)
    xqp, wqp, wq = _quantized_operands(x, w)
    pad = ((1, 1), (1, 1))
    ref = fused_lut_conv_ref(x, wq, lut.reshape(-1), 128, 256, xqp.scale,
                             xqp.zero_point, wqp.scale, padding=pad, bits=8)
    for bh in (1, 2, 4):
        tiled = fused_lut_conv_tiled(x, wq, lut, 128, xqp.scale,
                                     xqp.zero_point, wqp.scale, padding=pad,
                                     bits=8, bh=bh, interpret=True)
        assert jnp.array_equal(tiled, ref), bh


def test_tiled_kernel_emit_acc_is_raw_accumulator():
    """emit_acc=True on the tiled kernel returns the int32 accumulator
    (channel padding already corrected) — what the channel-contraction
    route psums — and dequantizing it reproduces the whole-image output
    bitwise."""
    x, w = _conv_operands((1, 6, 10, 8), (5, 6, 3, 3), seed=29)
    xqp, wqp, wq = _quantized_operands(x, w)
    pad = ((1, 1), (1, 1))
    acc = fused_lut_conv_tiled(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                               wqp.scale, padding=pad, bits=8, bh=2,
                               interpret=True, emit_acc=True)
    assert acc.dtype == jnp.int32
    ref = fused_lut_conv(x, wq, LUT, 128, xqp.scale, xqp.zero_point,
                         wqp.scale, padding=pad, bits=8, interpret=True)
    dq = acc.astype(jnp.float32) * \
        (xqp.scale * wqp.scale.reshape(1, 1, 1, -1))
    assert jnp.array_equal(dq, ref)


def test_conv2d_route_pin_tiled():
    """route="tiled" forces the spatially-tiled kernel on a fits-in-VMEM
    image and matches the whole-image fused route and the eager oracle
    bitwise, eager and jit; fake_quant_only contradicts the pin."""
    x, w = _conv_operands((2, 4, 11, 9), (5, 4, 3, 3), seed=31)
    b = jnp.asarray(np.random.default_rng(31).normal(size=(5,)), jnp.float32)
    y_t = conv2d(x, w, b, cfg=CFG, route="tiled")
    y_f = conv2d(x, w, b, cfg=CFG)
    y_o = conv2d(x, w, b, cfg=CFG, route="im2col")
    assert jnp.array_equal(y_t, y_o)
    assert jnp.array_equal(y_f, y_o)
    j_t = jax.jit(lambda x, w: conv2d(x, w, None, cfg=CFG,
                                      route="tiled"))(x, w)
    j_o = jax.jit(lambda x, w: conv2d(x, w, None, cfg=CFG,
                                      route="im2col"))(x, w)
    assert jnp.array_equal(j_t, j_o)
    fq = ApproxConfig(acu=ACU_FUSED, fake_quant_only=True)
    with pytest.raises(ValueError):
        conv2d(x, w, None, cfg=fq, route="tiled")


def test_conv2d_tiled_ste_backward_matches_im2col_route():
    """QAT through the tiled forward: gradients bitwise identical to the
    eager route's STE gradients."""
    x, w = _conv_operands((2, 3, 10, 10), (5, 3, 3, 3), seed=37)

    def loss(x, w, route):
        return (conv2d(x, w, None, cfg=CFG, route=route) ** 2).sum()

    gx_t, gw_t = jax.grad(loss, argnums=(0, 1))(x, w, "tiled")
    gx_o, gw_o = jax.grad(loss, argnums=(0, 1))(x, w, "im2col")
    assert jnp.array_equal(gx_t, gx_o)
    assert jnp.array_equal(gw_t, gw_o)


def test_vmem_estimate_matches_kernel_allocation():
    """Regression for the pre-PR 4 VMEM model bug: the estimate must count
    the exact padded extents the kernel allocates — including the
    (kh-1)*dilation halo rows a stride-only model misses — so near-budget
    dilated convs can never pick an overflowing tile. Pinned against the
    geometry helper the kernel wrapper itself pads with."""
    from repro.kernels.fused_lut_conv.ops import (conv_padded_geometry,
                                                  conv_vmem_bytes,
                                                  pick_conv_tiling)
    # dilation=3: the dilated tap span (kh-1)*dh = 12 dwarfs bh*sh
    geoms = [
        (8, 20, 20, 8, 5, 5, 1, 1, 3, 3, ((6, 6), (6, 6))),
        (16, 30, 14, 32, 3, 3, 2, 2, 2, 2, ((2, 2), (2, 2))),
        (4, 9, 33, 4, 3, 3, 1, 1, 1, 1, ((1, 1), (1, 1))),
    ]
    for (c, h, w, cout, kh, kw, sh, sw, dh, dw, pad) in geoms:
        ho, wo, _, _, _ = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                               pad, 1)
        inner, bh, bn = pick_conv_tiling(c, ho, wo, cout)
        _, _, _, hp, wp = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                               pad, bh)
        c_pad = c + (-c) % inner
        est = conv_vmem_bytes(c, h, w, cout, kh, kw, sh, sw, dh, dw, pad, 256)
        # the image-block + scratch term must cover the kernel's actual
        # (C_pad, Hp, Wp) f32 block and int32 scratch allocation
        assert est >= 8 * c_pad * hp * wp, (c, h, w, est)
        # and the whole estimate is what conv_plan budgets against
        from repro.core.acu import ConvSpec, _conv_vmem_estimate
        spec = ConvSpec(x_shape=(1, c, h, w), w_shape=(cout, c, kh, kw),
                        stride=(sh, sw), padding=pad, dilation=(dh, dw))
        assert _conv_vmem_estimate(spec, 256) == est


def test_spatial_tiling_pick_respects_budget():
    """pick_conv_spatial_tiling returns a banding whose modeled working set
    fits the budget, and None when even a one-row band cannot."""
    from repro.kernels.fused_lut_conv.ops import (conv_tiled_vmem_bytes,
                                                  pick_conv_spatial_tiling)
    args = (64, 224, 224, 64, 3, 3, 1, 1, 1, 1, ((1, 1), (1, 1)), 256)
    tiling = pick_conv_spatial_tiling(*args)
    assert tiling is not None
    inner, bh, bn, n_copies = tiling
    assert conv_tiled_vmem_bytes(*args[:-1], 256, inner=inner, bh=bh,
                                 bn=bn) <= 12 << 20
    # a taller band would not have fit (the pick is the tallest feasible)
    if bh < 64:
        assert conv_tiled_vmem_bytes(*args[:-1], 256, inner=inner, bh=bh + 1,
                                     bn=bn) > 12 << 20
    # LUT alone (256 KiB) over budget -> no feasible band
    assert pick_conv_spatial_tiling(*args, budget=128 << 10) is None


# ---------------------------------------------------------------------------
# property-based tiling harness: hypothesis strategy over ConvSpec geometry
# (offline via tests/_hypothesis_compat.py)
# ---------------------------------------------------------------------------

_BIASED_MULT = dataclasses.replace(
    make_exact(8), name="mul8s_biased",
    fn=lambda a, w: a.astype(jnp.int32) * w.astype(jnp.int32) + 7)
_BIASED_LUT = jnp.asarray(build_lut(_BIASED_MULT))
ACU_BIASED = dataclasses.replace(
    make_acu("mul8s_exact", AcuMode.LUT, use_pallas=True, fused=True),
    multiplier=_BIASED_MULT, lut=build_lut(_BIASED_MULT))


@settings(max_examples=8, deadline=None)
@given(
    h=st.integers(6, 18),
    w=st.integers(5, 17),
    c=st.integers(1, 9),
    cout=st.integers(1, 9),
    k=st.sampled_from([1, 3, 5]),          # odd kernels
    sh=st.integers(1, 3),
    sw=st.integers(1, 3),
    dh=st.integers(1, 2),
    dw=st.integers(1, 2),
    same=st.sampled_from([True, False]),
    bh=st.integers(1, 4),                  # pinned band height under test
    groups=st.sampled_from([1, 1, 1, 2]),
    biased=st.sampled_from([False, True]),
)
def test_property_tiled_whole_oracle_bitwise(h, w, c, cout, k, sh, sw, dh,
                                             dw, same, bh, groups, biased):
    """Property harness over ConvSpec geometry: for every drawn (H, W, C,
    Cout, kernel, stride, dilation, padding, band height, multiplier bias)
    the spatially-tiled kernel, the whole-image kernel, and the eager
    im2col + fused_lut_dense oracle agree BITWISE, eager and jit; and plan
    resolution against a budget the whole image exceeds picks the tiled
    route exactly when a feasible banding exists. Grouped draws assert the
    preserved vmapped-GEMM route instead (the fused kernels serve groups=1).
    """
    if groups != 1:
        assume(c % groups == 0 and cout % groups == 0)
    x_shape = (2, c, h, w)
    w_shape = (cout, c // groups, k, k)
    stride, dil = (sh, sw), (dh, dw)
    padding = "SAME" if same else "VALID"
    pad = resolve_conv_padding(padding, x_shape, w_shape, stride, dil)
    from repro.kernels.fused_lut_conv.ops import conv_out_size
    ho = conv_out_size(h, k, sh, dh, pad[0])
    wo = conv_out_size(w, k, sw, dw, pad[1])
    assume(ho >= 1 and wo >= 1)
    seed = (h * 31 + w * 17 + c * 13 + cout * 11 + k * 7 + sh * 5 + sw * 3
            + dh * 2 + dw + bh + groups + int(biased))
    x, wt = _conv_operands(x_shape, w_shape, seed=seed)
    spec = ConvSpec(x_shape=x_shape, w_shape=w_shape, stride=stride,
                    padding=pad, dilation=dil, groups=groups)
    acu = ACU_BIASED if biased else ACU_FUSED
    cfg = ApproxConfig(acu=acu)

    if groups != 1:
        plan = conv_plan(acu, spec, fused=True)
        assert plan.route in ("im2col_grouped", "im2col_depthwise")
        y = conv2d(x, wt, None, cfg=cfg, stride=stride, padding=padding,
                   dilation=dil, groups=groups)
        y2 = conv2d(x, wt, None, cfg=cfg, stride=stride, padding=padding,
                    dilation=dil, groups=groups, route="im2col")
        assert jnp.array_equal(y, y2)
        return

    lut = _BIASED_LUT if biased else LUT
    xqp, wqp, wq = _quantized_operands(x, wt)
    geom = dict(stride=stride, padding=pad, dilation=dil, bits=8)
    ref = fused_lut_conv_ref(x, wq, lut.reshape(-1), 128, 256, xqp.scale,
                             xqp.zero_point, wqp.scale, **geom)
    whole = fused_lut_conv(x, wq, lut, 128, xqp.scale, xqp.zero_point,
                           wqp.scale, interpret=True, **geom)
    tiled = fused_lut_conv_tiled(x, wq, lut, 128, xqp.scale, xqp.zero_point,
                                 wqp.scale, bh=bh, interpret=True, **geom)
    assert jnp.array_equal(whole, ref)
    assert jnp.array_equal(tiled, ref)
    j_t = jax.jit(lambda x, wq, xs, xz, ws: fused_lut_conv_tiled(
        x, wq, lut, 128, xs, xz, ws, bh=bh, interpret=True, **geom))(
            x, wq, xqp.scale, xqp.zero_point, wqp.scale)
    j_w = jax.jit(lambda x, wq, xs, xz, ws: fused_lut_conv(
        x, wq, lut, 128, xs, xz, ws, interpret=True, **geom))(
            x, wq, xqp.scale, xqp.zero_point, wqp.scale)
    j_r = jax.jit(lambda x, wq, xs, xz, ws: fused_lut_conv_ref(
        x, wq, lut.reshape(-1), 128, 256, xs, xz, ws, **geom))(
            x, wq, xqp.scale, xqp.zero_point, wqp.scale)
    assert jnp.array_equal(j_t, j_r)
    assert jnp.array_equal(j_w, j_r)

    # plan resolution: shrink the budget below the whole-image working set;
    # the plan must pick the tiled route iff a feasible banding exists
    from repro.kernels.fused_lut_conv.ops import (conv_vmem_bytes,
                                                  pick_conv_spatial_tiling)
    gargs = (c, h, w, cout, k, k, sh, sw, dh, dw, pad, 256)
    budget = conv_vmem_bytes(*gargs) - 1
    plan = conv_plan(acu, spec, fused=True, vmem_budget=budget)
    tiling = pick_conv_spatial_tiling(*gargs, budget=budget)
    if tiling is None:
        assert plan.route == "im2col"
        assert any("degenerate" in r for r in plan.report)
    else:
        assert plan.route == "tiled"
        assert plan.tiling == tiling
        out = plan(x, wq, xqp.scale, xqp.zero_point, wqp.scale)
        assert jnp.array_equal(out, ref)


@pytest.mark.slow
def test_imagenet_scale_conv_resolves_tiled_and_matches_oracle():
    """The PR 4 acceptance geometry: a 1x64x224x224 conv2d resolves to
    route="tiled" under the default budget (no im2col fallback anywhere in
    the plan report) and is bitwise identical to the eager im2col +
    fused_lut_dense oracle."""
    rep = conv_plan_report((1, 64, 224, 224), (64, 64, 3, 3), CFG)
    assert rep["route"] == "tiled"
    assert rep["tiling"] is not None
    assert not any("im2col" in r for r in rep["report"])
    x, w = _conv_operands((1, 64, 224, 224), (64, 64, 3, 3), seed=224)
    y_t = conv2d(x, w, None, cfg=CFG)
    y_o = conv2d(x, w, None, cfg=CFG, route="im2col")
    assert jnp.array_equal(y_t, y_o)


def test_conv2d_separable_still_works():
    """separable_conv2d composes the depthwise and pointwise plans; the
    pointwise half rides the fused kernel."""
    x, _ = _conv_operands((1, 4, 8, 8), (1, 1, 1, 1), seed=8)
    rng = np.random.default_rng(8)
    wdw = jnp.asarray(rng.normal(size=(4, 1, 3, 3)), jnp.float32)
    wpw = jnp.asarray(rng.normal(size=(6, 4, 1, 1)), jnp.float32)
    from repro.core.approx_ops import separable_conv2d
    out = separable_conv2d(x, wdw, wpw, cfg=CFG)
    assert out.shape == (1, 6, 8, 8)
    assert bool(jnp.isfinite(out).all())


# ---------------------------------------------------------------------------
# approximate backward: banded weight-grad kernel + conv STE approx_bwd
# ---------------------------------------------------------------------------

from repro.core.approx_ops import _conv_qparams, _im2col
from repro.core.quantization import fake_quantize, inline_symmetric_scale, \
    pin_rounding
from repro.kernels.fused_lut_conv.ops import conv_out_size, \
    fused_lut_conv_bwd_w


def _oracle_bwd_w(acu, xf, g, sx, sg, ksize, stride, padding, dilation):
    """quantize -> im2col of CODES -> unfused LUT GEMM: the materialized
    oracle the banded kernel must reproduce bitwise (int accumulators)."""
    kh, kw = ksize
    qx = jnp.clip(jnp.round(xf.astype(jnp.float32) / sx), -128, 127)
    qg = jnp.clip(jnp.round(g.astype(jnp.float32) / sg), -128,
                  127).astype(jnp.int32)
    cols, _ = _im2col(qx, kh, kw, stride, padding, dilation)  # pads -> code 0
    cols = cols.astype(jnp.int32).reshape(-1, cols.shape[-1])
    g2 = qg.reshape(-1, g.shape[3])
    acc = acu._lut_matmul_jnp(cols.T, g2, k_chunk=min(256, cols.shape[0]))
    c = xf.shape[1]
    return acc.reshape(c, kh * kw, g.shape[3]).transpose(1, 0, 2)


@pytest.mark.parametrize("geom", [
    # (n, c, h, w, cout, (kh, kw), stride, dilation, padding)
    (2, 3, 9, 11, 5, (3, 3), (1, 1), (1, 1), ((1, 1), (1, 1))),
    (1, 4, 12, 10, 7, (3, 2), (2, 1), (1, 2), ((0, 0), (1, 0))),
    (2, 2, 8, 8, 3, (2, 2), (2, 2), (1, 1), ((0, 0), (0, 0))),
    (1, 5, 14, 9, 6, (3, 3), (1, 2), (2, 1), ((2, 2), (1, 1))),
])
@pytest.mark.parametrize("bh", [0, 1, 3])
def test_bwd_w_kernel_matches_im2col_oracle(geom, bh):
    """Banded weight-grad kernel (patch rows streamed per output-row band,
    invalid rows masked in-kernel) == materialized im2col-code oracle,
    bitwise on the int32 accumulator, across stride/dilation/asymmetric-pad
    geometry and band heights (bh=0 lets the VMEM model pick)."""
    n, c, h, w, cout, ksize, stride, dil, pad = geom
    rng = np.random.default_rng(sum(ksize) + n + c + h)
    xf = jnp.asarray(rng.standard_normal((n, c, h, w)), jnp.float32)
    ho = conv_out_size(h, ksize[0], stride[0], dil[0], pad[0])
    wo = conv_out_size(w, ksize[1], stride[1], dil[1], pad[1])
    g = jnp.asarray(rng.standard_normal((n, ho, wo, cout)), jnp.float32)
    sx = inline_symmetric_scale(jnp.max(jnp.abs(xf)), 8)
    sg = inline_symmetric_scale(jnp.max(jnp.abs(g)), 8)
    ref = _oracle_bwd_w(ACU_FUSED, xf, g, sx, sg, ksize, stride, pad, dil)
    got = fused_lut_conv_bwd_w(xf, g, LUT, 128, sx, sg, ksize=ksize,
                               stride=stride, padding=pad, dilation=dil,
                               bits=8, bh=bh, interpret=True)
    assert got.dtype == jnp.int32
    assert jnp.array_equal(got, ref)


def test_bwd_w_kernel_biased_m00_masks_invalid_rows():
    """Biased multiplier (M[0,0] = 7): band-alignment pad rows would each
    leak a non-constant LUT[qx, off] sum — the in-kernel row mask must kill
    them exactly (no post-hoc correction can)."""
    rng = np.random.default_rng(4)
    xf = jnp.asarray(rng.standard_normal((1, 3, 9, 8)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((1, 7, 6, 4)), jnp.float32)
    sx = inline_symmetric_scale(jnp.max(jnp.abs(xf)), 8)
    sg = inline_symmetric_scale(jnp.max(jnp.abs(g)), 8)
    ref = _oracle_bwd_w(ACU_BIASED, xf, g, sx, sg, (3, 3), (1, 1),
                        ((0, 0), (0, 0)), (1, 1))
    for bh in (2, 3):   # 7 rows: both leave partial last bands
        got = fused_lut_conv_bwd_w(xf, g, _BIASED_LUT, 128, sx, sg,
                                   ksize=(3, 3), stride=(1, 1),
                                   padding=((0, 0), (0, 0)), dilation=(1, 1),
                                   bits=8, bh=bh, interpret=True)
        assert jnp.array_equal(got, ref)


def _oracle_approx_grads(acu, x, w, g_nchw, cfg, stride, padding, dilation):
    """Unfused approximate-backward oracle for the conv STE: quantize
    globally -> code im2col -> int LUT GEMMs -> int scatter (gx) -> ONE
    combined-scale dequant per grad."""
    n, cin, h, w_in = x.shape
    cout, _, kh, kw = w.shape
    sh, sw = stride
    dh, dw = dilation
    (ph0, ph1), (pw0, pw1) = padding
    xqp, wqp = _conv_qparams(x, w, cfg, None, None)
    xf = fake_quantize(x, xqp).astype(jnp.float32)
    wf = fake_quantize(w, wqp).astype(jnp.float32)
    g = g_nchw.transpose(0, 2, 3, 1).astype(jnp.float32)
    ho, wo = g.shape[1:3]
    sg = inline_symmetric_scale(jnp.max(jnp.abs(g)), 8)
    sx = inline_symmetric_scale(jnp.max(jnp.abs(xf)), 8)
    sw_s = inline_symmetric_scale(jnp.max(jnp.abs(wf)), 8)
    accw = _oracle_bwd_w(acu, xf, g, sx, sg, (kh, kw), stride, padding,
                         dilation)
    gw = (accw.astype(jnp.float32) * pin_rounding(sx * sg)
          ).transpose(2, 1, 0).reshape(cout, cin, kh, kw)
    qg = jnp.clip(jnp.round(g / sg), -128, 127).astype(jnp.int32)
    qw = jnp.clip(jnp.round(wf / sw_s), -128, 127).astype(jnp.int32)
    accx = acu._lut_matmul_jnp(qg.reshape(-1, cout), qw.reshape(cout, -1),
                               k_chunk=min(256, cout))
    accx = accx.reshape(n, ho, wo, cin, kh, kw)
    canvas = jnp.zeros((n, cin, h + ph0 + ph1, w_in + pw0 + pw1), jnp.int32)
    for u in range(kh):
        for v in range(kw):
            canvas = canvas.at[
                :, :, u * dh:u * dh + (ho - 1) * sh + 1:sh,
                v * dw:v * dw + (wo - 1) * sw + 1:sw,
            ].add(accx[:, :, :, :, u, v].transpose(0, 3, 1, 2))
    canvas = canvas[:, :, ph0:ph0 + h, pw0:pw0 + w_in]
    gx = canvas.astype(jnp.float32) * pin_rounding(sg * sw_s)
    return gx, gw


@pytest.mark.parametrize("geom", [
    ((2, 3, 9, 11), (5, 3, 3, 3), (1, 1), "SAME", (1, 1)),
    ((1, 4, 12, 10), (7, 4, 3, 2), (2, 1), "VALID", (1, 2)),
])
def test_conv2d_approx_bwd_matches_unfused_oracle(geom):
    """End-to-end jax.vjp through conv2d with cfg.approx_bwd: the banded
    fused backward (weight-grad kernel + per-band gx GEMMs scattering int32)
    equals the materialized unfused composition bitwise, eager and jit. The
    im2col patch tensor never exists in HBM on the fused route."""
    x_shape, w_shape, stride, padding, dil = geom
    rng = np.random.default_rng(x_shape[2])
    cfg = ApproxConfig(acu=ACU_FUSED, approx_bwd=True)
    x = jnp.asarray(rng.standard_normal(x_shape), jnp.float32)
    w = jnp.asarray(rng.standard_normal(w_shape), jnp.float32)
    pad = resolve_conv_padding(padding, x_shape, w_shape, stride, dil)
    spec = ConvSpec(x_shape=x_shape, w_shape=w_shape, stride=stride,
                    padding=pad, dilation=dil)
    plan = conv_plan(ACU_FUSED, spec, a_bits=8, fused=True, mesh=False)
    assert plan.bwd_route == "banded"

    def f(x, w):
        return conv2d(x, w, stride=stride, padding=padding, dilation=dil,
                      cfg=cfg)

    y, vjp = jax.vjp(f, x, w)
    g = jnp.asarray(rng.standard_normal(y.shape), jnp.float32)
    gx, gw = vjp(g)
    ogx, ogw = _oracle_approx_grads(ACU_FUSED, x, w, g, cfg, stride, pad, dil)
    assert jnp.array_equal(gx, ogx)
    assert jnp.array_equal(gw, ogw)
    gx_j, gw_j = jax.jit(lambda x, w, g: jax.vjp(f, x, w)[1](g))(x, w, g)
    assert jnp.array_equal(gx, gx_j) and jnp.array_equal(gw, gw_j)


def test_conv_plan_resolves_bwd_route():
    """Fused plans resolve a banded bwd_route + tiling under the VMEM budget;
    unfused plans carry none."""
    spec = ConvSpec(x_shape=(1, 8, 16, 16), w_shape=(8, 8, 3, 3),
                    stride=(1, 1), padding=((1, 1), (1, 1)), dilation=(1, 1))
    plan = conv_plan(ACU_FUSED, spec, a_bits=8, fused=True, mesh=False)
    assert plan.bwd_route == "banded" and plan.bwd_tiling is not None
    assert "bwd_route" in plan.describe()
    acu_unfused = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    plan_u = conv_plan(acu_unfused, spec, a_bits=8, fused=False, mesh=False)
    assert plan_u.bwd_route is None
