"""Affine quantization properties (hypothesis) + STE gradients."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, strategies as st

from repro.core.quantization import (QParams, acu_operand, affine_qparams,
                                     dequantize, fake_quantize, quantize,
                                     symmetric_qparams)

floats = st.floats(-100.0, 100.0, allow_nan=False, width=32,
                   allow_subnormal=False)


@given(x=st.lists(floats, min_size=1, max_size=64),
       bits=st.sampled_from([4, 8, 12]))
def test_quant_dequant_error_bound(x, bits):
    """Round-trip error <= scale/2 inside the clip range."""
    x = jnp.asarray(x, jnp.float32)
    amax = float(jnp.max(jnp.abs(x)))
    qp = symmetric_qparams(jnp.float32(max(amax, 1e-6)), bits)
    err = jnp.abs(dequantize(quantize(x, qp), qp) - x)
    assert float(err.max()) <= float(qp.scale) / 2 + 1e-6


@given(bits=st.sampled_from([4, 8, 12]))
def test_zero_is_exact(bits):
    """Affine quantization must represent 0.0 exactly (padding correctness)."""
    qp = affine_qparams(jnp.float32(-3.0), jnp.float32(5.0), bits)
    z = dequantize(quantize(jnp.zeros(4), qp), qp)
    assert float(jnp.abs(z).max()) == 0.0


@given(lo=st.floats(-50.0, -0.001953125, width=32, allow_subnormal=False),
       hi=st.floats(0.001953125, 50.0, width=32, allow_subnormal=False))
def test_affine_range_covered(lo, hi):
    qp = affine_qparams(jnp.float32(lo), jnp.float32(hi), 8)
    x = jnp.asarray([lo, hi, 0.0], jnp.float32)
    back = dequantize(quantize(x, qp), qp)
    # zero_point rounding adds up to scale/2 on top of value rounding
    assert float(jnp.abs(back - x).max()) <= float(qp.scale) * 1.51


def test_per_channel_weights(rng):
    w = jnp.asarray(rng.normal(size=(16, 8)) * np.array([1e-3] * 4 + [10.0] * 4)[None, :],
                    jnp.float32)
    from repro.core.calibration import calibrate_weight
    qp = calibrate_weight(w, 8, axis=1)
    assert qp.scale.shape == (8,)
    err = jnp.abs(dequantize(quantize(w, qp), qp) - w)
    # per-channel: each channel's error bounded by its own scale/2
    assert float(err[:, :4].max()) < 1e-4
    assert float(err[:, 4:].max()) < float(qp.scale[4:].max()) / 2 + 1e-6


def test_acu_operand_shifts_zero_point():
    qp = QParams(scale=jnp.float32(0.1), zero_point=jnp.float32(3.0), bits=8)
    q = quantize(jnp.asarray([0.0]), qp)
    assert int(acu_operand(q, qp)[0]) == 0  # real 0 -> integer operand 0


def test_ste_gradient():
    qp = symmetric_qparams(jnp.float32(1.0), 8)

    def f(x):
        return fake_quantize(x, qp).sum()

    g = jax.grad(f)(jnp.asarray([0.5, -0.3, 5.0, -5.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0, 0.0])


def test_fake_quant_matches_quant_dequant(rng):
    x = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    qp = symmetric_qparams(jnp.float32(2.0), 8)
    np.testing.assert_allclose(np.asarray(fake_quantize(x, qp)),
                               np.asarray(dequantize(quantize(x, qp), qp)),
                               rtol=1e-6, atol=1e-6)
