"""Gradient-noise batch damping: estimator math, schedule dynamics, trainer
integration (microbatch accumulation + the data-parallel mesh path), and the
determinism contracts — damped sharded step == single-device oracle bitwise,
damped kill-and-resume == uninterrupted run bitwise.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import damping as D
from repro.optim.adamw import AdamW, SGD
from repro.train.trainer import Trainer, TrainerConfig

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ---------------------------------------------------------------------------
# estimator math
# ---------------------------------------------------------------------------

def test_noise_scale_inverts_the_noise_model():
    """E[|G_B|^2] = |G|^2 + S/B is linear in 1/B; feeding the estimator the
    model's exact expectations must return (S, |G|^2) exactly."""
    s_true, g2_true = 48.0, 3.0
    for b_small, b_big in [(2, 4), (8, 64), (1, 7)]:
        gsq_small = g2_true + s_true / b_small
        gsq_big = g2_true + s_true / b_big
        s, g2 = D.noise_scale(gsq_small, gsq_big, b_small, b_big)
        assert abs(s - s_true) < 1e-9
        assert abs(g2 - g2_true) < 1e-9


def test_noise_scale_statistical_recovery():
    """Monte-Carlo: i.i.d. per-sample gradients with known mean/variance."""
    rng = np.random.default_rng(0)
    dim, g = 64, rng.normal(size=64)
    sigma2 = 4.0
    b_small, b_big, trials = 4, 32, 4000
    small_sq = big_sq = 0.0
    for _ in range(trials):
        noise = rng.normal(scale=np.sqrt(sigma2), size=(b_big, dim))
        per = g[None] + noise
        small_sq += float((np.mean(per[:b_small], 0) ** 2).sum())
        big_sq += float((np.mean(per, 0) ** 2).sum())
    s, g2 = D.noise_scale(small_sq / trials, big_sq / trials, b_small, b_big)
    s_true = sigma2 * dim          # trace of the per-sample covariance
    g2_true = float((g ** 2).sum())
    assert abs(s - s_true) / s_true < 0.1
    assert abs(g2 - g2_true) / g2_true < 0.1


def test_tree_sqnorm():
    t = {"a": jnp.array([3.0, 4.0]), "b": {"c": jnp.array([[2.0]])}}
    assert float(D.tree_sqnorm(t)) == pytest.approx(29.0)


def test_microbatch_noise_stats():
    grads = {"w": jnp.array([1.0, 2.0])}
    st = D.microbatch_noise_stats(jnp.float32(40.0), grads, b_small=4,
                                  b_big=16)
    assert float(st.gsq_small) == pytest.approx(10.0)   # sum over 4 micros
    assert float(st.gsq_big) == pytest.approx(5.0)
    assert (st.b_small, st.b_big) == (4, 16)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def _stats(b_noise, b_small=4, b_big=8, g2=1.0):
    """Stats whose exact two-point inversion yields S = b_noise * g2."""
    s = b_noise * g2
    return D.NoiseStats(gsq_small=g2 + s / b_small, gsq_big=g2 + s / b_big,
                        b_small=b_small, b_big=b_big)


def test_schedule_growth_is_rate_limited():
    cfg = D.DampingConfig(accum_max=16, warmup_updates=2, ema=0.0,
                          max_growth=2)
    st = D.init_state(cfg)
    noisy = _stats(b_noise=1024.0)
    st = D.update_state(st, cfg, noisy, batch_size=8)
    assert st.accum == 1                       # warming up
    seen = []
    for _ in range(6):
        st = D.update_state(st, cfg, noisy, batch_size=8)
        seen.append(st.accum)
    assert seen == [2, 4, 8, 16, 16, 16]       # doubles, then caps


def test_schedule_grow_only_holds_under_quiet_gradients():
    cfg = D.DampingConfig(accum_max=8, warmup_updates=0, ema=0.0)
    st = D.DampingState(accum=4)
    st = D.update_state(st, cfg, _stats(b_noise=1.0), batch_size=8)
    assert st.accum == 4                       # grow_only: no shrink
    cfg2 = D.DampingConfig(accum_max=8, warmup_updates=0, ema=0.0,
                           grow_only=False)
    st2 = D.update_state(D.DampingState(accum=4), cfg2,
                         _stats(b_noise=1.0), batch_size=8)
    assert st2.accum == 2                      # shrink also rate-limited


def test_residual_energy_inflates_noise():
    cfg = D.DampingConfig(warmup_updates=0, ema=0.0, residual_weight=1.0)
    quiet = _stats(b_noise=4.0)
    st_plain = D.update_state(D.init_state(cfg), cfg, quiet, batch_size=1)
    loud = quiet._replace(resid_sq=jnp.float32(10.0))
    st_resid = D.update_state(D.init_state(cfg), cfg, loud, batch_size=1)
    assert st_resid.b_noise > st_plain.b_noise


def test_state_json_roundtrip():
    cfg = D.DampingConfig()
    st = D.update_state(D.init_state(cfg), cfg, _stats(64.0), batch_size=8)
    st2 = D.DampingState.from_dict(json.loads(json.dumps(st.to_dict())))
    assert st2 == st
    # and the schedule continues identically from the round-tripped state
    a = D.update_state(st, cfg, _stats(64.0), batch_size=8)
    b = D.update_state(st2, cfg, _stats(64.0), batch_size=8)
    assert a == b


# ---------------------------------------------------------------------------
# trainer integration (single device)
# ---------------------------------------------------------------------------

def _regression_problem(noise=2.0, dim=8, seed=0):
    """Noisy linear regression: per-sample gradient noise is controllable."""
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(dim,)).astype(np.float32)

    def batches(batch, seed=1):
        r = np.random.default_rng(seed)
        while True:
            x = r.normal(size=(batch, dim)).astype(np.float32)
            y = (x @ w_true + noise * r.normal(size=batch)).astype(np.float32)
            yield {"x": x, "y": y}

    def loss_fn(params, b):
        pred = b["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - b["y"]) ** 2)

    params = {"w": jnp.zeros(dim, jnp.float32), "b": jnp.zeros((), jnp.float32)}
    return params, loss_fn, batches


def test_microbatch_matches_full_batch():
    """cfg.microbatch=k accumulates to the same step as one full-batch pass
    (same mean loss/grads up to fp reassociation)."""
    params, loss_fn, batches = _regression_problem()
    outs = []
    for k in (0, 2, 4):
        tr = Trainer(loss_fn, SGD(lr=0.05),
                     TrainerConfig(microbatch=k, log_every=1), donate=False)
        p, _ = tr.fit(jax.tree.map(jnp.copy, params), SGD(lr=0.05).init(params),
                      batches(16, seed=3), n_steps=5)
        outs.append(p)
    for p in outs[1:]:
        for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(p)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)


def test_microbatch_non_divisible_raises():
    params, loss_fn, batches = _regression_problem()
    tr = Trainer(loss_fn, SGD(lr=0.05), TrainerConfig(microbatch=3))
    with pytest.raises(ValueError, match="does not divide"):
        tr.fit(params, SGD(lr=0.05).init(params), batches(16), n_steps=1)


def test_microbatch_loss_accumulator_is_float32():
    """The scan carry pins fp32 even when the loss comes back half-precision
    (a weak-typed 0.0 used to inherit bf16 and quantize the accumulation)."""
    params, loss_fn, batches = _regression_problem()
    bf16_loss = lambda p, b: loss_fn(p, b).astype(jnp.bfloat16)
    tr = Trainer(bf16_loss, SGD(lr=0.05),
                 TrainerConfig(microbatch=4, log_every=1), donate=False)
    tr.fit(params, SGD(lr=0.05).init(params), batches(16, seed=3), n_steps=1)
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses and np.isfinite(losses[0])


def test_damping_forbids_fixed_microbatch():
    params, loss_fn, _ = _regression_problem()
    with pytest.raises(ValueError, match="damping"):
        Trainer(loss_fn, SGD(lr=0.05),
                TrainerConfig(microbatch=4, damping=D.DampingConfig()))


def test_damped_trainer_grows_effective_batch():
    """High per-sample noise + tiny batch => B_noise >> batch => the trainer
    must grow its accumulation factor and consume extra batches."""
    params, loss_fn, batches = _regression_problem(noise=8.0)
    cfg = TrainerConfig(log_every=1,
                        damping=D.DampingConfig(accum_max=8, warmup_updates=1,
                                                ema=0.5))
    tr = Trainer(loss_fn, SGD(lr=0.01), cfg, donate=False)
    tr.fit(params, SGD(lr=0.01).init(params), batches(4, seed=2), n_steps=12)
    assert tr.damp_state.accum > 1
    assert tr.consumed > 12                    # accum>1 steps drew extra
    accums = [h["accum"] for h in tr.history if "accum" in h]
    assert accums == sorted(accums)            # grow_only is monotone


def test_damped_resume_matches_uninterrupted(tmp_path):
    """Kill-and-resume of a DAMPED run reproduces the uninterrupted run
    exactly: params bitwise, consumed count, and schedule state."""
    params, loss_fn, batches = _regression_problem(noise=6.0)
    dcfg = D.DampingConfig(accum_max=4, warmup_updates=1, ema=0.5)
    opt = SGD(lr=0.01)

    def mk(ckpt):
        return Trainer(loss_fn, opt,
                       TrainerConfig(ckpt_dir=ckpt, ckpt_every=5,
                                     async_ckpt=False, log_every=1,
                                     damping=dcfg), donate=False)

    tr0 = mk(str(tmp_path / "clean"))
    p_clean, _ = tr0.fit(jax.tree.map(jnp.copy, params), opt.init(params),
                         batches(4, seed=2), n_steps=20)

    tr1 = mk(str(tmp_path / "killed"))
    tr1.fit(jax.tree.map(jnp.copy, params), opt.init(params),
            batches(4, seed=2), n_steps=10)
    tr2 = mk(str(tmp_path / "killed"))      # fresh process stand-in
    p_res, _ = tr2.fit(jax.tree.map(jnp.copy, params), opt.init(params),
                       batches(4, seed=2), n_steps=20)

    assert tr2.consumed == tr0.consumed
    assert tr2.damp_state == tr0.damp_state
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# mesh path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    from repro.launch.mesh import make_host_multi_mesh
    return make_host_multi_mesh((2, 4))


@needs_8_devices
def test_compressed_psum_stats_pair(mesh):
    """with_stats exports the free estimator pair: mean per-worker |g|^2,
    |mean|^2, residual energy — and the noisier the shards, the wider the
    small/large gap."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim.compression import EFState, compressed_psum

    W = 2
    rng = np.random.default_rng(0)
    g = rng.normal(size=(W, 16)).astype(np.float32)

    def worker(gs, rs):
        summed, ef, stats = compressed_psum(
            {"g": gs[0]}, EFState(residual={"g": rs[0]}), "data",
            with_stats=True)
        return (jax.tree.map(lambda x: x[None], summed),
                jax.tree.map(lambda x: x[None], ef.residual),
                jax.tree.map(lambda x: jnp.reshape(x, (1,)), stats))

    f = shard_map(worker, mesh=mesh,
                  in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data"),
                             jax.tree.map(lambda _: P("data"), {
                                 "gsq_small": 0, "gsq_big": 0,
                                 "resid_sq": 0})),
                  check_rep=False)
    summed, resid, stats = f(jnp.asarray(g), jnp.zeros_like(jnp.asarray(g)))
    mean = np.asarray(summed["g"])[0]
    small = float(np.asarray(stats["gsq_small"])[0])
    big = float(np.asarray(stats["gsq_big"])[0])
    assert small == pytest.approx(float((g ** 2).sum(1).mean()), rel=1e-5)
    assert big == pytest.approx(float((mean ** 2).sum()), rel=1e-5)
    assert small > big                         # disagreeing shards
    # residual energy (what int8 dropped) is reported and finite
    assert np.isfinite(np.asarray(stats["resid_sq"])[0])
    assert np.isfinite(np.asarray(resid["g"])).all()


@needs_8_devices
def test_dp_damped_step_bitwise_matches_single_device_oracle(mesh):
    """The acceptance pin: one damped data-parallel step on the 2x4 mesh is
    BITWISE the single-device oracle that replays its semantics — per-shard
    grads, shared-amax int8 codes, int32 sum x scale/W, same AdamW update.
    The int-space psum in compressed_psum is what makes this exact."""
    from repro.optim.compression import compress, decompress

    params, loss_fn, batches = _regression_problem(noise=4.0)
    opt = AdamW(lr=1e-2)
    W = 2                                      # dp_axes=("data",) on 2x4
    batch = next(batches(8, seed=5))

    tr = Trainer(loss_fn, opt, TrainerConfig(mesh=mesh), donate=False)
    p_mesh, o_mesh, loss_mesh, _ = tr._run_step(
        jax.tree.map(jnp.copy, params), opt.init(params),
        {k: jnp.asarray(v) for k, v in batch.items()}, n_micro=1)

    # ---- single-device oracle ----
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    shards = [{k: jnp.asarray(v[i * 4:(i + 1) * 4]) for k, v in batch.items()}
              for i in range(W)]
    per = [grad_fn(params, s)[1] for s in shards]
    leaves = [jax.tree.leaves(g) for g in per]
    mean_leaves = []
    for li in range(len(leaves[0])):
        gs = [leaves[w][li].astype(jnp.float32) for w in range(W)]
        amax = jnp.max(jnp.stack([jnp.max(jnp.abs(g)) for g in gs]))
        qs = [compress(g, amax) for g in gs]
        scale = qs[0][1]
        q_sum = sum(q[0].astype(jnp.int32) for q in qs)
        mean_leaves.append(q_sum.astype(jnp.float32) * (scale / W))
    mean = jax.tree.unflatten(jax.tree.structure(per[0]), mean_leaves)
    p_one, o_one = jax.jit(opt.update)(mean, opt.init(params), params)

    for a, b in zip(jax.tree.leaves(p_mesh), jax.tree.leaves(p_one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(o_mesh), jax.tree.leaves(o_one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@needs_8_devices
def test_dp_damped_trainer_runs_and_grows(mesh):
    """End-to-end damped data-parallel fit: schedule grows off the mesh's
    per-worker noise pair and the loss still falls."""
    params, loss_fn, batches = _regression_problem(noise=8.0)
    cfg = TrainerConfig(mesh=mesh, log_every=1,
                        damping=D.DampingConfig(accum_max=4, warmup_updates=1,
                                                ema=0.5))
    opt = SGD(lr=0.01)
    tr = Trainer(loss_fn, opt, cfg, donate=False)
    tr.fit(params, opt.init(params), batches(8, seed=2), n_steps=10)
    assert tr.damp_state.updates > 0
    assert tr.damp_state.b_noise > 0
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0]


@needs_8_devices
@pytest.mark.tier2
def test_mesh_wide_damped_qat_recovery(mesh):
    """Long tier-2 run: mesh-wide QAT recovery through the approximate
    forward/backward with damping on reaches the fixed-batch run's recovered
    loss using no more samples (the BENCH_PR9 sample-efficiency claim,
    in miniature)."""
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig
    from repro.data.pipeline import image_task
    from repro.models.vision import cnn_forward, init_cnn

    acfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT),
                        approx_bwd=True)

    def loss_fn(p, b):
        logits = cnn_forward(p, b["image"], acfg)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, b["label"][:, None], -1)[:, 0]
        return (logz - gold).mean()

    task = image_task(n_classes=4, size=8)
    params = init_cnn(jax.random.PRNGKey(0), n_classes=4, width=8, in_ch=3,
                      img=8)
    opt = SGD(lr=1e-2)

    def run(damping):
        tr = Trainer(loss_fn, opt,
                     TrainerConfig(mesh=mesh, log_every=1, damping=damping),
                     donate=False)
        p0 = jax.tree.map(jnp.copy, params)
        tr.fit(p0, opt.init(p0),
               ({k: jnp.asarray(v) for k, v in b.items()}
                for b in task(16, noise=0.5, seed=2)), n_steps=15)
        losses = [h["loss"] for h in tr.history if "loss" in h]
        return losses, tr.consumed * 16

    fixed_losses, fixed_samples = run(None)
    damped_losses, damped_samples = run(
        D.DampingConfig(accum_max=4, warmup_updates=2, ema=0.5))
    assert damped_losses[-1] <= fixed_losses[0]     # it recovered
    assert np.isfinite(damped_losses).all()
