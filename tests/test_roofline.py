"""Roofline extractor: HLO collective parsing + two-point combination."""
from repro.launch.roofline import (CellCost, collective_bytes, model_flops,
                                   two_point)

FAKE_HLO = """
  %ar = f32[256,1024]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %ag = bf16[512,2048]{1,0} all-gather(%y), dimensions={0}
  %rs.1 = f32[64]{0} reduce-scatter(%z), dimensions={0}
  %nota = f32[8]{0} add(%a, %b)
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = (f32[16,16]{1,0}) all-to-all(%q), dimensions={1}
"""


def test_collective_parsing():
    out = collective_bytes(FAKE_HLO)
    assert out["all-reduce"] == 256 * 1024 * 4
    assert out["all-gather"] == 512 * 2048 * 2
    assert out["reduce-scatter"] == 64 * 4
    assert out["collective-permute"] == 128
    assert out["all-to-all"] == 16 * 16 * 4
    assert "add" not in out


def test_async_start_not_double_counted():
    text = """
  %s = f32[100]{0} all-reduce-start(%x), to_apply=%sum
  %d = f32[100]{0} all-reduce-done(%s)
"""
    out = collective_bytes(text)
    assert out.get("all-reduce", 0) == 400  # start counted, done skipped


def make_cost(flops, by, coll):
    return CellCost(flops=flops, bytes_accessed=by, coll_bytes=coll,
                    coll_breakdown={"all-reduce": coll}, peak_memory=1e9,
                    arg_bytes=5e8)


def test_two_point_scaling():
    u1 = make_cost(100.0, 1000.0, 10.0)   # outside + 1 group
    u2 = make_cost(160.0, 1500.0, 14.0)   # outside + 2 groups
    total = two_point(u1, u2, n_groups=10)
    assert total.flops == 100 + 9 * 60
    assert total.bytes_accessed == 1000 + 9 * 500
    assert total.coll_bytes == 10 + 9 * 4
    assert total.peak_memory == u1.peak_memory


def test_bottleneck_and_terms():
    c = make_cost(197e12 * 0.5, 819e9 * 0.1, 50e9 * 0.2)
    assert abs(c.t_compute - 0.5) < 1e-9
    assert abs(c.t_memory - 0.1) < 1e-9
    assert abs(c.t_collective - 0.2) < 1e-9
    assert c.bottleneck == "compute"
    assert c.step_time == c.t_compute


def test_model_flops_dense_vs_moe():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    dense = get_config("qwen2.5-14b")
    moe = get_config("olmoe-1b-7b")
    sh = SHAPES["train_4k"]
    f_dense = model_flops(dense, sh, 256)
    assert abs(f_dense - 6 * dense.n_params() * sh.global_batch * sh.seq_len / 256) < 1e6
    # MoE: active params only
    f_moe = model_flops(moe, sh, 256)
    assert f_moe < 6 * moe.n_params() * sh.global_batch * sh.seq_len / 256 * 0.5
