"""Paper Table 4 analogue: emulation wall-clock per mode.

Ladder (same structure as the paper's Native / Baseline / AdaPT columns):
  native     — fp32 exact (no emulation)
  baseline   — FUNCTIONAL elementwise ACU (the paper's unoptimized baseline;
               76.5 min ResNet50 regime)
  adapt_lut  — vectorized LUT-gather GEMM (the paper's optimized engine,
               TPU-adapted; 1.7 min regime)
  lowrank    — beyond-paper error-factorized MXU GEMM (DESIGN.md §3)
  quantonly  — exact int GEMM (emulation lower bound)

Run on this container's CPU; the TPU-side projection of the same ladder is
EXPERIMENTS.md §Perf hillclimb #3. Emits CSV:
model,mode,ms_per_batch,speedup_vs_baseline
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.models.vision import cnn_forward, init_cnn, init_resnet, resnet_forward

KEY = jax.random.PRNGKey(0)

import dataclasses

_LUT_ACU = make_acu("mul8s_1L2H", AcuMode.LUT)
MODES = {
    "native": None,
    # paper's "Baseline Approx.": LUTs, no vectorization/chunking optimization
    "baseline_lut": ApproxConfig(acu=dataclasses.replace(_LUT_ACU, lut_chunk=0)),
    # paper's AdaPT engine, TPU/XLA adaptation: chunked vectorized gathers
    "adapt_lut": ApproxConfig(acu=_LUT_ACU),
    "functional": ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL)),
    # beyond-paper: low-rank error-corrected exact GEMM
    "lowrank_r8": ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LOWRANK, rank=8)),
    "quant_only": ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT)),
}


def timeit(fn, *args, reps: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.monotonic()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / reps * 1e3


def bench_model(name, init, fwd, x):
    p = init(KEY)
    rows = []
    times = {}
    for mode, acfg in MODES.items():
        f = jax.jit(lambda p, x, acfg=acfg: fwd(p, x, acfg))
        times[mode] = timeit(f, p, x)
    base = times["baseline_lut"]
    for mode, ms in times.items():
        rows.append(f"{name},{mode},{ms:.1f},{base / ms:.1f}x")
    return rows


def main():
    print("model,mode,ms_per_batch,speedup_vs_baseline")
    x = jax.random.normal(KEY, (16, 3, 32, 32))
    for row in bench_model("CNN-vgg32", lambda k: init_cnn(k, width=24),
                           cnn_forward, x):
        print(row)
    for row in bench_model("ResNet-mini",
                           lambda k: init_resnet(k, width=16, n_blocks=2),
                           lambda p, x, a: resnet_forward(p, x, a, n_blocks=2), x):
        print(row)


if __name__ == "__main__":
    main()
