"""Paper Table 2 analogue: FP32 / quantized / approx / retrained accuracy.

Five representative models (CNN, ResNet-style, SqueezeNet-style, LSTM, VAE)
x two ACUs (mul8s_1L2H-like lossy 8-bit, mul12s_2KM-like near-exact 12-bit),
on deterministic synthetic tasks (DESIGN.md §9: offline container — we
validate the paper's *relative* claims, not ImageNet absolutes).

Emits CSV: model,acu,fp32,quant,approx,retrained,retrain_s
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.data.pipeline import blob_task, image_task, text_cls_task
from repro.models.rnn import init_lstm, lstm
from repro.models.vision import (cnn_forward, init_cnn, init_resnet,
                                 init_squeezenet, init_vae, resnet_forward,
                                 squeezenet_forward, vae_forward,
                                 squeezenet_forward as _sq)

KEY = jax.random.PRNGKey(0)

# three ACU rows: the paper's two roles + a coarser 24%-MRE multiplier that
# makes the degradation->recovery arc visible on our (more error-resilient)
# small synthetic models
ACUS = {
    "mul8s_1L2H": lambda: ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT)),
    "mul8s_hiMRE_bam8": lambda: ApproxConfig(acu=make_acu("mul8s_bam8", AcuMode.LUT)),
    "mul12s_2KM": lambda: ApproxConfig(
        acu=make_acu("mul12s_2KM", AcuMode.FUNCTIONAL), a_bits=12, w_bits=12),
}
QUANT = {
    "mul8s_1L2H": lambda: ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT)),
    "mul8s_hiMRE_bam8": lambda: ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT)),
    "mul12s_2KM": lambda: ApproxConfig(
        acu=make_acu("mul12s_exact", AcuMode.EXACT), a_bits=12, w_bits=12),
}


def _softmax_xent(logits, labels):
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    return (logz - gold).mean()


def classification_problem(fwd, init, task, steps=200, batch=64):
    """AdamW pre-training (fp32); SGD lr 1e-4 retraining (paper §5.1)."""
    from repro.optim.adamw import SGD, AdamW
    params = init(KEY)

    def make_train(acfg, opt):
        def loss_fn(p, img, lab):
            return _softmax_xent(fwd(p, img, acfg), lab)

        @jax.jit
        def step(p, st, img, lab):
            g = jax.grad(loss_fn)(p, img, lab)
            return opt.update(g, st, p)
        return step

    opt = AdamW(lr=3e-3, weight_decay=0.0)
    st = opt.init(params)
    step = make_train(None, opt)
    it = iter(task(batch, seed=1))
    for _ in range(steps):
        b = next(it)
        params, st = step(params, st, jnp.asarray(b["image"]),
                          jnp.asarray(b["label"]))

    def acc(p, acfg):
        correct = total = 0
        ev = iter(task(batch, seed=99))
        for _ in range(4):
            b = next(ev)
            pred = jnp.argmax(fwd(p, jnp.asarray(b["image"]), acfg), -1)
            correct += int((pred == jnp.asarray(b["label"])).sum())
            total += batch
        return correct / total

    def retrain(p, acfg, n=60):
        # paper: SGD, lr 1e-4, one epoch, 10% subset
        sgd = SGD(lr=1e-3, momentum=0.9)
        st2 = sgd.init(p)
        stp = make_train(acfg, sgd)
        it2 = iter(task(batch, seed=2))
        for _ in range(n):
            b = next(it2)
            p, st2 = stp(p, st2, jnp.asarray(b["image"]), jnp.asarray(b["label"]))
        return p

    return params, acc, retrain


def run_model(name, fwd, init, task):
    params, acc, retrain = classification_problem(fwd, init, task)
    fp32 = acc(params, None)
    rows = []
    for acu_name in ACUS:
        q = acc(params, QUANT[acu_name]())
        a = acc(params, ACUS[acu_name]())
        t0 = time.monotonic()
        p2 = retrain(params, ACUS[acu_name]())
        dt = time.monotonic() - t0
        r = acc(p2, ACUS[acu_name]())
        rows.append(f"{name},{acu_name},{fp32:.3f},{q:.3f},{a:.3f},{r:.3f},{dt:.1f}")
    return rows


def lstm_problem():
    task = text_cls_task(vocab=200, n_classes=2)
    emb = jax.random.normal(KEY, (200, 16)) * 0.3
    p = {"lstm": init_lstm(KEY, 16, 32),
         "head": jax.random.normal(KEY, (32, 2)) * 0.2,
         "head_b": jnp.zeros((2,))}

    def fwd(p, toks, acfg=None):
        x = emb[toks]
        h = lstm(x, p["lstm"], acfg)
        return h @ p["head"] + p["head_b"]

    def loss_fn(p, toks, lab, acfg):
        return _softmax_xent(fwd(p, toks, acfg), lab)

    def train(p, acfg, steps, lr):
        from repro.optim.adamw import AdamW
        opt = AdamW(lr=lr, weight_decay=0.0)
        st = opt.init(p)

        @jax.jit
        def step(p, st, toks, lab):
            g = jax.grad(lambda p: loss_fn(p, toks, lab, acfg))(p)
            return opt.update(g, st, p)
        it = iter(task(32, seq=24, seed=3))
        for _ in range(steps):
            b = next(it)
            p, st = step(p, st, jnp.asarray(b["tokens"]), jnp.asarray(b["label"]))
        return p

    def acc(p, acfg):
        it = iter(task(64, seq=24, seed=99))
        c = t = 0
        for _ in range(3):
            b = next(it)
            pred = jnp.argmax(fwd(p, jnp.asarray(b["tokens"]), acfg), -1)
            c += int((pred == jnp.asarray(b["label"])).sum())
            t += 64
        return c / t

    p = train(p, None, 100, 3e-3)
    rows = []
    fp32 = acc(p, None)
    for acu_name in ACUS:
        q = acc(p, QUANT[acu_name]())
        a = acc(p, ACUS[acu_name]())
        t0 = time.monotonic()
        p2 = train(p, ACUS[acu_name](), 30, 3e-4)
        dt = time.monotonic() - t0
        r = acc(p2, ACUS[acu_name]())
        rows.append(f"LSTM-textcls,{acu_name},{fp32:.3f},{q:.3f},{a:.3f},{r:.3f},{dt:.1f}")
    return rows


def vae_problem():
    task = blob_task()
    p = init_vae(KEY, d_in=784, d_h=128, d_z=16)

    def loss_fn(p, x, key, acfg):
        from repro.models.vision import vae_loss
        return vae_loss(p, x, key, acfg)

    def train(p, acfg, steps, lr):
        from repro.optim.adamw import AdamW
        opt = AdamW(lr=lr, weight_decay=0.0)
        st = opt.init(p)

        @jax.jit
        def step(p, st, x, key):
            g = jax.grad(lambda p: loss_fn(p, x, key, acfg))(p)
            return opt.update(g, st, p)
        it = iter(task(64, seed=4))
        for i in range(steps):
            b = next(it)
            p, st = step(p, st, jnp.asarray(b["image"]),
                         jax.random.fold_in(KEY, i))
        return p

    def recon_acc(p, acfg):
        """Reconstruction 'accuracy': 1 - mean binary error (paper uses
        reconstruction fidelity for VAE)."""
        it = iter(task(128, seed=99))
        b = next(it)
        x = jnp.asarray(b["image"])
        recon, _, _ = vae_forward(p, x, KEY, acfg)
        return float(1.0 - jnp.abs((recon > 0.5).astype(jnp.float32) - x).mean())

    p = train(p, None, 80, 1e-3)
    rows = []
    fp32 = recon_acc(p, None)
    for acu_name in ACUS:
        q = recon_acc(p, QUANT[acu_name]())
        a = recon_acc(p, ACUS[acu_name]())
        t0 = time.monotonic()
        p2 = train(p, ACUS[acu_name](), 20, 3e-4)
        dt = time.monotonic() - t0
        r = recon_acc(p2, ACUS[acu_name]())
        rows.append(f"VAE-blobs,{acu_name},{fp32:.3f},{q:.3f},{a:.3f},{r:.3f},{dt:.1f}")
    return rows


def main():
    print("model,acu,fp32,quant,approx,retrained,retrain_s")
    task16 = image_task(n_classes=10, size=16)
    for row in run_model("CNN-vgg", cnn_forward,
                         lambda k: init_cnn(k, n_classes=10, width=8, in_ch=3, img=16),
                         lambda b, seed=1: task16(b, noise=1.8, seed=seed)):
        print(row)
    for row in run_model("ResNet-mini", lambda p, x, a=None: resnet_forward(p, x, a, n_blocks=3),
                         lambda k: init_resnet(k, n_classes=10, width=8, n_blocks=3),
                         lambda b, seed=1: task16(b, noise=1.8, seed=seed)):
        print(row)
    for row in run_model("SqueezeNet-fire", squeezenet_forward,
                         lambda k: init_squeezenet(k, n_classes=10, width=8),
                         lambda b, seed=1: task16(b, noise=1.8, seed=seed)):
        print(row)
    for row in lstm_problem():
        print(row)
    for row in vae_problem():
        print(row)


if __name__ == "__main__":
    main()
