"""Multiplier zoo fidelity table: measured MAE/MRE per multiplier (EvoApprox
convention) + low-rank error-factorization fidelity per rank.

Emits CSV: multiplier,bits,mae_pct,mre_pct,wce  then  multiplier,rank,
exact_frac,energy,max_abs_err.
"""
from __future__ import annotations

from repro.core import error_stats, factorize_error, get_multiplier
from repro.core.multipliers import REGISTRY

NAMED = ["mul8s_1L2H", "mul12s_2KM", "mul8s_trunc2", "mul8s_trunc3",
         "mul8s_bam5", "mul8s_bam6", "mul8s_mitchell", "mul8s_drum6",
         "mul12s_trunc2", "mul12s_mitchell"]


def main():
    print("multiplier,bits,mae_pct,mre_pct,worst_case_err")
    for name in NAMED:
        if name not in REGISTRY:
            continue
        m = get_multiplier(name)
        s = error_stats(m)
        print(f"{name},{s['bits']},{s['mae_pct']:.6g},{s['mre_pct']:.6g},"
              f"{s['worst_case_err']:.0f}")
    print()
    print("multiplier,rank,exact_frac,energy,max_abs_err")
    for name in ("mul8s_1L2H", "mul8s_mitchell", "mul8s_drum6"):
        for rank in (2, 4, 8, 16, 32):
            lr = factorize_error(get_multiplier(name), rank)
            print(f"{name},{rank},{lr.exact_frac:.4f},{lr.energy:.6f},"
                  f"{lr.max_abs_err:.2f}")


if __name__ == "__main__":
    main()
