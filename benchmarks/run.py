"""Benchmark driver: one function per paper table (+ kernel microbench).

``python -m benchmarks.run [--fast]`` prints CSV sections:
  [table2]  accuracy: fp32/quant/approx/retrained per DNN x ACU   (paper Tab.2)
  [table4]  emulation wall-clock speedups per mode                (paper Tab.4)
  [fidelity] multiplier MAE/MRE + low-rank factorization fidelity (paper Tab.2 header)
  [kernels] Pallas kernel micro-shape timings (interpret mode, CPU)
"""
from __future__ import annotations

import argparse
import sys
import time


def section(name):
    print(f"\n[{name}]", flush=True)


def kernel_micro():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import build_lut, factorize_error, get_multiplier
    from repro.kernels.err_matmul.ops import err_matmul
    from repro.kernels.lut_matmul.ops import lut_matmul

    mult = get_multiplier("mul8s_1L2H")
    lut = jnp.asarray(build_lut(mult))
    lr = factorize_error(mult, 8)
    f, g = jnp.asarray(lr.f), jnp.asarray(lr.g)
    rng = np.random.default_rng(0)
    print("kernel,M,K,N,us_per_call,derived")
    for (M, K, N) in [(128, 128, 128), (256, 256, 256)]:
        a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int32)
        w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int32)
        for name, fn in [
            ("lut_matmul", lambda: lut_matmul(a, w, lut, 128, interpret=True)),
            ("err_matmul", lambda: err_matmul(a, w, f, g, 128, interpret=True)),
        ]:
            jax.block_until_ready(fn())
            t0 = time.monotonic()
            jax.block_until_ready(fn())
            us = (time.monotonic() - t0) * 1e6
            flops = 2 * M * K * N
            print(f"{name},{M},{K},{N},{us:.0f},{flops/1e6:.1f}MFLOP-equiv")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the accuracy table (slowest section)")
    args = ap.parse_args(argv)

    section("fidelity")
    from benchmarks import multiplier_fidelity
    multiplier_fidelity.main()

    section("table4")
    from benchmarks import table4_speedup
    table4_speedup.main()

    if not args.fast:
        section("table2")
        from benchmarks import table2_accuracy
        table2_accuracy.main()

    section("kernels")
    kernel_micro()
    return 0


if __name__ == "__main__":
    sys.exit(main())
