"""Benchmark driver: one function per paper table (+ kernel microbench) and a
machine-readable regression record.

``python -m benchmarks.run [--fast] [--json BENCH_PR1.json]`` prints CSV
sections:
  [table2]  accuracy: fp32/quant/approx/retrained per DNN x ACU   (paper Tab.2)
  [table4]  emulation wall-clock speedups per mode                (paper Tab.4)
  [fidelity] multiplier MAE/MRE + low-rank factorization fidelity (paper Tab.2 header)
  [kernels] Pallas kernel micro-shape timings (interpret mode, CPU)
  [layers]  approx_dense wall-clock per dispatch route: fused single-kernel
            vs unfused quantize->LUT-GEMM->dequant vs functional baseline;
            plus conv2d routes (conv_fused patch-streaming kernel vs the
            eager im2col path) at a VGG-ish 3x3 and a 1x1 pointwise layer
  [train]   train-step (fwd + STE backward) per backward route: fused
            approximate backward vs the materialized eager approximate
            backward vs the exact-f32 backward (context), dense and 224^2
            x 64ch conv geometry
  [attn]    approximate flash attention: fused Pallas kernel vs the unfused
            jnp oracle it is bitwise-identical to, prefill + decode shapes
  [serve]   sustained serving tokens/s, wave vs continuous batching, with a
            LUT-Pallas acfg (end-to-end approximate decode) — all-at-once
            gated pair plus a Poisson arrival trace
  [sharded] the same routes under a 2x4 host-platform (data, model) mesh
            (needs XLA_FLAGS=--xla_force_host_platform_device_count=8;
            printed as skipped otherwise)
  [moe]     grouped ragged fused LUT-GEMM for MoE expert dispatch: ONE
            pallas_call over all E expert GEMMs (groupinfo skips row blocks
            past each expert's live token count) vs the per-expert vmapped
            composition it is bitwise-identical to, vs the exact f32 grouped
            einsum (context), at a granite-ish skewed-routing geometry.
            Runs after the serve section: its E=40 vmapped baseline alone
            compiles ~E kernel instances, and that jit/heap residue would
            tax the allocation-heavy serve rows (same rationale as
            [recovery] running last); its own rows are a within-section
            pair, immune to the ordering
  [recovery] damped vs fixed-batch QAT recovery accuracy-vs-samples curves
            (gradient-noise batch damping, docs/training.md); rows join the
            train record section, the damped row's sample_efficiency >= 1.0
            is a check_regression.py floor; mesh-wide when 8 devices exist.
            Runs last: its training runs' heap/jit residue would otherwise
            tax the timing sections that follow it

``--json`` additionally writes the kernel and layer sections (plus host
metadata) as a BENCH_*.json record — the perf trajectory future PRs append
to. Schema documented in docs/benchmarks.md.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def section(name):
    print(f"\n[{name}]", flush=True)


def _time_call(fn, reps: int = 5) -> float:
    """µs/call: warmup (compile) + min of ``reps`` timed calls (min, not
    mean — interpret-mode timings on a shared CPU are noisy upward only)."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.monotonic()
        jax.block_until_ready(fn())
        best = min(best, time.monotonic() - t0)
    return best * 1e6


def kernel_micro(records: list | None = None):
    import jax.numpy as jnp
    import numpy as np
    from repro.core import build_lut, factorize_error, get_multiplier
    from repro.core.quantization import symmetric_qparams
    from repro.kernels.err_matmul.ops import err_matmul
    from repro.kernels.fused_lut_dense.ops import fused_lut_dense
    from repro.kernels.lut_matmul.ops import lut_matmul

    mult = get_multiplier("mul8s_1L2H")
    lut = jnp.asarray(build_lut(mult))
    lr = factorize_error(mult, 8)
    f, g = jnp.asarray(lr.f), jnp.asarray(lr.g)
    rng = np.random.default_rng(0)
    print("kernel,M,K,N,us_per_call,derived")
    for (M, K, N) in [(128, 128, 128), (256, 256, 256)]:
        a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int32)
        w = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int32)
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        xqp = symmetric_qparams(jnp.max(jnp.abs(x)), 8)
        ws = jnp.full((N,), 0.01, jnp.float32)
        for name, fn in [
            ("lut_matmul", lambda: lut_matmul(a, w, lut, 128)),
            ("err_matmul", lambda: err_matmul(a, w, f, g, 128)),
            ("fused_lut_dense", lambda: fused_lut_dense(
                x, w, lut, 128, xqp.scale, xqp.zero_point, ws, bits=8)),
        ]:
            us = _time_call(fn)
            flops = 2 * M * K * N
            print(f"{name},{M},{K},{N},{us:.0f},{flops/1e6:.1f}MFLOP-equiv")
            if records is not None:
                records.append({"kernel": name, "M": M, "K": K, "N": N,
                                "us_per_call": round(us, 1)})


def layer_modes(records: list | None = None):
    """approx_dense wall-clock per dispatch route (the fusion headline).

    ``fused`` runs quantize -> LUT GEMM -> dequant as ONE Pallas kernel;
    ``unfused_pallas`` is the three-stage pipeline with the Pallas LUT GEMM;
    ``unfused_jnp`` the same pipeline with the chunked-gather jnp GEMM;
    ``functional`` the paper's unoptimized closed-form baseline.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig, approx_dense

    pallas_acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    modes = {
        "fused": ApproxConfig(acu=pallas_acu, fused=True),
        "unfused_pallas": ApproxConfig(acu=pallas_acu),
        "unfused_jnp": ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT)),
        "functional": ApproxConfig(
            acu=make_acu("mul8s_1L2H", AcuMode.FUNCTIONAL)),
    }
    rng = np.random.default_rng(1)
    print("mode,M,K,N,us_per_call,vs_unfused_pallas")
    for (M, K, N) in [(128, 128, 128), (256, 256, 256), (512, 256, 256),
                      (256, 512, 512)]:
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        times = {}
        for mode, cfg in modes.items():
            fn = jax.jit(lambda x, w, cfg=cfg: approx_dense(x, w, None, cfg))
            times[mode] = _time_call(lambda: fn(x, w), reps=8)
        base = times["unfused_pallas"]
        for mode, us in times.items():
            print(f"{mode},{M},{K},{N},{us:.0f},{base/us:.2f}x")
            if records is not None:
                records.append({"mode": mode, "M": M, "K": K, "N": N,
                                "us_per_call": round(us, 1),
                                "speedup_vs_unfused_pallas":
                                    round(base / us, 3)})


def conv_modes(records: list | None = None):
    """conv2d wall-clock: the fused conv kernels vs the eager im2col +
    fused-dense path they retired (``route="im2col"``).

    ``conv_fused`` rows (VGG-ish 3x3, 1x1 pointwise) ride the whole-image
    kernel; ``conv_tiled`` rows (224^2 x 64ch, 112^2 x 128ch — ImageNet-scale
    shapes the whole-image kernel refuses, its working set is over the VMEM
    budget) ride the spatially-tiled kernel, which until PR 4 fell back to
    eager im2col. Rows join the ``layers`` record section (M/K/N are the
    implicit im2col GEMM dims); the regression gates cover ``conv_fused`` at
    the VGG-ish shape and ``conv_tiled`` at 224^2, where tiled must also
    stay >= the im2col baseline (benchmarks/check_regression.py)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig, conv2d

    cfg = ApproxConfig(
        acu=make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True, fused=True))
    rng = np.random.default_rng(2)
    print("mode,conv,M,K,N,us_per_call,vs_im2col")
    for tag, n, c, h, w_sz, cout, k, fused_mode, reps in [
        ("vgg3x3", 2, 64, 32, 32, 128, 3, "conv_fused", 8),  # SAME, stride 1
        ("pointwise1x1", 2, 256, 16, 16, 256, 1, "conv_fused", 8),
        # over the whole-image VMEM budget -> the spatially-tiled kernel
        # (few reps: the im2col baseline takes ~a minute per call here)
        ("imagenet224", 1, 64, 224, 224, 64, 3, "conv_tiled", 2),
        ("imagenet112", 1, 128, 112, 112, 128, 3, "conv_tiled", 2),
    ]:
        x = jnp.asarray(rng.normal(size=(n, c, h, w_sz)), jnp.float32)
        wt = jnp.asarray(rng.normal(size=(cout, c, k, k)), jnp.float32)
        fns = {
            fused_mode: jax.jit(
                lambda x, wt: conv2d(x, wt, None, cfg=cfg)),
            "conv_im2col": jax.jit(
                lambda x, wt: conv2d(x, wt, None, cfg=cfg, route="im2col")),
        }
        times = {m: _time_call(lambda fn=fn: fn(x, wt), reps=reps)
                 for m, fn in fns.items()}
        base = times["conv_im2col"]
        m_rows, k_dim = n * h * w_sz, c * k * k   # SAME/stride-1 geometry
        for mode, us in times.items():
            print(f"{mode},{tag},{m_rows},{k_dim},{cout},{us:.0f},"
                  f"{base/us:.2f}x")
            if records is not None:
                records.append({"mode": mode, "conv": tag, "M": m_rows,
                                "K": k_dim, "N": cout,
                                "us_per_call": round(us, 1),
                                "speedup_vs_im2col": round(base / us, 3)})


def train_modes(records: list | None = None):
    """One optimizer-free train step (forward + STE backward via jax.grad)
    per backward route — the fused-approximate-backward headline.

    ``*_fused_bwd`` runs ``cfg.approx_bwd`` through the fused in-kernel
    routes (dense ``fused_lut_bwd``; banded conv weight-grad + per-band gx
    GEMMs — the im2col patch tensor never exists in HBM); ``*_eager_bwd``
    is the same approximate backward through the materialized unfused
    composition (conv pinned to ``route="im2col"``); ``*_exact_bwd`` is the
    default exact-f32 STE backward, recorded as CONTEXT ONLY — interpret-mode
    LUT gathers can never beat native XLA f32 GEMMs, so the regression floor
    compares fused vs eager approx instead (benchmarks/check_regression.py).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig, approx_dense, conv2d

    acu_fused = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True,
                         fused=True)
    acu_unfused = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    rng = np.random.default_rng(6)
    print("mode,train,M,K,N,us_per_call,vs_eager_bwd")

    def emit(times, tag, M, K, N):
        base = times[f"train_{tag}_eager_bwd"]
        for mode, us in times.items():
            print(f"{mode},{tag},{M},{K},{N},{us:.0f},{base/us:.2f}x")
            if records is not None:
                row = {"mode": mode, "train": tag, "M": M, "K": K, "N": N,
                       "us_per_call": round(us, 1)}
                if not mode.endswith("exact_bwd"):   # exact is context only
                    row["speedup_vs_eager_bwd"] = round(base / us, 3)
                records.append(row)

    # dense train step at the VGG-ish im2col GEMM geometry
    M, K, N = 2048, 576, 128
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    times = {}
    for mode, cfg, reps in [
        ("train_dense_fused_bwd",
         ApproxConfig(acu=acu_fused, approx_bwd=True), 3),
        ("train_dense_eager_bwd",
         ApproxConfig(acu=acu_unfused, approx_bwd=True), 3),
        ("train_dense_exact_bwd", ApproxConfig(acu=acu_fused), 3),
    ]:
        fn = jax.jit(jax.grad(
            lambda x, w, cfg=cfg: approx_dense(x, w, None, cfg).sum(),
            argnums=(0, 1)))
        times[mode] = _time_call(lambda: fn(x, w), reps=reps)
    emit(times, "dense", M, K, N)

    # conv train step at the ImageNet-scale 224^2 x 64ch geometry: fused
    # rides the banded backward, eager materializes the (50176, 576) patch
    # GEMMs (~a minute per call -> few reps)
    xc = jnp.asarray(rng.normal(size=(1, 64, 224, 224)), jnp.float32)
    wc = jnp.asarray(rng.normal(size=(64, 64, 3, 3)), jnp.float32)
    times = {}
    for mode, cfg, route, reps in [
        ("train_conv224_fused_bwd",
         ApproxConfig(acu=acu_fused, approx_bwd=True), None, 2),
        ("train_conv224_eager_bwd",
         ApproxConfig(acu=acu_fused, approx_bwd=True), "im2col", 1),
        ("train_conv224_exact_bwd", ApproxConfig(acu=acu_fused), None, 2),
    ]:
        fn = jax.jit(jax.grad(
            lambda x, w, cfg=cfg, route=route:
                conv2d(x, w, cfg=cfg, route=route).sum(),
            argnums=(0, 1)))
        times[mode] = _time_call(lambda: fn(xc, wc), reps=reps)
    emit(times, "conv224", 1 * 224 * 224, 64 * 9, 64)


def recovery_modes(records: list | None = None):
    """Damped vs fixed-batch QAT recovery — the gradient-noise batch-damping
    headline (docs/training.md "Damped QAT recovery").

    A CNN pretrained in fp32 is dropped onto the lossy 8-bit ACU and
    retrained through the approximate forward + fused approximate backward
    (``approx_bwd=True``, the PR 6 in-kernel STE routes) twice, via
    ``train.Trainer``:

    * ``recovery_fixed``   — the fixed LARGE effective batch (the batch a
      fixed-budget recovery would pick for its final accuracy),
    * ``recovery_damped``  — starts at a quarter of that batch and lets the
      gradient-noise schedule (optim/damping.py) grow accumulation back to
      the same effective batch as the approximate gradients denoise.

    Both record accuracy-vs-samples curves on one fixed eval set.
    ``sample_efficiency`` on the damped row = fixed-run total samples /
    damped samples at the first step whose accuracy reaches the fixed run's
    final accuracy (0.0 if never reached) — the ``>= 1.0`` within-record
    floor in benchmarks/check_regression.py: damping must never need MORE
    data than the fixed batch to recover the same accuracy. Mesh-wide
    (2x4 host mesh, data-parallel compressed psum) when 8 devices are
    available, single-device otherwise (``mesh`` field records which)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig
    from repro.data.pipeline import image_task
    from repro.models.vision import cnn_forward, init_cnn
    from repro.optim.adamw import SGD
    from repro.optim.damping import DampingConfig
    from repro.train.trainer import Trainer, TrainerConfig

    mesh = None
    if len(jax.devices()) >= 8:
        from repro.launch.mesh import make_host_multi_mesh
        mesh = make_host_multi_mesh((2, 4))

    task0 = image_task(n_classes=4, size=8)
    task = lambda b, seed: task0(b, noise=0.55, seed=seed)
    params0 = init_cnn(jax.random.PRNGKey(0), n_classes=4, width=8, in_ch=3,
                       img=8)
    # trunc3 (27% MRE) actually dents the pretrained model (~0.98 -> ~0.70
    # here); the milder ACUs leave nothing to recover at this scale
    acfg = ApproxConfig(acu=make_acu("mul8s_trunc3", AcuMode.LUT,
                                     use_pallas=True, fused=True),
                        approx_bwd=True)

    def xent(p, b, cfg=None):
        logits = cnn_forward(p, b["image"], cfg)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, b["label"][:, None], -1)[:, 0]
        return (logz - gold).mean()

    # fp32 pretrain (plain SGD outside the Trainer: not what's measured)
    pre = jax.jit(lambda p, b: jax.tree.map(
        lambda w, g: w - 3e-3 * g, p, jax.grad(xent)(p, b)))
    it = iter(task(64, seed=1))
    for _ in range(60):
        b = next(it)
        params0 = pre(params0, {k: jnp.asarray(v) for k, v in b.items()})

    eb = next(iter(task(256, seed=99)))
    eimg, elab = jnp.asarray(eb["image"]), jnp.asarray(eb["label"])
    acc_fn = jax.jit(lambda p: jnp.mean(
        jnp.argmax(cnn_forward(p, eimg, acfg), -1) == elab))

    B_SMALL, ACCUM_MAX, STEPS = 8, 4, 60
    lr = 3e-3

    def recover(damping, batch, n_steps, seed):
        tr = Trainer(xent if acfg is None else
                     (lambda p, b: xent(p, b, acfg)), SGD(lr=lr),
                     TrainerConfig(mesh=mesh, log_every=10**9,
                                   damping=damping), donate=False)
        curve = []
        tr.fit(jax.tree.map(jnp.copy, params0), SGD(lr=lr).init(params0),
               ({k: jnp.asarray(v) for k, v in bt.items()}
                for bt in task(batch, seed=seed)), n_steps,
               step_hook=lambda s, p, consumed: curve.append(
                   (consumed * batch, float(acc_fn(p)))))
        return curve

    t0 = time.monotonic()
    fixed = recover(None, B_SMALL * ACCUM_MAX, STEPS, seed=2)
    t_fixed = time.monotonic() - t0
    t0 = time.monotonic()
    damped = recover(DampingConfig(accum_max=ACCUM_MAX, warmup_updates=2,
                                   ema=0.5), B_SMALL, STEPS + STEPS // 2,
                     seed=2)
    t_damped = time.monotonic() - t0

    acc0 = float(acc_fn(params0))                 # pre-recovery (dropped)
    target = fixed[-1][1]
    fixed_samples = fixed[-1][0]
    reach = next((s for s, a in damped if a >= target), None)
    eff = round(fixed_samples / reach, 3) if reach else 0.0
    mesh_tag = "2x4" if mesh is not None else "1x1"
    rows = [
        {"mode": "recovery_fixed", "mesh": mesh_tag, "batch": B_SMALL * ACCUM_MAX,
         "steps": STEPS, "samples": fixed_samples, "acc_start": round(acc0, 4),
         "acc_final": round(target, 4), "wall_s": round(t_fixed, 1),
         "curve": [[s, round(a, 4)] for s, a in fixed]},
        {"mode": "recovery_damped", "mesh": mesh_tag, "batch": B_SMALL,
         "accum_max": ACCUM_MAX, "steps": STEPS + STEPS // 2,
         "samples": damped[-1][0], "acc_start": round(acc0, 4),
         "acc_final": round(damped[-1][1], 4),
         "samples_to_target": reach, "sample_efficiency": eff,
         "wall_s": round(t_damped, 1),
         "curve": [[s, round(a, 4)] for s, a in damped]},
    ]
    print("mode,mesh,batch,steps,samples,acc_start,acc_final,"
          "sample_efficiency")
    for r in rows:
        print(f"{r['mode']},{r['mesh']},{r['batch']},{r['steps']},"
              f"{r['samples']},{r['acc_start']},{r['acc_final']},"
              f"{r.get('sample_efficiency', '')}")
        if records is not None:
            records.append(r)


def attn_modes(records: list | None = None):
    """Approximate attention wall-clock: the fused flash kernel (in-kernel
    quantize + LUT-gather QK^T/PV inside the streaming softmax) vs the
    unfused jnp oracle composition it is bitwise-identical to, at a prefill
    and a decode-step geometry. BH folds batch x heads (GQA rep=4)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import build_lut, get_multiplier
    from repro.kernels.flash_attention.approx import approx_flash_attention
    from repro.kernels.flash_attention.ref import approx_attention_ref

    lut = jnp.asarray(build_lut(get_multiplier("mul8s_1L2H")))
    rng = np.random.default_rng(4)
    print("mode,attn,BH,Sq,Sk,D,us_per_call,vs_unfused")
    for tag, bh_kv, rep, sq, sk, d, reps in [
        ("prefill256", 2, 4, 256, 256, 32, 5),
        ("decode1x256", 2, 4, 1, 256, 32, 8),
    ]:
        q = jnp.asarray(rng.normal(size=(bh_kv * rep, sq, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(bh_kv, sk, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(bh_kv, sk, d)), jnp.float32)
        s = [jnp.float32(jnp.max(jnp.abs(t)) / 127.0) for t in (q, k, v)]
        fns = {
            "attn_fused": lambda: approx_flash_attention(
                q, k, v, lut, 128, *s, causal=True),
            "attn_unfused": lambda: approx_attention_ref(
                q, k, v, lut, 128, *s, causal=True),
        }
        times = {m: _time_call(fn, reps=reps) for m, fn in fns.items()}
        base = times["attn_unfused"]
        for mode, us in times.items():
            print(f"{mode},{tag},{bh_kv * rep},{sq},{sk},{d},{us:.0f},"
                  f"{base/us:.2f}x")
            if records is not None:
                records.append({"mode": mode, "attn": tag,
                                "BH": bh_kv * rep, "Sq": sq, "Sk": sk, "D": d,
                                "us_per_call": round(us, 1),
                                "speedup_vs_unfused": round(base / us, 3)})


def moe_modes(records: list | None = None):
    """Grouped ragged fused LUT-GEMM for MoE expert dispatch (docs/moe.md).

    ``moe_grouped`` runs ALL E expert GEMMs as ONE ``pallas_call`` whose
    per-expert groupinfo lets the grid skip row blocks past each expert's
    live token count; ``moe_vmapped`` is the per-expert vmapped fused-dense
    composition it is bitwise-identical to (one kernel instance per expert,
    every instance walking the full capacity buffer); ``moe_exact`` is the
    exact-f32 grouped einsum, context only — interpret-mode LUT gathers
    cannot beat native XLA GEMMs, so the regression floor is grouped >=
    vmapped (benchmarks/check_regression.py), not grouped vs exact.

    Geometry: granite-ish routing (E=40 experts, top-8) at reduced width,
    t=256 tokens, capacity factor 1.25 -> 64-row capacity buffers, with a
    skewed (Zipf) routing profile so the ragged skip has something to skip
    — the load imbalance the grouped kernel exists for. ``live_frac`` is
    the occupied fraction of the E x cap buffer rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig, approx_grouped_dense

    cfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT,
                                    use_pallas=True, fused=True))
    E, top_k, t, D, F = 40, 8, 256, 256, 128
    cap = int(round(t * top_k / E * 1.25))            # 64
    rng = np.random.default_rng(7)
    share = 1.0 / np.arange(1, E + 1) ** 1.2          # Zipf-ish skew
    share /= share.sum()
    assign = rng.choice(E, size=t * top_k, p=share)
    counts = jnp.asarray(np.minimum(np.bincount(assign, minlength=E), cap),
                         jnp.int32)
    x = jnp.asarray(rng.normal(size=(E, cap, D)), jnp.float32)
    x = x * (jnp.arange(cap)[None, :] < counts[:, None])[..., None]
    w = jnp.asarray(rng.normal(size=(E, D, F)), jnp.float32)
    live = float(counts.sum()) / (E * cap)

    fns = {
        "moe_grouped": jax.jit(
            lambda x, w, c: approx_grouped_dense(x, w, cfg, c)),
        "moe_vmapped": jax.jit(
            lambda x, w, c: approx_grouped_dense(x, w, cfg, c, route="vmap")),
        "moe_exact": jax.jit(
            lambda x, w, c: jnp.einsum("eck,ekn->ecn", x, w)),
    }
    times = {m: _time_call(lambda fn=fn: fn(x, w, counts), reps=3)
             for m, fn in fns.items()}
    base = times["moe_vmapped"]
    print("mode,E,top_k,cap,D,F,live_frac,us_per_call,vs_vmapped")
    for mode, us in times.items():
        print(f"{mode},{E},{top_k},{cap},{D},{F},{live:.2f},{us:.0f},"
              f"{base/us:.2f}x")
        if records is not None:
            row = {"mode": mode, "E": E, "top_k": top_k, "cap": cap,
                   "D": D, "F": F, "live_frac": round(live, 3),
                   "us_per_call": round(us, 1)}
            if mode != "moe_exact":    # exact f32 is context only
                row["speedup_vs_vmapped"] = round(base / us, 3)
            records.append(row)


def serve_modes(records: list | None = None):
    """Sustained serving throughput, wave vs continuous batching, end-to-end
    approximate decode (LUT-Pallas acfg: every GEMM and every attention
    layer rides the ACU kernels).

    The request mix is deliberately skewed (a few long generations among
    many short ones): the wave engine drains each batch at the pace of its
    longest row, continuous batching refills freed slots immediately. Both
    engines serve the IDENTICAL request set all-at-once for the gated pair
    (``us_per_call`` = µs per generated token, so the trajectory gate
    machinery applies unchanged; ``speedup_vs_wave`` carries the
    within-record floor), plus one continuous row under a Poisson arrival
    trace (rate 1.0/decode-step) as the sustained-load headline."""
    import jax
    import numpy as np
    from repro.configs import reduced_config
    from repro.core import make_acu
    from repro.core.approx_ops import ApproxConfig
    from repro.models.transformer import init_params
    from repro.serve.engine import (ContinuousServeEngine, Request,
                                    ServeEngine, poisson_arrivals)

    cfg = reduced_config("smollm-135m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    acfg = ApproxConfig(acu=make_acu("mul8s_1L2H", use_pallas=True,
                                     fused=True))
    rng = np.random.default_rng(5)
    budgets = [24, 2, 2, 2, 24, 2, 2, 2]

    def make_reqs():
        return [Request(prompt=rng.integers(1, cfg.vocab_size, 4
                                            ).astype(np.int32),
                        max_new_tokens=b)
                for b in list(budgets)]

    rows = []
    print("mode,requests,tokens,decode_steps,tok_per_s,us_per_call,"
          "speedup_vs_wave")

    def timed(eng, arrivals=None, warm=True):
        is_cont = isinstance(eng, ContinuousServeEngine)
        if warm:   # compile THIS engine's prefill/decode outside the timing
            wr = [Request(prompt=np.asarray([3, 1, 4, 1], np.int32),
                          max_new_tokens=2)]
            eng.run(wr, None) if is_cont else eng.run(wr)
        reqs = make_reqs()
        t0 = time.monotonic()
        done = eng.run(reqs, arrivals) if is_cont else eng.run(reqs)
        dt = time.monotonic() - t0
        toks = sum(len(r.out) for r in done)
        return toks, dt

    wave = ServeEngine(params, cfg, slots=4, max_seq=32, acfg=acfg)
    toks, dt = timed(wave)
    rows.append({"mode": "serve_wave", "requests": len(budgets),
                 "tokens": toks, "decode_steps": None,
                 "tok_per_s": round(toks / dt, 2),
                 "us_per_call": round(dt / toks * 1e6, 1)})

    cont = ContinuousServeEngine(params, cfg, slots=4, max_seq=32, acfg=acfg)
    toks, dt = timed(cont)
    wave_tps = rows[0]["tok_per_s"]
    rows.append({"mode": "serve_continuous", "requests": len(budgets),
                 "tokens": toks, "decode_steps": cont.stats["decode_steps"],
                 "tok_per_s": round(toks / dt, 2),
                 "us_per_call": round(dt / toks * 1e6, 1),
                 "speedup_vs_wave": round((toks / dt) / wave_tps, 3)})

    # Poisson arrival trace through the SAME (already compiled) engine
    toks, dt = timed(cont, arrivals=poisson_arrivals(len(budgets), 1.0,
                                                     seed=7), warm=False)
    rows.append({"mode": "serve_continuous_poisson",
                 "requests": len(budgets), "tokens": toks,
                 "decode_steps": cont.stats["decode_steps"],
                 "tok_per_s": round(toks / dt, 2),
                 "us_per_call": round(dt / toks * 1e6, 1),
                 "occupancy": round(cont.stats["occupancy"], 2)})

    # -- memory-pressure trace: paged vs contiguous under the SAME HBM budget
    # (docs/serving.md "Paged KV"). 12 long-context requests sharing a
    # 24-token prefix (28-token prompts: the contiguous engine's pow2
    # bucket is then 32, leaving it decode headroom — a 36-token prompt
    # would bucket to the whole row and emit nothing) against a budget of
    # two contiguous max_seq rows: the
    # contiguous engine can only pin 2 slots, the paged engine packs 4 slots
    # into the same bytes because rows pin blocks, not whole rows, and the
    # shared prefix is stored once. The gated pair serves the identical
    # request set all-at-once; ``speedup_vs_contiguous`` carries the
    # within-record floor (paged must not lose to contiguous under the
    # budget it exists to relieve) and ``prefix_hit_rate`` must stay > 0.
    from repro.serve.engine import PagedContinuousServeEngine, kv_block_bytes

    p_max_seq, p_bk = 64, 8
    budget = 2 * (p_max_seq // p_bk) * kv_block_bytes(cfg, p_bk)
    shared = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)

    def make_pressure_reqs():
        r2 = np.random.default_rng(11)
        return [Request(prompt=np.concatenate(
                    [shared, r2.integers(1, cfg.vocab_size, 4
                                         ).astype(np.int32)]),
                        max_new_tokens=8) for _ in range(12)]

    def timed_pressure(eng):
        eng.run([Request(prompt=np.asarray([3, 1, 4, 1], np.int32),
                         max_new_tokens=2)], None)     # warm compile
        reqs = make_pressure_reqs()
        t0 = time.monotonic()
        done = eng.run(reqs, None)
        dt = time.monotonic() - t0
        return sum(len(r.out) for r in done), dt

    cpress = ContinuousServeEngine(params, cfg, slots=2, max_seq=p_max_seq,
                                   acfg=acfg)
    toks, dt = timed_pressure(cpress)
    contig_tps = toks / dt
    rows.append({"mode": "serve_paged_contig_baseline", "requests": 12,
                 "tokens": toks, "decode_steps": cpress.stats["decode_steps"],
                 "tok_per_s": round(contig_tps, 2),
                 "us_per_call": round(dt / toks * 1e6, 1)})

    paged = PagedContinuousServeEngine(params, cfg, slots=4,
                                       max_seq=p_max_seq, block_size=p_bk,
                                       acfg=acfg, hbm_budget=budget)
    toks, dt = timed_pressure(paged)
    rows.append({"mode": "serve_paged", "requests": 12, "tokens": toks,
                 "decode_steps": paged.stats["decode_steps"],
                 "tok_per_s": round(toks / dt, 2),
                 "us_per_call": round(dt / toks * 1e6, 1),
                 "speedup_vs_contiguous": round((toks / dt) / contig_tps, 3),
                 "prefix_hit_rate": round(paged.stats["prefix_hit_rate"], 3),
                 "occupancy": round(paged.stats["occupancy"], 2),
                 "block_util": round(paged.stats["block_util"], 3),
                 "peak_blocks": paged.stats["peak_blocks"],
                 "cache_evictions": paged.stats["cache_evictions"],
                 "preemptions": paged.stats["preemptions"]})

    for r in rows:
        print(f"{r['mode']},{r['requests']},{r['tokens']},"
              f"{r['decode_steps']},{r['tok_per_s']},{r['us_per_call']},"
              f"{r.get('speedup_vs_wave', '')}")
        if records is not None:
            records.append(r)


def sharded_modes(records: list | None = None):
    """approx_dense under an active 2x4 host mesh vs replicated (docs/
    sharding.md). On the CPU interpreter the sharded numbers mostly measure
    shard_map/collective overhead — 8 emulated devices share one physical
    CPU — so the interesting trajectory is the overhead ratio, not a win;
    the fusion speedup story stays in [layers]."""
    import jax
    if len(jax.devices()) < 8:
        print("skipped: needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return
    import jax.numpy as jnp
    import numpy as np
    from repro.core import make_acu
    from repro.core.acu import AcuMode
    from repro.core.approx_ops import ApproxConfig, approx_dense
    from repro.launch.mesh import make_host_multi_mesh
    from repro.parallel.sharding import use_mesh

    mesh = make_host_multi_mesh((2, 4))
    acu = make_acu("mul8s_1L2H", AcuMode.LUT, use_pallas=True)
    modes = {
        "sharded_fused": ApproxConfig(acu=acu, fused=True),
        "sharded_unfused_pallas": ApproxConfig(acu=acu),
        "sharded_unfused_jnp": ApproxConfig(
            acu=make_acu("mul8s_1L2H", AcuMode.LUT)),
    }
    rng = np.random.default_rng(3)
    print("mode,mesh,M,K,N,us_per_call,vs_replicated")
    for (M, K, N) in [(256, 256, 256), (512, 256, 256)]:
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
        for mode, cfg in modes.items():
            rep = jax.jit(lambda x, w, cfg=cfg: approx_dense(x, w, None, cfg))
            t_rep = _time_call(lambda: rep(x, w), reps=5)
            with use_mesh(mesh):
                sh = jax.jit(
                    lambda x, w, cfg=cfg: approx_dense(x, w, None, cfg))
                t_sh = _time_call(lambda: sh(x, w), reps=5)
            print(f"{mode},2x4,{M},{K},{N},{t_sh:.0f},{t_rep/t_sh:.2f}x")
            if records is not None:
                records.append({"mode": mode, "mesh": "2x4",
                                "M": M, "K": K, "N": N,
                                "us_per_call": round(t_sh, 1),
                                "replicated_us_per_call": round(t_rep, 1),
                                "speedup_vs_replicated":
                                    round(t_rep / t_sh, 3)})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the accuracy table (slowest section)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write kernel/layer timings as a BENCH_*.json "
                         "regression record (schema: docs/benchmarks.md)")
    args = ap.parse_args(argv)

    if args.json:  # fail fast: don't discover an unwritable path after
        with open(args.json, "a"):  # minutes of benchmarking
            pass

    section("fidelity")
    from benchmarks import multiplier_fidelity
    multiplier_fidelity.main()

    section("table4")
    from benchmarks import table4_speedup
    table4_speedup.main()

    if not args.fast:
        section("table2")
        from benchmarks import table2_accuracy
        table2_accuracy.main()

    kernel_records: list = []
    layer_records: list = []
    train_records: list = []
    attn_records: list = []
    moe_records: list = []
    serve_records: list = []
    sharded_records: list = []
    section("kernels")
    kernel_micro(kernel_records)
    section("layers")
    layer_modes(layer_records)
    conv_modes(layer_records)
    section("train")
    train_modes(train_records)
    section("attn")
    attn_modes(attn_records)
    section("serve")
    serve_modes(serve_records)
    section("sharded")
    sharded_modes(sharded_records)
    # moe AFTER serve: the E=40 per-expert vmapped baseline compiles ~E
    # kernel instances and that jit/heap residue taxes the allocation-heavy
    # serve rows (same reason recovery runs last); the moe rows themselves
    # are a within-section pair, immune to the ordering
    section("moe")
    moe_modes(moe_records)
    # recovery runs LAST: its two full training runs leave enough heap/jit
    # residue to tax the allocation-heavy serve rows by ~30% if it runs
    # before them (its own rows are accuracy curves, immune to that)
    section("recovery")
    recovery_modes(train_records)

    if args.json:
        import jax
        record = {
            "schema": "adapt-bench-v1",
            "unix_time": int(time.time()),
            "host": {"platform": platform.platform(),
                     "python": platform.python_version(),
                     "jax": jax.__version__,
                     "backend": jax.default_backend(),
                     "interpret_mode": True},
            "kernels": kernel_records,
            "layers": layer_records,
            "train": train_records,
            "attn": attn_records,
            "moe": moe_records,
            "serve": serve_records,
            "sharded": sharded_records,
        }
        with open(args.json, "w") as fh:
            json.dump(record, fh, indent=1)
            fh.write("\n")
        print(f"\n[json] wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
