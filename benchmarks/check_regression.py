"""Benchmark regression gate for the adapt-bench-v1 trajectory.

``python benchmarks/check_regression.py [OLD.json NEW.json] [--tol 0.10]``

With no positional args, compares the two newest committed ``BENCH_PR<n>.json``
records at the repo root (sorted by ``n``), so the gate self-maintains as PRs
append to the series. Fails (exit 1) when the new record's ``layers`` entry
for ``mode=fused`` at (256, 256, 256) is more than ``tol`` slower than the
old record's — the headline number docs/benchmarks.md says every PR must
hold. Records are only comparable within the same host/backend pair; the
committed series is produced on the dev container, so CI gates on the
committed files rather than re-timing on shared runners.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

GATE = {"mode": "fused", "M": 256, "K": 256, "N": 256}


def latest_pair() -> tuple[str, str]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    recs = sorted(
        ((int(m.group(1)), p) for p in glob.glob(os.path.join(root, "BENCH_PR*.json"))
         if (m := re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(p)))))
    if len(recs) < 2:
        raise SystemExit(f"need >= 2 BENCH_PR<n>.json records at {root}, "
                         f"found {[p for _, p in recs]}")
    return recs[-2][1], recs[-1][1]


def _fused_256(record: dict, path: str) -> float:
    assert record.get("schema") == "adapt-bench-v1", (path, record.get("schema"))
    for row in record.get("layers", []):
        if all(row.get(k) == v for k, v in GATE.items()):
            return float(row["us_per_call"])
    raise SystemExit(f"{path}: no layers entry matching {GATE}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", nargs="?")
    ap.add_argument("new", nargs="?")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional slowdown (default 10%%)")
    args = ap.parse_args(argv)
    if args.old is None or args.new is None:
        args.old, args.new = latest_pair()
        print(f"comparing newest committed records: {args.old} -> {args.new}")
    with open(args.old) as fh:
        old = _fused_256(json.load(fh), args.old)
    with open(args.new) as fh:
        new = _fused_256(json.load(fh), args.new)
    ratio = new / old
    verdict = "OK" if ratio <= 1.0 + args.tol else "REGRESSION"
    print(f"layers.fused@256^3: {old:.0f}us -> {new:.0f}us "
          f"({ratio:.3f}x, tol {1 + args.tol:.2f}x) {verdict}")
    return 0 if verdict == "OK" else 1


if __name__ == "__main__":
    sys.exit(main())
