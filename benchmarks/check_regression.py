"""Benchmark regression gate for the adapt-bench-v1 trajectory.

``python benchmarks/check_regression.py [OLD.json NEW.json] [--tol 0.25]``

With no positional args, compares the two newest committed ``BENCH_PR<n>.json``
records at the repo root (sorted by ``n``), so the gate self-maintains as PRs
append to the series. Fails (exit 1) when any gated ``layers`` entry in the
new record is more than ``tol`` slower than the old record's:

* ``mode=fused`` at (256, 256, 256) — the fused-dense headline
  docs/benchmarks.md says every PR must hold;
* ``mode=conv_fused`` at the VGG-ish conv shape (M=2048, K=576, N=128) —
  the patch-streaming conv kernel (docs/fused_conv.md), gated from the first
  record that carries it (a gate entry absent from the *old* record is
  reported as a new baseline, not a failure; absent from the *new* record is
  a failure — trajectory entries must never disappear);
* ``mode=conv_tiled`` at the ImageNet-scale 224^2 shape (M=50176, K=576,
  N=64) — the spatially-tiled conv kernel, gated from PR 4 on. The 224^2
  entry additionally enforces a *within-record* floor: tiled must stay at
  least as fast as the eager im2col baseline it replaced
  (``speedup_vs_im2col >= 1``), so the tiled route can never silently
  become a de-optimization;
* the ``train`` section's ``*_fused_bwd`` rows (dense + 224^2 conv
  train-step, docs/fused_conv.md "Approximate backward") — gated from PR 6
  on, each with a within-record floor ``speedup_vs_eager_bwd >= 1``: the
  fused approximate backward must never fall behind the materialized eager
  approximate backward it replaced. The ``*_exact_bwd`` rows are context
  only — interpret-mode LUT gathers cannot beat native XLA f32 GEMMs, so
  exact-f32 is deliberately NOT a floor baseline;
* the ``attn`` section's ``attn_fused`` rows (approximate flash attention,
  docs/benchmarks.md "[attn]") — gated from PR 7 on; the prefill row also
  carries a within-record *parity* floor ``speedup_vs_unfused >= 0.75``.
  The interpreter does not model the HBM round-trips the fusion removes
  (the (Sq, Sk) score matrix the unfused oracle materializes is exactly
  the traffic the interpreter doesn't charge for), so fused vs unfused
  measures ~parity with heavy noise on CPU — the floor only catches the
  fused route becoming a real de-optimization, and demanding a win here
  would wedge the gate for the same reason the exact-bwd rows are not a
  train floor. The decode-step row is trajectory-gated only: at Sq=1
  per-call interpreter overhead dominates both sides;
* the ``serve`` section's ``serve_continuous`` row (continuous-batching
  sustained decode, docs/serving.md) — trajectory-gated µs per generated
  token from PR 7 on, with the within-record floor
  ``speedup_vs_wave >= 1.25``: slot-level admission/eviction must keep
  beating the wave scheduler on the skewed request mix by a real margin,
  or continuous batching has silently stopped paying for its complexity;
* the ``train`` section's ``recovery_damped`` row (gradient-noise batch
  damping, docs/training.md) — within-record floor from PR 9 on:
  ``sample_efficiency >= 1.0``, i.e. the damped QAT recovery reaches the
  fixed-batch run's final recovered accuracy using no more samples than
  the fixed batch consumed (the whole point of the schedule; a damped run
  that never reaches it records 0.0 and fails). These rows carry accuracy
  curves, not timings, so they are deliberately NOT in the trajectory
  (us_per_call) gate list;
* the ``moe`` section's ``moe_grouped`` row (grouped ragged fused LUT-GEMM
  for MoE expert dispatch, docs/moe.md) — trajectory-gated from PR 10 on,
  with the within-record floor ``speedup_vs_vmapped >= 1.0``: the single
  groupinfo-skipping grouped kernel must never fall behind the per-expert
  vmapped composition it replaced (both sides bitwise-identical, so the
  floor is purely about dispatch efficiency). The ``moe_exact`` row is
  context only, for the same reason the exact-bwd train rows are;
* the ``serve`` section's ``serve_paged`` row (paged KV + prefix reuse
  under a fixed HBM budget, docs/serving.md "Paged KV") — trajectory-gated
  µs per generated token from PR 8 on, with two within-record floors:
  ``speedup_vs_contiguous >= 1.0`` (against the contiguous engine serving
  the identical memory-pressure trace under the same budget — paged must
  never lose to the layout it replaced) and ``prefix_hit_rate >= 0.1``
  (the shared-prefix trace must actually hit the prefix cache, or reuse
  has silently broken).

Records are only comparable within the same host/backend pair; the committed
series is produced on the dev container, so CI gates on the committed files
rather than re-timing on shared runners.

The default ``--tol`` is set to the dev container's *measured* same-code
noise floor, not to wishful precision: re-timing the bit-identical PR 8
commit against its own committed record showed individual rows drifting
1.15-1.25x (conv_tiled@224: 2.00M -> 2.32M us) and the attn prefill row up
to 1.4x across a day — the VM's effective CPU speed has minutes-scale modes
that min-of-reps timing cannot average away. A tolerance below that floor
just converts host noise into gate alarms. The *within-record* floors below
are unaffected (both sides of each floor are timed in the same run, so host
drift cancels) — they remain the tight invariants; the trajectory gate
catches real (> noise) de-optimizations and entries silently vanishing.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# (name, record section, row selector)
GATES = [
    ("layers.fused@256^3", "layers",
     {"mode": "fused", "M": 256, "K": 256, "N": 256}),
    ("layers.conv_fused@vgg3x3", "layers",
     {"mode": "conv_fused", "M": 2048, "K": 576, "N": 128}),
    ("layers.conv_tiled@imagenet224", "layers",
     {"mode": "conv_tiled", "M": 50176, "K": 576, "N": 64}),
    ("train.dense_fused_bwd", "train",
     {"mode": "train_dense_fused_bwd"}),
    ("train.conv224_fused_bwd", "train",
     {"mode": "train_conv224_fused_bwd"}),
    ("attn.fused@prefill256", "attn",
     {"mode": "attn_fused", "attn": "prefill256"}),
    ("attn.fused@decode1x256", "attn",
     {"mode": "attn_fused", "attn": "decode1x256"}),
    ("moe.grouped@granite40x8", "moe",
     {"mode": "moe_grouped", "E": 40, "top_k": 8}),
    ("serve.continuous", "serve",
     {"mode": "serve_continuous"}),
    ("serve.paged", "serve",
     {"mode": "serve_paged"}),
]

# within-record floors on the NEW record:
# (name, section, row selector, field, min)
FLOORS = [
    ("layers.conv_tiled@imagenet224 >= im2col", "layers",
     {"mode": "conv_tiled", "M": 50176, "K": 576, "N": 64},
     "speedup_vs_im2col", 1.0),
    ("train.dense_fused_bwd >= eager", "train",
     {"mode": "train_dense_fused_bwd"}, "speedup_vs_eager_bwd", 1.0),
    ("train.conv224_fused_bwd >= eager", "train",
     {"mode": "train_conv224_fused_bwd"}, "speedup_vs_eager_bwd", 1.0),
    ("attn.fused@prefill256 ~parity", "attn",
     {"mode": "attn_fused", "attn": "prefill256"},
     "speedup_vs_unfused", 0.75),
    ("moe.grouped >= vmapped", "moe",
     {"mode": "moe_grouped", "E": 40, "top_k": 8},
     "speedup_vs_vmapped", 1.0),
    ("serve.continuous >= 1.25x wave", "serve",
     {"mode": "serve_continuous"}, "speedup_vs_wave", 1.25),
    ("serve.paged >= contiguous under same budget", "serve",
     {"mode": "serve_paged"}, "speedup_vs_contiguous", 1.0),
    ("serve.paged prefix cache hitting", "serve",
     {"mode": "serve_paged"}, "prefix_hit_rate", 0.1),
    ("train.recovery damped vs fixed-batch samples", "train",
     {"mode": "recovery_damped"}, "sample_efficiency", 1.0),
]


def latest_pair() -> tuple[str, str]:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    recs = sorted(
        ((int(m.group(1)), p) for p in glob.glob(os.path.join(root, "BENCH_PR*.json"))
         if (m := re.fullmatch(r"BENCH_PR(\d+)\.json", os.path.basename(p)))))
    if len(recs) < 2:
        raise SystemExit(f"need >= 2 BENCH_PR<n>.json records at {root}, "
                         f"found {[p for _, p in recs]}")
    return recs[-2][1], recs[-1][1]


def _entry(record: dict, path: str, section: str, gate: dict) -> float | None:
    assert record.get("schema") == "adapt-bench-v1", (path, record.get("schema"))
    for row in record.get(section, []):
        if all(row.get(k) == v for k, v in gate.items()):
            return float(row["us_per_call"])
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("old", nargs="?")
    ap.add_argument("new", nargs="?")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional slowdown (default 25%% — the "
                         "dev container's measured same-code noise floor, "
                         "see module docstring)")
    args = ap.parse_args(argv)
    if args.old is None or args.new is None:
        args.old, args.new = latest_pair()
        print(f"comparing newest committed records: {args.old} -> {args.new}")
    with open(args.old) as fh:
        old_rec = json.load(fh)
    with open(args.new) as fh:
        new_rec = json.load(fh)

    failed = False
    for name, section, gate in GATES:
        old = _entry(old_rec, args.old, section, gate)
        new = _entry(new_rec, args.new, section, gate)
        if old is None and new is None:
            print(f"{name}: absent from both records (gate not yet active)")
            continue
        if old is None:
            print(f"{name}: new baseline {new:.0f}us (no prior entry)")
            continue
        if new is None:
            print(f"{name}: MISSING from {args.new} (present in {args.old}) "
                  f"REGRESSION")
            failed = True
            continue
        ratio = new / old
        ok = ratio <= 1.0 + args.tol
        print(f"{name}: {old:.0f}us -> {new:.0f}us "
              f"({ratio:.3f}x, tol {1 + args.tol:.2f}x) "
              f"{'OK' if ok else 'REGRESSION'}")
        failed |= not ok

    for name, section, sel, field, floor in FLOORS:
        row = next((r for r in new_rec.get(section, [])
                    if all(r.get(k) == v for k, v in sel.items())), None)
        if row is None:
            print(f"{name}: entry absent from {args.new} (floor not yet "
                  f"active)")
            continue
        val = float(row[field])
        ok = val >= floor
        print(f"{name}: {field}={val:.3f} (floor {floor:.2f}) "
              f"{'OK' if ok else 'REGRESSION'}")
        failed |= not ok
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
