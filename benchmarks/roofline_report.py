"""Render dry-run result JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_report results_pod.json
"""
from __future__ import annotations

import json
import sys


def render(path: str) -> None:
    recs = json.load(open(path))
    print(f"### {path}")
    print("| arch | shape | variant | bottleneck | T_comp | T_mem | T_coll | "
          "MODEL/HLO | roofline | args/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | — | SKIP | — | — | — | — | — | — |")
            continue
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | — | ERROR | — | — | — | — | — | — |")
            continue
        ma = r["memory_analysis"]
        print(f"| {r['arch']} | {r['shape']} | {r.get('variant', 'baseline')}"
              f"{'+' + r['acu'] if r.get('acu') else ''} | {r['bottleneck']} | "
              f"{r['t_compute']*1e3:.1f}ms | {r['t_memory']*1e3:.1f}ms | "
              f"{r['t_collective']*1e3:.1f}ms | {r['useful_ratio']:.3f} | "
              f"{r['roofline_frac']*100:.2f}% | "
              f"{ma['argument_bytes']/2**30:.2f}GiB |")
    n_ok = sum(1 for r in recs if "t_compute" in r)
    n_skip = sum(1 for r in recs if "skipped" in r)
    n_err = sum(1 for r in recs if "error" in r)
    print(f"\n{n_ok} compiled / {n_skip} skipped / {n_err} errors\n")


def main():
    paths = sys.argv[1:] or ["results_pod.json", "results_multipod.json",
                             "results_pod_optimized.json"]
    for p in paths:
        try:
            render(p)
        except FileNotFoundError:
            print(f"(missing {p})")


if __name__ == "__main__":
    main()
