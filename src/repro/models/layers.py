"""Shared transformer building blocks (pure functional JAX).

Every GEMM goes through :func:`repro.core.approx_ops.approx_dense`, so the
paper's ACU emulation is a first-class switch on any architecture
(``cfg=None`` -> exact bf16 substrate path).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import (ApproxConfig, approx_attention,
                                   approx_attention_paged, approx_dense,
                                   conv2d)
from repro.parallel.sharding import shard

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# conv building block (vision stacks, GAN generators, audio frontends)
# ---------------------------------------------------------------------------

def conv2d_block(x: Array, w: Array, b: Optional[Array] = None, *,
                 stride=(1, 1), padding="SAME", dilation=(1, 1),
                 groups: int = 1, acfg: Optional[ApproxConfig] = None,
                 activation=None) -> Array:
    """Conv2d + optional bias + optional activation — the shared conv
    call site for every model in this package.

    Routing is resolved per layer by :func:`repro.core.acu.conv_plan`:
    LUT-mode Pallas ACUs run the fused patch-streaming
    im2col->quantize->LUT-GEMM->dequant kernel (the patch tensor never
    reaches HBM) — whole-image resident inside the VMEM budget, spatially
    tiled over halo'd output-row bands above it, so ImageNet-scale (224^2)
    feature maps stay fused — and everything else takes the audited eager
    im2col fallback; under an active mesh the plan shards batch x
    output-row-band rows over ``acu_conv_rows`` and output channels over
    ``acu_conv_cols``. ``acfg=None`` is the exact substrate conv.
    """
    y = conv2d(x, w, b, stride=stride, padding=padding, dilation=dilation,
               groups=groups, cfg=acfg)
    return y if activation is None else activation(y)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-6,
             plus_one: bool = False) -> Array:
    """RMSNorm; ``plus_one`` = gemma-style (1 + w) parameterization."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * w).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, sections=(16, 24, 24),
                theta: float = 10000.0) -> Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) — (temporal, h, w) ids.

    The d/2 rotary frequency channels are partitioned into ``sections``
    (t/h/w); each partition rotates by its own position stream. For text-only
    tokens all three streams are equal and M-RoPE reduces to RoPE.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    # build per-channel position selector
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])  # (d/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32).transpose(1, 2, 0),      # (B, S, 3)
        sec[None, None, :].astype(jnp.int32) * jnp.ones(
            (*positions.shape[1:], 1), jnp.int32), axis=-1)    # (B, S, d/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _mask_scores(s: Array, q_pos: Array, k_pos: Array, causal: bool,
                 window: Optional[int],
                 pad_mask: Optional[Array] = None) -> Array:
    if q_pos.ndim == 2:
        # per-row query positions (continuous batching: every slot decodes
        # at its own cache offset) — the structural mask gains a batch dim
        mask = jnp.ones((q_pos.shape[0], *s.shape[-2:]), bool)
        if causal:
            mask &= k_pos[None, None, :] <= q_pos[:, :, None]
        if window is not None:
            mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
        if pad_mask is not None:
            mask &= pad_mask[:, None, :]
        return jnp.where(mask[:, None, None], s, -1e30)
    mask = jnp.ones(s.shape[-2:], bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if pad_mask is not None:
        # (B, Tk) valid-key mask (serving: left-pad slots are False) joins
        # the (cq, Tk) structural mask batched: (B, 1, 1, cq, Tk)
        return jnp.where(mask[None, None, None] & pad_mask[:, None, None, None, :],
                         s, -1e30)
    return jnp.where(mask, s, -1e30)


def gqa_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: Optional[int] = None, softcap: Optional[float] = None,
                  q_offset: int = 0, chunk: int = 512,
                  impl: str = "chunked", causal_blocking: bool = False,
                  pad_mask: Optional[Array] = None) -> Array:
    """Grouped-query attention.

    q: (B, S, Hq, D); k/v: (B, T, Hkv, D); returns (B, S, Hq, D).
    ``q_offset``: absolute position of q[0] within the key sequence (decode) —
    an int/scalar, or a (B,) int vector when every batch row sits at its own
    cache position (continuous batching).
    ``chunked`` processes q in blocks of ``chunk`` for O(S·chunk) score memory.
    ``pad_mask``: optional (B, T) bool, False keys are never attended (batched
    serving masks left-pad slots out of every query row).
    """
    b, s_len, hq, d = q.shape
    t_len = k.shape[1]
    hkv = k.shape[2]
    rep = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, s_len, hkv, rep, d)
    per_row = jnp.ndim(q_offset) == 1

    def q_positions(start: int, length: int) -> Array:
        pos = jnp.arange(length) + start
        if per_row:
            return pos[None, :] + jnp.asarray(q_offset, jnp.int32)[:, None]
        return pos + q_offset

    def block(q_blk: Array, q_pos: Array, k_blk: Array, v_blk: Array,
              k_pos: Array, pm: Optional[Array]) -> Array:
        # q_blk: (B, cq, Hkv, rep, D) -> scores (B, Hkv, rep, cq, Tk)
        sc = jnp.einsum("bqhrd,bthd->bhrqt", q_blk.astype(jnp.float32),
                        k_blk.astype(jnp.float32)) * scale
        if softcap is not None:
            sc = softcap * jnp.tanh(sc / softcap)
        sc = _mask_scores(sc, q_pos, k_pos, causal, window, pm)
        p = jax.nn.softmax(sc, axis=-1)
        o = jnp.einsum("bhrqt,bthd->bqhrd", p, v_blk.astype(jnp.float32))
        return o

    if impl == "naive" or s_len <= chunk or s_len % chunk != 0:
        out = block(qg, q_positions(0, s_len), k, v,
                    jnp.arange(t_len), pad_mask)
    else:
        # statically unrolled q-block loop (NOT lax.map): keeps score memory at
        # O(S*chunk) while every block appears in the HLO, so cost_analysis
        # counts the true attention FLOPs (DESIGN.md §7 — scan bodies are
        # counted once). XLA reuses the temp buffers across blocks.
        n_blk = s_len // chunk
        outs = []
        for i in range(n_blk):
            q_blk = jax.lax.dynamic_slice_in_dim(qg, i * chunk, chunk, axis=1)
            pos = q_positions(i * chunk, chunk)
            if causal_blocking and causal and isinstance(q_offset, int) \
                    and q_offset == 0 and s_len == t_len:
                # §Perf hillclimb: a causal q-block only sees keys < its end;
                # slicing K/V per block drops ~half the attention FLOPs.
                hi = (i + 1) * chunk
                if window is not None:
                    lo = max(0, i * chunk - window)
                else:
                    lo = 0
                k_blk = k[:, lo:hi]
                v_blk = v[:, lo:hi]
                k_pos = jnp.arange(lo, hi)
                pm = None if pad_mask is None else pad_mask[:, lo:hi]
            else:
                k_blk, v_blk, k_pos, pm = k, v, jnp.arange(t_len), pad_mask
            outs.append(block(q_blk, pos, k_blk, v_blk, k_pos, pm))
        out = jnp.concatenate(outs, axis=1)
    return out.reshape(b, s_len, hq, d).astype(q.dtype)


def attention_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig],
                    positions: Array, *, kv: Optional[tuple] = None,
                    cache=None, cache_pos: Optional[Array] = None,
                    window: Optional[int] = None, causal: bool = True,
                    pad_mask: Optional[Array] = None,
                    page_table: Optional[Array] = None):
    """Full attention sub-layer: qkv proj -> rope -> attention -> out proj.

    ``cache``: optional (k_cache, v_cache) of shape (B, Smax, Hkv, D);
    returns (out, new_cache). ``kv``: cross-attention source (B, T, D).
    ``pad_mask``: (B, T) bool over the key length (the full cache when one is
    threaded) — False slots never contribute to any query.

    ``page_table`` switches the cache to the block-paged layout: ``cache``
    is then (k_pool, v_pool) of shape (Hkv, P, block, D) — a physical block
    pool shared by every row — and ``page_table`` (B, n_logical) int32 maps
    each row's logical KV blocks to pool blocks. New K/V append through the
    table (decode: per-row scatter at ``cache_pos``; prefill: batch-1
    block-aligned chunks of at most one block), attention reads through it
    (fused paged kernel, or an exact gather fallback when the plan audits
    to dense). No left-padding exists in the paged scheme, so ``pad_mask``
    is ignored here.
    """
    b, s_len, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = approx_dense(x, p["wq"], p.get("bq"), acfg).reshape(b, s_len, h, hd)
    src = x if kv is None else kv
    t0 = src.shape[1]
    k = approx_dense(src, p["wk"], p.get("bk"), acfg).reshape(b, t0, hkv, hd)
    v = approx_dense(src, p["wv"], p.get("bv"), acfg).reshape(b, t0, hkv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    if kv is None and cfg.rope != "none":
        if cfg.rope == "mrope":
            mpos = jnp.broadcast_to(positions[None], (3, *positions.shape))
            q = apply_mrope(q, mpos, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, mpos, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", "seq_kv", "kv_heads", None)
    v = shard(v, "batch", "seq_kv", "kv_heads", None)

    if page_table is not None:
        assert cache is not None and kv is None, \
            "paged KV needs a (k_pool, v_pool) self-attention cache"
        kc, vc = cache
        hkv_p, _, blk, _ = kc.shape
        pt = jnp.asarray(page_table, jnp.int32)
        pos = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32).reshape(-1), (b,))
        if s_len == 1:
            # decode: each row scatters its one new KV into its own tail
            # block (CoW in the engine guarantees tail blocks are private)
            phys = jnp.take_along_axis(pt, (pos // blk)[:, None], axis=1)[:, 0]
            off = pos % blk
            kc = kc.at[:, phys, off].set(
                jnp.swapaxes(k[:, 0], 0, 1).astype(kc.dtype))
            vc = vc.at[:, phys, off].set(
                jnp.swapaxes(v[:, 0], 0, 1).astype(vc.dtype))
        else:
            # block-aligned chunked prefill: one request, one chunk starting
            # on a block boundary and fitting inside a single block
            assert b == 1 and s_len <= blk, (b, s_len, blk)
            phys = pt[0, pos[0] // blk]
            off = pos[0] % blk
            kc = jax.lax.dynamic_update_slice(
                kc, jnp.swapaxes(k[0], 0, 1)[:, None].astype(kc.dtype),
                (0, phys, off, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, jnp.swapaxes(v[0], 0, 1)[:, None].astype(vc.dtype),
                (0, phys, off, 0))
        cache = (kc, vc)
        rowinfo = jnp.stack([pos, jnp.zeros_like(pos), pos + s_len], axis=1)
        fused = None
        if acfg is not None and not acfg.fake_quant_only:
            fused = approx_attention_paged(
                q.transpose(0, 2, 1, 3), kc, vc, acfg, page_table=pt,
                rowinfo=rowinfo, causal=causal, window=window,
                softcap=cfg.softcap_attn)
        if fused is not None:
            out = fused.transpose(0, 2, 1, 3).astype(q.dtype)
        else:
            # exact fallback: gather the referenced blocks back into a
            # contiguous (B, n_logical*block, Hkv, D) view — exact math is
            # layout-independent, and positions >= kv_len are masked out
            n_log = pt.shape[1]
            kg = jnp.moveaxis(kc[:, pt].reshape(hkv_p, b, n_log * blk, hd),
                              0, 2)
            vg = jnp.moveaxis(vc[:, pt].reshape(hkv_p, b, n_log * blk, hd),
                              0, 2)
            pm = jnp.arange(n_log * blk)[None, :] < (pos + s_len)[:, None]
            out = gqa_attention(q, kg, vg, causal=causal,
                                softcap=cfg.softcap_attn, window=window,
                                q_offset=pos, chunk=cfg.attn_chunk,
                                impl=cfg.attn_impl, pad_mask=pm)
        out = out.reshape(b, s_len, h * hd)
        out = approx_dense(out, p["wo"], p.get("bo"), acfg)
        return out, cache

    q_offset = 0
    if cache is not None:
        kc, vc = cache
        if kv is None:  # self-attention: append to cache
            if jnp.ndim(cache_pos) == 1:
                # continuous batching: every slot writes at its own offset
                upd = jax.vmap(lambda c, new, p0: jax.lax.
                               dynamic_update_slice_in_dim(c, new, p0, axis=0))
                kc = upd(kc, k.astype(kc.dtype), cache_pos)
                vc = upd(vc, v.astype(vc.dtype), cache_pos)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cache_pos, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cache_pos, axis=1)
            k, v = kc, vc
            cache = (kc, vc)
        q_offset = cache_pos
        # mask out not-yet-written cache slots via causal masking at q_offset

    if acfg is not None and not acfg.fake_quant_only and kv is None \
            and cache is not None:
        # ACU route: fused quantize->LUT-gather QK^T / PV inside the
        # streaming-softmax kernel (core/acu.attn_plan). Falls through to the
        # exact-substrate gqa_attention when the plan audits to "dense".
        b_rows = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32).reshape(-1), (b,))
        if pad_mask is not None:
            # serving pad is left-contiguous: first True marks the kv start
            kv_start = jnp.argmax(pad_mask, axis=1).astype(jnp.int32)
        else:
            kv_start = jnp.zeros((b,), jnp.int32)
        rowinfo = jnp.stack([b_rows, kv_start, b_rows + s_len], axis=1)
        fused = approx_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), acfg, causal=causal, window=window,
            softcap=cfg.softcap_attn, rowinfo=rowinfo)
        if fused is not None:
            out = fused.transpose(0, 2, 1, 3).astype(q.dtype)
            out = out.reshape(b, s_len, h * hd)
            out = approx_dense(out, p["wo"], p.get("bo"), acfg)
            return out, cache

    out = gqa_attention(q, k, v, causal=causal and kv is None, window=window,
                        softcap=cfg.softcap_attn, q_offset=q_offset,
                        chunk=cfg.attn_chunk, impl=cfg.attn_impl,
                        causal_blocking=getattr(cfg, "attn_causal_blocking", False),
                        pad_mask=pad_mask)
    out = out.reshape(b, s_len, h * hd)
    out = approx_dense(out, p["wo"], p.get("bo"), acfg)
    return out, cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig]) -> Array:
    """Gated (SwiGLU/GeGLU) or plain-GELU MLP, TP-sharded on the hidden dim."""
    if cfg.mlp_type in ("swiglu", "geglu"):
        gate = approx_dense(x, p["w_gate"], None, acfg)
        up = approx_dense(x, p["w_up"], None, acfg)
        act = jax.nn.silu(gate) if cfg.mlp_type == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(approx_dense(x, p["w_up"], p.get("b_up"), acfg))
    h = shard(h, "batch", None, "mlp")
    return approx_dense(h, p["w_down"], p.get("b_down"), acfg)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed(tokens: Array, table: Array) -> Array:
    return jnp.take(table, tokens, axis=0)


def lm_head(x: Array, w: Array, acfg: Optional[ApproxConfig],
            softcap: Optional[float] = None) -> Array:
    logits = approx_dense(x, w, None, acfg)
    logits = shard(logits, "batch", None, "vocab")
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits: Array, labels: Array, n_valid_vocab: int) -> Array:
    """Mean next-token CE; padded vocab columns masked out."""
    v = logits.shape[-1]
    if n_valid_vocab < v:
        neg = jnp.full((v - n_valid_vocab,), -1e30, logits.dtype)
        logits = logits.at[..., n_valid_vocab:].set(neg)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
