"""Decoder-only LM engine: init / forward / prefill / decode for every
assigned architecture via the layer-pattern system.

Layers are stacked into repeating *groups* (``cfg.pattern``) and the forward
pass is a ``lax.scan`` over groups — HLO stays one-group-sized regardless of
depth (compile time, and the roofline extractor's two-point unroll method
depends on this structure; see DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx_ops import ApproxConfig
from repro.models import layers as L
from repro.models.mamba import MambaState, mamba_block
from repro.models.moe import moe_block
from repro.models.rwkv import RwkvState, rwkv_block
from repro.parallel.sharding import shard

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_params(cfg: ModelConfig, shape_d: int, g: int) -> dict:
    if cfg.norm == "ln":
        return {"w": jnp.ones((g, shape_d), jnp.float32),
                "b": jnp.zeros((g, shape_d), jnp.float32)}
    init = jnp.zeros if cfg.norm == "rms1p" else jnp.ones
    return {"w": init((g, shape_d), jnp.float32)}


def _dense_init(key, g, din, dout, cfg, scale=None):
    scale = scale or (din ** -0.5)
    return (jax.random.normal(key, (g, din, dout), jnp.float32) * scale
            ).astype(cfg.param_dtype)


def _init_attn(key, cfg: ModelConfig, g: int, cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], g, d, h * hd, cfg),
        "wk": _dense_init(ks[1], g, d, hkv * hd, cfg),
        "wv": _dense_init(ks[2], g, d, hkv * hd, cfg),
        "wo": _dense_init(ks[3], g, h * hd, d, cfg),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((g, h * hd), cfg.param_dtype)
        p["bk"] = jnp.zeros((g, hkv * hd), cfg.param_dtype)
        p["bv"] = jnp.zeros((g, hkv * hd), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((g, hd), jnp.float32)
        p["k_norm"] = jnp.ones((g, hd), jnp.float32)
    return p


def _init_mlp(key, cfg: ModelConfig, g: int) -> dict:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": _dense_init(ks[0], g, d, f, cfg),
                "w_up": _dense_init(ks[1], g, d, f, cfg),
                "w_down": _dense_init(ks[2], g, f, d, cfg)}
    return {"w_up": _dense_init(ks[0], g, d, f, cfg),
            "b_up": jnp.zeros((g, f), cfg.param_dtype),
            "w_down": _dense_init(ks[1], g, f, d, cfg),
            "b_down": jnp.zeros((g, d), cfg.param_dtype)}


def _init_moe(key, cfg: ModelConfig, g: int) -> dict:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = d ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (g, d, e), jnp.float32) * s
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (g, e, d, f), jnp.float32) * s
                   ).astype(cfg.param_dtype),
        "w_up": (jax.random.normal(ks[2], (g, e, d, f), jnp.float32) * s
                 ).astype(cfg.param_dtype),
        "w_down": (jax.random.normal(ks[3], (g, e, f, d), jnp.float32) * (f ** -0.5)
                   ).astype(cfg.param_dtype),
    }


def _init_mamba(key, cfg: ModelConfig, g: int) -> dict:
    ks = jax.random.split(key, 6)
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = cfg.mamba_dt_rank, cfg.mamba_d_conv
    return {
        "in_proj": _dense_init(ks[0], g, d, 2 * di, cfg),
        "conv_w": (jax.random.normal(ks[1], (g, dc, di), jnp.float32) * 0.1
                   ).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((g, di), cfg.param_dtype),
        "x_proj": _dense_init(ks[2], g, di, dtr + 2 * ds, cfg),
        "dt_proj": _dense_init(ks[3], g, dtr, di, cfg),
        "dt_bias": jnp.full((g, di), -4.6, cfg.param_dtype),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (g, di, ds))),
        "Dskip": jnp.ones((g, di), cfg.param_dtype),
        "out_proj": _dense_init(ks[4], g, di, d, cfg),
    }


def _init_rwkv(key, cfg: ModelConfig, g: int) -> dict:
    ks = jax.random.split(key, 12)
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    lora_r = max(32, d // 32)
    decay_r = max(64, d // 16)
    p = {
        "ln1_w": jnp.ones((g, d), jnp.float32), "ln1_b": jnp.zeros((g, d), jnp.float32),
        "ln2_w": jnp.ones((g, d), jnp.float32), "ln2_b": jnp.zeros((g, d), jnp.float32),
        "lora_A": _dense_init(ks[0], g, d, lora_r, cfg),
        "Wdecay_A": _dense_init(ks[1], g, d, decay_r, cfg),
        "Wdecay_B": (jax.random.normal(ks[2], (g, decay_r, d), jnp.float32) * 1e-2
                     ).astype(cfg.param_dtype),
        "decay_base": jnp.full((g, d), 0.5, jnp.float32),
        "bonus": jnp.zeros((g, d), jnp.float32),
        "Wr": _dense_init(ks[3], g, d, d, cfg),
        "Wk": _dense_init(ks[4], g, d, d, cfg),
        "Wv": _dense_init(ks[5], g, d, d, cfg),
        "Wg": _dense_init(ks[6], g, d, d, cfg),
        "Wo": _dense_init(ks[7], g, d, d, cfg),
        "ln_w": jnp.ones((g, d), jnp.float32), "ln_b": jnp.zeros((g, d), jnp.float32),
        "Wk_cm": _dense_init(ks[8], g, d, f, cfg),
        "Wv_cm": _dense_init(ks[9], g, f, d, cfg),
        "Wr_cm": _dense_init(ks[10], g, d, d, cfg),
    }
    for mu in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w", "cm_mu_k", "cm_mu_r"):
        p[mu] = jnp.full((g, d), 0.5, jnp.float32)
    for b in ("lora_B_r", "lora_B_k", "lora_B_v", "lora_B_g", "lora_B_w"):
        p[b] = jnp.zeros((g, lora_r, d), cfg.param_dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    """Full parameter pytree; group-stacked leaves of shape (n_groups, ...)."""
    keys = jax.random.split(key, len(cfg.pattern) + 3)
    g = cfg.n_groups
    d, v = cfg.d_model, cfg.vocab_padded
    groups: dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        ki = jax.random.split(keys[i], 4)
        blk: dict[str, Any] = {"norm1": _norm_params(cfg, d, g)}
        if kind.startswith("attn"):
            blk["attn"] = _init_attn(ki[0], cfg, g)
            blk["norm2"] = _norm_params(cfg, d, g)
            if cfg.post_norm:
                blk["post_norm1"] = _norm_params(cfg, d, g)
                blk["post_norm2"] = _norm_params(cfg, d, g)
            blk["mlp"] = (_init_moe(ki[1], cfg, g) if kind.endswith("moe")
                          else _init_mlp(ki[1], cfg, g))
        elif kind.startswith("mamba"):
            blk["mamba"] = _init_mamba(ki[0], cfg, g)
            blk["norm2"] = _norm_params(cfg, d, g)
            blk["mlp"] = (_init_moe(ki[1], cfg, g) if kind.endswith("moe")
                          else _init_mlp(ki[1], cfg, g))
        elif kind == "rwkv":
            blk = {"rwkv": _init_rwkv(ki[0], cfg, g)}
        else:
            raise ValueError(kind)
        groups[f"b{i}"] = blk
    params = {
        "embed": (jax.random.normal(keys[-3], (v, d), jnp.float32) * (d ** -0.5)
                  ).astype(cfg.param_dtype),
        "groups": groups,
        "final_norm": _norm_params(cfg, d, 1),
    }
    if not cfg.tie_embed:
        params["lm_head"] = _dense_init(keys[-2], 1, d, v, cfg)[0]
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _norm(x, p, cfg: ModelConfig):
    if cfg.norm == "ln":
        return L.layer_norm(x, p["w"], p["b"])
    return L.rms_norm(x, p["w"], plus_one=(cfg.norm == "rms1p"))


def _apply_block(x, blk, kind, cfg, acfg, positions, cache, cache_pos, decode,
                 pad_mask=None, page_table=None):
    """One layer; returns (x, new_cache_entry)."""
    new_cache = cache
    if kind.startswith("attn"):
        window = cfg.window_size if kind == "attn_local" else None
        h = _norm(x, blk["norm1"], cfg)
        attn_cache = cache["attn"] if cache is not None else None
        a, attn_cache = L.attention_block(
            h, blk["attn"], cfg, acfg, positions, cache=attn_cache,
            cache_pos=cache_pos, window=window, pad_mask=pad_mask,
            page_table=page_table)
        if cfg.post_norm:
            a = _norm(a, blk["post_norm1"], cfg)
        if cfg.parallel_block:
            m = mlp_apply(h, blk["mlp"], kind, cfg, acfg)
            x = x + a + m
        else:
            x = x + a
            h2 = _norm(x, blk["norm2"], cfg)
            m = mlp_apply(h2, blk["mlp"], kind, cfg, acfg)
            if cfg.post_norm:
                m = _norm(m, blk["post_norm2"], cfg)
            x = x + m
        if cache is not None:
            new_cache = {**cache, "attn": attn_cache}
    elif kind.startswith("mamba"):
        h = _norm(x, blk["norm1"], cfg)
        st = cache["mamba"] if cache is not None else None
        m, st = mamba_block(h, blk["mamba"], cfg, acfg, state=st, decode=decode)
        x = x + m
        h2 = _norm(x, blk["norm2"], cfg)
        x = x + mlp_apply(h2, blk["mlp"], kind, cfg, acfg)
        if cache is not None:
            new_cache = {**cache, "mamba": st}
    elif kind == "rwkv":
        st = cache["rwkv"] if cache is not None else None
        x, st = rwkv_block(x, blk["rwkv"], cfg, acfg, state=st, decode=decode)
        if cache is not None:
            new_cache = {**cache, "rwkv": st}
    return x, new_cache


def mlp_apply(h, p, kind, cfg, acfg):
    if kind.endswith("moe"):
        return moe_block(h, p, cfg, acfg)
    return L.mlp_block(h, p, cfg, acfg)


def apply_model(params: dict, tokens: Array, cfg: ModelConfig, *,
                acfg: Optional[ApproxConfig] = None, cache: Optional[dict] = None,
                cache_pos: int | Array = 0, decode: bool = False,
                last_only: bool = False, pos_offset: Optional[Array] = None,
                pad_mask: Optional[Array] = None,
                page_table: Optional[Array] = None):
    """Token ids -> logits. With ``cache``, also threads KV/SSM state.

    cache: {"groups": pytree stacked (n_groups, ...)}; returns (logits, cache).

    Batched serving with left-padded prompts passes ``pos_offset`` (B,) —
    each row's pad count, subtracted from RoPE positions so every request
    sees positions 0..len-1 regardless of wave padding — and ``pad_mask``
    (B, T) over the key length so pad slots never contribute attention mass
    (attention layers only; recurrent blocks still ingest pads).

    ``page_table`` (B, n_logical) int32 switches attention caches to the
    block-paged layout (:func:`init_paged_cache`): one physical pool per
    layer shared by all rows, the same table threaded to every attention
    layer (the engine allocates blocks per slot, not per layer).
    """
    b, s = tokens.shape
    x = L.embed(tokens, params["embed"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = shard(x, "batch", None, None)
    cp = jnp.asarray(cache_pos)
    # cache_pos may be a (B,) vector — continuous batching, every slot decodes
    # at its own cache offset — or the usual scalar (wave serving / training)
    positions = jnp.arange(s)[None, :] + (cp[:, None] if cp.ndim == 1 else cp)
    if pos_offset is not None:
        positions = jnp.maximum(positions - pos_offset[:, None], 0)
    positions = jnp.broadcast_to(positions, (b, s))

    group_cache = cache["groups"] if cache is not None else None

    def group_body(xc, scanned):
        x = xc
        gp, gc = scanned
        new_gc = gc
        for i, kind in enumerate(cfg.pattern):
            blk_cache = None if gc is None else gc[f"b{i}"]
            x, blk_cache = _apply_block(x, gp[f"b{i}"], kind, cfg, acfg,
                                        positions, blk_cache, cache_pos, decode,
                                        pad_mask, page_table)
            if new_gc is not None:
                new_gc = {**new_gc, f"b{i}": blk_cache}
        return x, new_gc

    body = group_body
    if cfg.remat and cache is None:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(group_body, policy=policy)

    if group_cache is None:
        x, _ = jax.lax.scan(lambda c, gp: body(c, (gp, None)),
                            x, params["groups"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        x, new_groups = jax.lax.scan(body, x, (params["groups"], group_cache),
                                     unroll=cfg.scan_unroll)
        new_cache = {"groups": new_groups}

    if last_only:
        # serving prefill: only the last position's logits are needed —
        # skips a (B, S, V) logits tensor and its GEMM
        x = x[:, -1:]
    x = _norm(x, jax.tree.map(lambda a: a[0], params["final_norm"]), cfg)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = L.lm_head(x, head, acfg, softcap=cfg.softcap_final)
    return logits, new_cache


def loss_fn(params, tokens, labels, cfg: ModelConfig,
            acfg: Optional[ApproxConfig] = None) -> Array:
    logits, _ = apply_model(params, tokens, cfg, acfg=acfg)
    return L.cross_entropy(logits, labels, cfg.vocab_size)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    """Decode cache pytree, group-stacked like params."""
    dtype = dtype or cfg.param_dtype
    g = cfg.n_groups
    groups = {}
    for i, kind in enumerate(cfg.pattern):
        if kind.startswith("attn"):
            kv = jnp.zeros((g, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
            groups[f"b{i}"] = {"attn": (kv, kv)}
        elif kind.startswith("mamba"):
            groups[f"b{i}"] = {"mamba": MambaState(
                conv=jnp.zeros((g, batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
                ssm=jnp.zeros((g, batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
            )}
        elif kind == "rwkv":
            hd = cfg.rwkv_head_dim
            groups[f"b{i}"] = {"rwkv": RwkvState(
                tm_shift=jnp.zeros((g, batch, 1, cfg.d_model), dtype),
                wkv=jnp.zeros((g, batch, cfg.rwkv_n_heads, hd, hd), jnp.float32),
                cm_shift=jnp.zeros((g, batch, 1, cfg.d_model), dtype),
            )}
    return {"groups": groups}


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=None) -> dict:
    """Block-paged decode cache: per attention layer one physical pool
    ``(n_groups, Hkv, n_blocks, block_size, head_dim)`` shared by every
    sequence; rows address it through the ``page_table`` threaded into
    :func:`apply_model`. Physical block 0 is the engine's permanently-zero
    *null block* (page tables default to it, so unallocated logical blocks
    gather zeros — matching what a contiguous cache holds past its fill).
    Only attention layers page; recurrent state is O(1) per slot and keeps
    its dense layout.
    """
    dtype = dtype or cfg.param_dtype
    g = cfg.n_groups
    groups = {}
    for i, kind in enumerate(cfg.pattern):
        if kind.startswith("attn"):
            shape = (g, cfg.n_kv_heads, n_blocks, block_size, cfg.head_dim)
            # distinct arrays: an aliased (pool, pool) pair breaks buffer
            # donation in the serve engine's jitted steps
            groups[f"b{i}"] = {"attn": (jnp.zeros(shape, dtype),
                                        jnp.zeros(shape, dtype))}
        else:
            raise NotImplementedError("paged cache covers attention-only "
                                      f"patterns; got {kind!r}")
    return {"groups": groups}
