"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

Dispatch is scatter/gather (not GShard one-hot einsum): a (T, E, C) one-hot
dispatch tensor is O(T^2)-ish at LM scale, while the scatter form moves
exactly T*k rows.

Two dispatch layouts (cfg.moe_shard_dispatch — §Perf hillclimb #1):

* ``False`` — *global* capacity buffers (E, C, D). Faithful to GShard
  semantics, but the buffer is unshardable when E doesn't divide the model
  axis and the combine-gather crosses shards: GSPMD replicates ~E*C*D bytes
  per layer (granite: 16 GB of all-gather per layer — the recorded baseline).
* ``True``  — *block-local* dispatch: tokens are grouped into ``data``-aligned
  blocks; each block routes into its own (E, C/nb) slice. Every dispatch
  gather/scatter is then shard-local; only the expert weights (TP) or the
  expert dim (EP) move across devices. Per-block capacity is the standard
  local-capacity relaxation of GShard.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense
from repro.parallel.sharding import current_mesh_context, shard

Array = jnp.ndarray


def _route(xf: Array, router: Array, k: int):
    gate_logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)               # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e


def _expert_ffn(xe: Array, p: dict, cfg, acfg, block_axes):
    """xe: (..., E, C, D) -> (..., E, C, D) through the gated expert FFN."""
    if acfg is None:
        gate = jnp.einsum("...ecd,edf->...ecf", xe, p["w_gate"])
        up = jnp.einsum("...ecd,edf->...ecf", xe, p["w_up"])
        h = jax.nn.silu(gate) * up
        h = shard(h, *block_axes, "experts", None, "expert_mlp")
        return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])

    def one(xe_e, wg, wu, wd):
        h = jax.nn.silu(approx_dense(xe_e, wg, None, acfg)) * \
            approx_dense(xe_e, wu, None, acfg)
        return approx_dense(h, wd, None, acfg)

    fn = jax.vmap(one, in_axes=(0, 0, 0, 0))
    if xe.ndim == 4:  # leading block dim
        fn = jax.vmap(fn, in_axes=(0, None, None, None))
    return fn(xe, p["w_gate"], p["w_up"], p["w_down"])


def _dispatch_blocks(cfg, t: int) -> int:
    """Number of data-aligned dispatch blocks (1 disables block-locality)."""
    if not cfg.moe_shard_dispatch:
        return 1
    ctx = current_mesh_context()
    nb = 1
    if ctx is not None:
        for a in ("pod", "data"):
            if a in ctx.mesh.axis_names:
                nb *= ctx.mesh.shape[a]
    else:
        nb = 16  # planner default when traced without a mesh (tests)
    while t % nb != 0 or nb > t:
        nb //= 2
    return max(nb, 1)


def moe_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig]) -> Array:
    """x: (B, S, D) -> (B, S, D).

    p: router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * s
    xf = x.reshape(t, d)
    top_p, top_e = _route(xf, p["router"], k)

    nb = _dispatch_blocks(cfg, t)
    tb = t // nb                 # tokens per block
    cap = int(max(1, round(tb * k / e * cfg.moe_capacity)))

    # ---- block-local slot assignment -----------------------------------
    flat_e = top_e.reshape(nb, tb * k)                         # (nb, TBk)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (nb, TBk, E)
    onehot = shard(onehot, "expert_blocks", None, None)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                  # within block
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = slot < cap                                          # (nb, TBk)
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)       # (nb, TBk)

    # scatter token indices into per-block buffers (trash slot at the end)
    tok_in_block = jnp.arange(tb * k, dtype=jnp.int32) // k    # (TBk,)
    idx_buf = jnp.zeros((nb, e * cap + 1), jnp.int32)
    idx_buf = idx_buf.at[jnp.arange(nb)[:, None], dest].set(tok_in_block[None] + 1)
    idx_buf = idx_buf[:, :-1]                                  # (nb, E*cap)

    # gather rows (block-local): xfb (nb, TB, D) -> xe (nb, E, cap, D)
    xfb = xf.reshape(nb, tb, d)
    xfb = shard(xfb, "expert_blocks", None, None)
    xe = jnp.take_along_axis(
        xfb, jnp.maximum(idx_buf - 1, 0)[..., None], axis=1)
    xe = xe * (idx_buf > 0)[..., None].astype(x.dtype)
    xe = xe.reshape(nb, e, cap, d)
    xe = shard(xe, "expert_blocks", "experts", None, None)

    ye = _expert_ffn(xe, p, cfg, acfg, ("expert_blocks",))
    ye = shard(ye, "expert_blocks", "experts", None, None)

    # combine (block-local gather + routed weights)
    yeb = ye.reshape(nb, e * cap, d)
    src = jnp.where(keep, flat_e * cap + slot, 0)              # (nb, TBk)
    yk = jnp.take_along_axis(yeb, src[..., None], axis=1)      # (nb, TBk, D)
    yk = jnp.where(keep[..., None], yk, 0.0).reshape(t, k, d)
    out = (yk * top_p[:, :, None].astype(yk.dtype)).sum(axis=1)
    return out.reshape(b, s, d)


def router_aux_loss(x: Array, router: Array, n_experts: int, top_k: int) -> Array:
    """Switch-style load-balancing auxiliary loss."""
    t = x.shape[0] * x.shape[1]
    logits = x.reshape(t, -1).astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, top_e = jax.lax.top_k(probs, top_k)
    frac_tokens = jax.nn.one_hot(top_e, n_experts).mean(axis=(0, 1))
    frac_probs = probs.mean(0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)
