"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

Dispatch is scatter/gather (not GShard one-hot einsum): a (T, E, C) one-hot
dispatch tensor is O(T^2)-ish at LM scale, while the scatter form moves
exactly T*k rows.

Two dispatch layouts (cfg.moe_shard_dispatch — §Perf hillclimb #1):

* ``False`` — *global* capacity buffers (E, C, D). Faithful to GShard
  semantics, but the buffer is unshardable when E doesn't divide the model
  axis and the combine-gather crosses shards: GSPMD replicates ~E*C*D bytes
  per layer (granite: 16 GB of all-gather per layer — the recorded baseline).
* ``True``  — *block-local* dispatch: tokens are grouped into ``data``-aligned
  blocks; each block routes into its own (E, C/nb) slice. Every dispatch
  gather/scatter is then shard-local; only the expert weights (TP) or the
  expert dim (EP) move across devices. Per-block capacity is the standard
  local-capacity relaxation of GShard.

Expert GEMMs under an ``ApproxConfig`` run as ONE grouped ragged fused
LUT-GEMM per projection (``approx_grouped_dense`` — docs/moe.md): all
``nb * E`` capacity buffers walk a single ``pallas_call`` whose groupinfo
lets it skip row-blocks past each group's live token count, instead of
launching E (or nb*E) separate kernels that all run ``cap`` rows. QAT
(``fake_quant_only``) keeps the per-expert vmapped path — fake-quant has no
LUT kernel to fuse.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense, approx_grouped_dense
from repro.parallel.sharding import current_mesh_context, shard

Array = jnp.ndarray


def _route(xf: Array, router: Array, k: int):
    """Router products: full softmax probs (T, E) plus renormalized top-k
    weights/indices (T, k). One softmax serves both dispatch and the
    load-balancing aux loss (``moe_block`` stats) — callers reuse these
    instead of re-running the router."""
    gate_logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)               # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def _aux_loss(probs: Array, top_e: Array, n_experts: int) -> Array:
    """Switch-style load-balancing loss from routing products already in
    hand: E * sum(frac_tokens_per_expert * mean_router_prob_per_expert)."""
    frac_tokens = jax.nn.one_hot(top_e, n_experts).mean(
        axis=tuple(range(top_e.ndim)))
    frac_probs = probs.reshape(-1, n_experts).mean(0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def _expert_ffn(xe: Array, p: dict, cfg, acfg, block_axes,
                counts: Optional[Array] = None):
    """xe: (..., E, C, D) -> (..., E, C, D) through the gated expert FFN.

    ``counts`` (matching xe's leading block/expert dims) gives the live row
    count of each capacity buffer; with an approx config the three
    projections run as grouped ragged fused LUT-GEMMs that skip row-blocks
    past the counts. Rows at or beyond a buffer's count come back exactly
    0.0 from the grouped path (dead-row contract, see docs/moe.md).
    """
    if acfg is None:
        gate = jnp.einsum("...ecd,edf->...ecf", xe, p["w_gate"])
        up = jnp.einsum("...ecd,edf->...ecf", xe, p["w_up"])
        h = jax.nn.silu(gate) * up
        h = shard(h, *block_axes, "experts", None, "expert_mlp")
        return jnp.einsum("...ecf,efd->...ecd", h, p["w_down"])

    if not acfg.fake_quant_only:
        # grouped ragged fused LUT-GEMM: one kernel per projection over all
        # nb*E capacity buffers, ragged-skipping past each live count
        lead = xe.shape[:-3]
        e_dim, cap, d = xe.shape[-3:]
        xg = xe.reshape(-1, cap, d)                      # (G, C, D)
        g = xg.shape[0]
        if counts is None:
            cnt = jnp.full((g,), cap, jnp.int32)
        else:
            cnt = jnp.asarray(counts, jnp.int32).reshape(g)
        gate = approx_grouped_dense(xg, p["w_gate"], acfg, cnt)
        up = approx_grouped_dense(xg, p["w_up"], acfg, cnt)
        h = jax.nn.silu(gate) * up
        y = approx_grouped_dense(h, p["w_down"], acfg, cnt)
        return y.reshape(*lead, e_dim, cap, d)

    def one(xe_e, wg, wu, wd):
        h = jax.nn.silu(approx_dense(xe_e, wg, None, acfg)) * \
            approx_dense(xe_e, wu, None, acfg)
        return approx_dense(h, wd, None, acfg)

    fn = jax.vmap(one, in_axes=(0, 0, 0, 0))
    if xe.ndim == 4:  # leading block dim
        fn = jax.vmap(fn, in_axes=(0, None, None, None))
    return fn(xe, p["w_gate"], p["w_up"], p["w_down"])


def _dispatch_blocks(cfg, t: int) -> int:
    """Number of data-aligned dispatch blocks (1 disables block-locality)."""
    if not cfg.moe_shard_dispatch:
        return 1
    ctx = current_mesh_context()
    nb = 1
    if ctx is not None:
        for a in ("pod", "data"):
            if a in ctx.mesh.axis_names:
                nb *= ctx.mesh.shape[a]
    else:
        nb = 16  # planner default when traced without a mesh (tests)
    while t % nb != 0 or nb > t:
        nb //= 2
    return max(nb, 1)


def dispatch_geometry(cfg, t: int) -> dict:
    """Static dispatch geometry for ``t`` tokens under the active mesh
    context: resolved block count (after the divisibility fallback), tokens
    per block, and the per-block capacity. Pure shape arithmetic — safe to
    call at trace/lowering time (the dry-run surfaces it per MoE cell)."""
    e, k = cfg.n_experts, cfg.moe_top_k
    nb = _dispatch_blocks(cfg, t)
    tb = t // nb
    cap = int(max(1, round(tb * k / e * cfg.moe_capacity)))
    return {"n_blocks": nb, "tokens_per_block": tb, "capacity": cap,
            "n_experts": e, "top_k": k,
            "capacity_factor": cfg.moe_capacity}


def moe_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig],
              *, return_stats: bool = False):
    """x: (B, S, D) -> (B, S, D), or ``(out, stats)`` with
    ``return_stats=True``.

    p: router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D).

    stats (all computed from products the block already has in hand):
      ``aux_loss``      Switch-style load-balancing loss (reuses the routing
                        softmax — bitwise-identical to ``router_aux_loss``).
      ``dropped_frac``  fraction of the T*k routed assignments dropped by
                        the capacity limit (f32 scalar).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * s
    xf = x.reshape(t, d)
    probs, top_p, top_e = _route(xf, p["router"], k)

    nb = _dispatch_blocks(cfg, t)
    tb = t // nb                 # tokens per block
    cap = int(max(1, round(tb * k / e * cfg.moe_capacity)))

    # ---- block-local slot assignment -----------------------------------
    flat_e = top_e.reshape(nb, tb * k)                         # (nb, TBk)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (nb, TBk, E)
    onehot = shard(onehot, "expert_blocks", None, None)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                  # within block
    slot = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = slot < cap                                          # (nb, TBk)
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)       # (nb, TBk)

    # live rows per capacity buffer: slots 0..count-1 are occupied (cumsum
    # order packs kept tokens densely) — the grouped GEMM's groupinfo
    counts = jnp.minimum(onehot.sum(axis=1), cap)              # (nb, E)

    # scatter token indices into per-block buffers (trash slot at the end)
    tok_in_block = jnp.arange(tb * k, dtype=jnp.int32) // k    # (TBk,)
    idx_buf = jnp.zeros((nb, e * cap + 1), jnp.int32)
    idx_buf = idx_buf.at[jnp.arange(nb)[:, None], dest].set(tok_in_block[None] + 1)
    idx_buf = idx_buf[:, :-1]                                  # (nb, E*cap)

    # gather rows (block-local): xfb (nb, TB, D) -> xe (nb, E, cap, D)
    xfb = xf.reshape(nb, tb, d)
    xfb = shard(xfb, "expert_blocks", None, None)
    xe = jnp.take_along_axis(
        xfb, jnp.maximum(idx_buf - 1, 0)[..., None], axis=1)
    xe = xe * (idx_buf > 0)[..., None].astype(x.dtype)
    xe = xe.reshape(nb, e, cap, d)
    xe = shard(xe, "expert_blocks", "experts", None, None)

    ye = _expert_ffn(xe, p, cfg, acfg, ("expert_blocks",), counts=counts)
    ye = shard(ye, "expert_blocks", "experts", None, None)

    # combine (block-local gather + routed weights)
    yeb = ye.reshape(nb, e * cap, d)
    src = jnp.where(keep, flat_e * cap + slot, 0)              # (nb, TBk)
    yk = jnp.take_along_axis(yeb, src[..., None], axis=1)      # (nb, TBk, D)
    yk = jnp.where(keep[..., None], yk, 0.0).reshape(t, k, d)
    out = (yk * top_p[:, :, None].astype(yk.dtype)).sum(axis=1)
    out = out.reshape(b, s, d)
    if not return_stats:
        return out
    stats = {
        "aux_loss": _aux_loss(probs, top_e, e),
        "dropped_frac": 1.0 - keep.mean(dtype=jnp.float32),
    }
    return out, stats


def router_aux_loss(x: Array, router: Array, n_experts: int, top_k: int) -> Array:
    """Switch-style load-balancing auxiliary loss (standalone API — shares
    ``_route``/``_aux_loss`` with ``moe_block``'s stats)."""
    xf = x.reshape(x.shape[0] * x.shape[1], -1)
    probs, _, top_e = _route(xf, router, top_k)
    return _aux_loss(probs, top_e, n_experts)
