"""Mamba (S6 selective state space) block — the Jamba hybrid's SSM layer.

Projections (in/out/x-proj/dt-proj) are GEMMs and therefore go through the
paper's ACU emulation when enabled; the selective-scan recurrence itself is
elementwise/add-dominated (no multiplier array in the accelerator sense) and
stays exact — recorded in DESIGN.md §6.

Train: associative scan over time (parallel, O(log S) depth).
Decode: O(1) recurrent state update per token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense
from repro.parallel.sharding import shard

Array = jnp.ndarray


class MambaState(NamedTuple):
    conv: Array   # (B, d_conv - 1, d_inner) — causal conv tail
    ssm: Array    # (B, d_inner, d_state)


def _ssm_scan(dA: Array, dBx: Array, h0: Optional[Array] = None):
    """h_t = dA_t * h_{t-1} + dBx_t along axis 1 (time).

    dA, dBx: (B, S, d_inner, d_state). Associative scan over composed affine
    maps (a, b): (a2*a1, a2*b1 + b2).
    """
    if h0 is not None:
        # fold initial state into the first step
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return h  # (B, S, d_inner, d_state)


def mamba_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig], *,
                state: Optional[MambaState] = None, decode: bool = False):
    """x: (B, S, D). Returns (y, new_state).

    p: in_proj (D, 2*d_inner), conv_w (d_conv, d_inner), conv_b (d_inner,),
       x_proj (d_inner, dt_rank + 2*d_state), dt_proj (dt_rank, d_inner),
       dt_bias (d_inner,), A_log (d_inner, d_state), Dskip (d_inner,),
       out_proj (d_inner, D).
    """
    b, s, _ = x.shape
    d_inner = cfg.mamba_d_inner
    d_state = cfg.mamba_d_state
    d_conv = cfg.mamba_d_conv

    xz = approx_dense(x, p["in_proj"], None, acfg)
    xs, z = jnp.split(xz, 2, axis=-1)              # (B, S, d_inner) each
    xs = shard(xs, "batch", None, "mlp")

    # causal depthwise conv over time
    if decode:
        conv_in = jnp.concatenate([state.conv, xs], axis=1)     # (B, d_conv-1+S, di)
        new_conv = conv_in[:, -(d_conv - 1):]
    else:
        pad = jnp.zeros((b, d_conv - 1, d_inner), xs.dtype) if state is None \
            else state.conv
        conv_in = jnp.concatenate([pad, xs], axis=1)
        new_conv = conv_in[:, -(d_conv - 1):]
    # (B, S, di): sum_w conv_in[:, t + w] * conv_w[w]
    xc = sum(conv_in[:, w:w + s] * p["conv_w"][w][None, None, :]
             for w in range(d_conv))
    xc = jax.nn.silu(xc + p["conv_b"][None, None, :])

    # input-dependent SSM parameters
    xdbc = approx_dense(xc, p["x_proj"], None, acfg)
    dt_r, bmat, cmat = jnp.split(
        xdbc, [cfg.mamba_dt_rank, cfg.mamba_dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(approx_dense(dt_r, p["dt_proj"], p["dt_bias"], acfg))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (di, ds)

    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A[None, None])   # (B,S,di,ds)
    dBx = (dt * xc)[..., None].astype(jnp.float32) * \
        bmat[:, :, None, :].astype(jnp.float32)                       # (B,S,di,ds)

    h0 = state.ssm if state is not None else None
    if decode and s == 1:
        h_prev = h0 if h0 is not None else jnp.zeros((b, d_inner, d_state), jnp.float32)
        h_last = dA[:, 0] * h_prev + dBx[:, 0]
        h = h_last[:, None]
    else:
        h = _ssm_scan(dA, dBx, h0)
        h_last = h[:, -1]

    y = jnp.einsum("btdn,btn->btd", h, cmat.astype(jnp.float32))
    y = y.astype(x.dtype) + xc * p["Dskip"][None, None, :]
    y = y * jax.nn.silu(z)
    out = approx_dense(y, p["out_proj"], None, acfg)
    return out, MambaState(conv=new_conv, ssm=h_last)
