"""Whisper-style encoder-decoder backbone (audio frontend stubbed per spec:
``input_specs()`` provides precomputed post-conv frame embeddings).

Encoder: non-causal self-attention stack over (B, enc_ctx, D) frames with
sinusoidal positions. Decoder: causal self-attention + cross-attention to the
encoder output, learned positions. Both stacks scan over layer groups like
the decoder-only engine.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.approx_ops import ApproxConfig
from repro.models import layers as L
from repro.models.transformer import _init_attn, _init_mlp, _norm_params
from repro.parallel.sharding import shard

Array = jnp.ndarray


def _sinusoid(n: int, d: int) -> Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    g_enc, g_dec = cfg.n_enc_layers, cfg.n_layers
    d, v = cfg.d_model, cfg.vocab_padded
    enc = {
        "attn": _init_attn(keys[0], cfg, g_enc),
        "mlp": _init_mlp(keys[1], cfg, g_enc),
        "norm1": _norm_params(cfg, d, g_enc),
        "norm2": _norm_params(cfg, d, g_enc),
    }
    dec = {
        "self_attn": _init_attn(keys[2], cfg, g_dec),
        "cross_attn": _init_attn(keys[3], cfg, g_dec, cross=True),
        "mlp": _init_mlp(keys[4], cfg, g_dec),
        "norm1": _norm_params(cfg, d, g_dec),
        "norm_x": _norm_params(cfg, d, g_dec),
        "norm2": _norm_params(cfg, d, g_dec),
    }
    return {
        "embed": (jax.random.normal(keys[5], (v, d), jnp.float32) * d ** -0.5
                  ).astype(cfg.param_dtype),
        "dec_pos": (jax.random.normal(keys[6], (cfg.max_dec_pos, d), jnp.float32)
                    * 0.01).astype(cfg.param_dtype),
        "enc": enc,
        "dec": dec,
        "enc_norm": _norm_params(cfg, d, 1),
        "final_norm": _norm_params(cfg, d, 1),
        "lm_head": (jax.random.normal(keys[7], (d, v), jnp.float32) * d ** -0.5
                    ).astype(cfg.param_dtype),
    }


def _norm(x, p, cfg):
    if cfg.norm == "ln":
        return L.layer_norm(x, p["w"], p["b"])
    return L.rms_norm(x, p["w"])


def encode(params: dict, frames: Array, cfg: ModelConfig,
           acfg: Optional[ApproxConfig] = None) -> Array:
    """frames: (B, enc_ctx, D) stub embeddings -> (B, enc_ctx, D)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)[None]
    x = shard(x, "batch", None, None)
    dummy_pos = jnp.zeros(frames.shape[:2], jnp.int32)

    def body(x, gp):
        h = _norm(x, gp["norm1"], cfg)
        a, _ = L.attention_block(h, gp["attn"], cfg, acfg, dummy_pos,
                                 causal=False)
        x = x + a
        x = x + L.mlp_block(_norm(x, gp["norm2"], cfg), gp["mlp"], cfg, acfg)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return _norm(x, jax.tree.map(lambda a: a[0], params["enc_norm"]), cfg)


def decode(params: dict, tokens: Array, enc_out: Array, cfg: ModelConfig, *,
           acfg: Optional[ApproxConfig] = None, cache: Optional[dict] = None,
           cache_pos: int | Array = 0, last_only: bool = False):
    """tokens: (B, S) -> logits; cross-attends to enc_out (B, T, D)."""
    b, s = tokens.shape
    x = L.embed(tokens, params["embed"])
    pos_emb = jax.lax.dynamic_slice_in_dim(params["dec_pos"],
                                           jnp.asarray(cache_pos), s, axis=0)
    x = x + pos_emb[None]
    x = shard(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s)[None] + cache_pos, (b, s))
    group_cache = cache["groups"] if cache is not None else None

    def body(x, scanned):
        gp, gc = scanned
        h = _norm(x, gp["norm1"], cfg)
        sc = None if gc is None else gc["self"]
        a, sc = L.attention_block(h, gp["self_attn"], cfg, acfg, positions,
                                  cache=sc, cache_pos=cache_pos)
        x = x + a
        hx = _norm(x, gp["norm_x"], cfg)
        cx, _ = L.attention_block(hx, gp["cross_attn"], cfg, acfg, positions,
                                  kv=enc_out, causal=False)
        x = x + cx
        x = x + L.mlp_block(_norm(x, gp["norm2"], cfg), gp["mlp"], cfg, acfg)
        return x, (None if gc is None else {"self": sc})

    bodyfn = body
    if cfg.remat and cache is None:
        bodyfn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if group_cache is None:
        x, _ = jax.lax.scan(lambda c, gp: bodyfn(c, (gp, None)), x,
                            params["dec"], unroll=cfg.scan_unroll)
        new_cache = None
    else:
        x, new_groups = jax.lax.scan(bodyfn, x, (params["dec"], group_cache),
                                     unroll=cfg.scan_unroll)
        new_cache = {"groups": new_groups}

    if last_only:
        x = x[:, -1:]
    x = _norm(x, jax.tree.map(lambda a: a[0], params["final_norm"]), cfg)
    logits = L.lm_head(x, params["lm_head"], acfg)
    return logits, new_cache


def loss_fn(params, frames, tokens, labels, cfg, acfg=None):
    enc_out = encode(params, frames, cfg, acfg)
    logits, _ = decode(params, tokens, enc_out, cfg, acfg=acfg)
    return L.cross_entropy(logits, labels, cfg.vocab_size)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.param_dtype
    kv = jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype)
    return {"groups": {"self": (kv, kv)}}
