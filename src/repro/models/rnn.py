"""RNN / LSTM / GRU cells on the approximate Linear layer (paper §3.3.4).

"It also utilizes our custom Linear layer thus making it approximation
compatible as well" — every gate GEMM goes through approx_dense.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense

Array = jnp.ndarray


def init_lstm(key, d_in: int, d_hidden: int) -> dict:
    k1, k2 = jax.random.split(key)
    s = (d_in + d_hidden) ** -0.5
    return {
        "wx": jax.random.normal(k1, (d_in, 4 * d_hidden), jnp.float32) * s,
        "wh": jax.random.normal(k2, (d_hidden, 4 * d_hidden), jnp.float32) * s,
        "b": jnp.zeros((4 * d_hidden,), jnp.float32),
    }


def lstm_cell(x: Array, h: Array, c: Array, p: dict,
              acfg: Optional[ApproxConfig]) -> tuple[Array, Array]:
    gates = approx_dense(x, p["wx"], None, acfg) + \
        approx_dense(h, p["wh"], p["b"], acfg)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm(xs: Array, p: dict, acfg: Optional[ApproxConfig] = None) -> Array:
    """xs: (B, S, D) -> final hidden state (B, H)."""
    b = xs.shape[0]
    dh = p["wh"].shape[0]
    h0 = jnp.zeros((b, dh), xs.dtype)
    c0 = jnp.zeros((b, dh), xs.dtype)

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell(x, h, c, p, acfg)
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h0, c0), xs.transpose(1, 0, 2))
    return h


def init_gru(key, d_in: int, d_hidden: int) -> dict:
    k1, k2 = jax.random.split(key)
    s = (d_in + d_hidden) ** -0.5
    return {
        "wx": jax.random.normal(k1, (d_in, 3 * d_hidden), jnp.float32) * s,
        "wh": jax.random.normal(k2, (d_hidden, 3 * d_hidden), jnp.float32) * s,
        "b": jnp.zeros((3 * d_hidden,), jnp.float32),
    }


def gru_cell(x: Array, h: Array, p: dict, acfg: Optional[ApproxConfig]) -> Array:
    gx = approx_dense(x, p["wx"], p["b"], acfg)
    gh = approx_dense(h, p["wh"], None, acfg)
    xr, xz, xn = jnp.split(gx, 3, axis=-1)
    hr, hz, hn = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1 - z) * n + z * h


def rnn_cell(x: Array, h: Array, p: dict, acfg: Optional[ApproxConfig]) -> Array:
    return jnp.tanh(approx_dense(x, p["wx"], p["b"], acfg) +
                    approx_dense(h, p["wh"], None, acfg))


def init_rnn(key, d_in: int, d_hidden: int) -> dict:
    k1, k2 = jax.random.split(key)
    s = (d_in + d_hidden) ** -0.5
    return {
        "wx": jax.random.normal(k1, (d_in, d_hidden), jnp.float32) * s,
        "wh": jax.random.normal(k2, (d_hidden, d_hidden), jnp.float32) * s,
        "b": jnp.zeros((d_hidden,), jnp.float32),
    }
