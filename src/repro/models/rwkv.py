"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent decay + channel mix.

All R/K/V/G/W/O projections and the channel-mix GEMMs route through the ACU
when approximation is enabled; the WKV recurrence (decay-accumulate) has no
multiplier-array analogue and stays exact (DESIGN.md §6).

State per layer: time-mix shift (B, 1, D), wkv state (B, H, hd, hd),
channel-mix shift (B, 1, D).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense
from repro.parallel.sharding import shard

Array = jnp.ndarray


class RwkvState(NamedTuple):
    tm_shift: Array   # (B, 1, D)
    wkv: Array        # (B, H, hd, hd) float32
    cm_shift: Array   # (B, 1, D)


def _shift(x: Array, prev: Optional[Array]) -> Array:
    """x_{t-1} stream: shift right by one along time, seeded by state."""
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev.astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _lora_mix(x: Array, xs: Array, mu: Array, A: Array, B: Array) -> Array:
    """Finch data-dependent token-shift: lerp(x, x_prev, mu + lora(x_mix))."""
    mu = mu.astype(x.dtype)[None, None, :]
    xmix = x + (xs - x) * mu
    lora = jnp.tanh(xmix @ A) @ B
    m = mu + lora.astype(x.dtype)
    return x + (xs - x) * m


def time_mix(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig], *,
             state: Optional[RwkvState], decode: bool = False):
    b, s, d = x.shape
    h = cfg.rwkv_n_heads
    hd = d // h
    prev = state.tm_shift if state is not None else None
    xs = _shift(x, prev)
    new_shift = x[:, -1:]

    r_in = _lora_mix(x, xs, p["mu_r"], p["lora_A"], p["lora_B_r"])
    k_in = _lora_mix(x, xs, p["mu_k"], p["lora_A"], p["lora_B_k"])
    v_in = _lora_mix(x, xs, p["mu_v"], p["lora_A"], p["lora_B_v"])
    g_in = _lora_mix(x, xs, p["mu_g"], p["lora_A"], p["lora_B_g"])
    w_in = _lora_mix(x, xs, p["mu_w"], p["lora_A"], p["lora_B_w"])

    r = approx_dense(r_in, p["Wr"], None, acfg).reshape(b, s, h, hd)
    k = approx_dense(k_in, p["Wk"], None, acfg).reshape(b, s, h, hd)
    v = approx_dense(v_in, p["Wv"], None, acfg).reshape(b, s, h, hd)
    g = jax.nn.silu(approx_dense(g_in, p["Wg"], None, acfg))
    # data-dependent per-channel decay in (0, 1)
    dw = (w_in @ p["Wdecay_A"]) @ p["Wdecay_B"]
    w = jnp.exp(-jnp.exp((p["decay_base"][None, None] + dw)
                         .astype(jnp.float32))).reshape(b, s, h, hd)
    u = p["bonus"].reshape(h, hd)

    r = shard(r, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    rf = r.astype(jnp.float32)

    s0 = state.wkv if state is not None else jnp.zeros((b, h, hd, hd), jnp.float32)

    if decode and s == 1:
        kv = kf[:, 0, :, :, None] * vf[:, 0, :, None, :]        # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rf[:, 0],
                         s0 + u[None, :, :, None] * kv)
        s_new = w[:, 0, :, :, None] * s0 + kv
        y = out[:, None]                                        # (B,1,H,hd)
    else:
        def step(carry, t_in):
            st = carry
            kt, vt, rt, wt = t_in                               # (B,H,hd) each
            kv = kt[:, :, :, None] * vt[:, :, None, :]
            out = jnp.einsum("bhk,bhkv->bhv", rt,
                             st + u[None, :, :, None] * kv)
            st = wt[:, :, :, None] * st + kv
            return st, out

        # time-chunked nested scan: the inner chunk is rematerialized on the
        # backward pass, so only chunk-boundary wkv states are saved
        # (O(S/chunk) instead of O(S) of the (B,H,hd,hd) state).
        chunk = min(getattr(cfg, "rwkv_chunk", 256), s)
        while s % chunk:            # fall back to a divisor of S (small seqs)
            chunk -= 1
        n_chunks = s // chunk

        def to_chunks(a):  # (B,S,H,hd) -> (n_chunks, chunk, B, H, hd)
            return a.transpose(1, 0, 2, 3).reshape(n_chunks, chunk, b, h, hd)

        t_in = tuple(map(to_chunks, (kf, vf, rf, w)))

        @jax.checkpoint
        def chunk_scan(st, tc):
            return jax.lax.scan(step, st, tc)

        s_new, ys = jax.lax.scan(chunk_scan, s0, t_in)
        y = ys.reshape(s, b, h, hd).transpose(1, 0, 2, 3)       # (B,S,H,hd)

    # per-head group norm then gate
    y = y.reshape(b, -1, h, hd)
    mu_ = y.mean(-1, keepdims=True)
    var = y.var(-1)[..., None]
    y = (y - mu_) * jax.lax.rsqrt(var + 1e-5)
    y = (y * p["ln_w"].reshape(h, hd)[None, None] +
         p["ln_b"].reshape(h, hd)[None, None])
    y = y.reshape(b, -1, d).astype(x.dtype) * g
    out = approx_dense(y, p["Wo"], None, acfg)
    return out, new_shift, s_new


def channel_mix(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig], *,
                state: Optional[RwkvState]):
    prev = state.cm_shift if state is not None else None
    xs = _shift(x, prev)
    new_shift = x[:, -1:]
    xk = x + (xs - x) * p["cm_mu_k"].astype(x.dtype)[None, None, :]
    xr = x + (xs - x) * p["cm_mu_r"].astype(x.dtype)[None, None, :]
    k = jnp.square(jax.nn.relu(approx_dense(xk, p["Wk_cm"], None, acfg)))
    k = shard(k, "batch", None, "mlp")
    kv = approx_dense(k, p["Wv_cm"], None, acfg)
    return jax.nn.sigmoid(approx_dense(xr, p["Wr_cm"], None, acfg)) * kv, new_shift


def rwkv_block(x: Array, p: dict, cfg, acfg: Optional[ApproxConfig], *,
               state: Optional[RwkvState] = None, decode: bool = False):
    """Pre-norm time-mix + channel-mix; returns (y, new_state)."""
    from .layers import layer_norm
    h1 = layer_norm(x, p["ln1_w"], p["ln1_b"])
    att, tm_shift, wkv = time_mix(h1, p, cfg, acfg, state=state, decode=decode)
    x = x + att
    h2 = layer_norm(x, p["ln2_w"], p["ln2_b"])
    ffn, cm_shift = channel_mix(h2, p, cfg, acfg, state=state)
    x = x + ffn
    return x, RwkvState(tm_shift=tm_shift, wkv=wkv, cm_shift=cm_shift)
