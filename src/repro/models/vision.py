"""Paper-side evaluation models (Table 1/2/4 analogues): CNNs, VAE, GAN.

All conv/linear layers route through ``repro.core`` so any model can be run
exact, quantized, or through an approximate multiplier — the "multi-DNN
simulation" capability of Table 3.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.approx_ops import ApproxConfig, approx_dense, separable_conv2d
from repro.models.layers import conv2d_block

Array = jnp.ndarray


def _conv_init(key, cout, cin, kh, kw):
    s = (cin * kh * kw) ** -0.5
    return jax.random.normal(key, (cout, cin, kh, kw), jnp.float32) * s


def _lin_init(key, din, dout):
    return jax.random.normal(key, (din, dout), jnp.float32) * din ** -0.5


# ---------------------------------------------------------------------------
# Small VGG-style CNN (the CIFAR10 CNN rows)
# ---------------------------------------------------------------------------

def init_cnn(key, n_classes: int = 10, width: int = 32, in_ch: int = 3,
             img: int = 32) -> dict:
    ks = jax.random.split(key, 8)
    w = width
    flat = 4 * w * (img // 8) ** 2   # three 2x2 pools
    return {
        "c1": _conv_init(ks[0], w, in_ch, 3, 3), "b1": jnp.zeros((w,)),
        "c2": _conv_init(ks[1], 2 * w, w, 3, 3), "b2": jnp.zeros((2 * w,)),
        "c3": _conv_init(ks[2], 4 * w, 2 * w, 3, 3), "b3": jnp.zeros((4 * w,)),
        "f1": _lin_init(ks[3], flat, 8 * w), "fb1": jnp.zeros((8 * w,)),
        "f2": _lin_init(ks[4], 8 * w, n_classes), "fb2": jnp.zeros((n_classes,)),
    }


def cnn_forward(p: dict, x: Array, acfg: Optional[ApproxConfig] = None) -> Array:
    """x: (N, C, 32, 32) -> logits (N, n_classes)."""
    pool = lambda t: jax.lax.reduce_window(
        t, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = conv2d_block(x, p["c1"], p["b1"], acfg=acfg, activation=jax.nn.relu)
    x = pool(x)
    x = conv2d_block(x, p["c2"], p["b2"], acfg=acfg, activation=jax.nn.relu)
    x = pool(x)
    x = conv2d_block(x, p["c3"], p["b3"], acfg=acfg, activation=jax.nn.relu)
    x = pool(x)                                        # (N, 4w, 4, 4)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(approx_dense(x, p["f1"], p["fb1"], acfg))
    return approx_dense(x, p["f2"], p["fb2"], acfg)


# ---------------------------------------------------------------------------
# Mini ResNet (basic blocks, the ResNet50 row's structural stand-in)
# ---------------------------------------------------------------------------

def init_resnet(key, n_classes: int = 10, width: int = 16, n_blocks: int = 3) -> dict:
    ks = iter(jax.random.split(key, 4 + 4 * n_blocks * 3))
    p: dict = {"stem": _conv_init(next(ks), width, 3, 3, 3),
               "stem_b": jnp.zeros((width,))}
    w = width
    for stage in range(3):
        wo = width * (2 ** stage)
        for blk in range(n_blocks):
            pre = f"s{stage}b{blk}"
            stride = 2 if (blk == 0 and stage > 0) else 1
            cin = w if blk == 0 else wo
            p[f"{pre}_c1"] = _conv_init(next(ks), wo, cin, 3, 3)
            p[f"{pre}_c2"] = _conv_init(next(ks), wo, wo, 3, 3)
            if cin != wo or stride != 1:
                p[f"{pre}_sc"] = _conv_init(next(ks), wo, cin, 1, 1)
        w = wo
    p["head"] = _lin_init(next(ks), w, n_classes)
    p["head_b"] = jnp.zeros((n_classes,))
    return p


def resnet_forward(p: dict, x: Array, acfg: Optional[ApproxConfig] = None,
                   n_blocks: int = 3) -> Array:
    x = conv2d_block(x, p["stem"], p["stem_b"], acfg=acfg, activation=jax.nn.relu)
    for stage in range(3):
        for blk in range(n_blocks):
            pre = f"s{stage}b{blk}"
            stride = (2, 2) if (blk == 0 and stage > 0) else (1, 1)
            h = conv2d_block(x, p[f"{pre}_c1"], None, stride=stride,
                             acfg=acfg, activation=jax.nn.relu)
            h = conv2d_block(h, p[f"{pre}_c2"], None, acfg=acfg)
            sc = x if f"{pre}_sc" not in p else conv2d_block(
                x, p[f"{pre}_sc"], None, stride=stride, padding="VALID", acfg=acfg)
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(2, 3))
    return approx_dense(x, p["head"], p["head_b"], acfg)


# ---------------------------------------------------------------------------
# SqueezeNet-style (fire modules: squeeze 1x1 -> expand 1x1/3x3)
# ---------------------------------------------------------------------------

def init_squeezenet(key, n_classes: int = 10, width: int = 16) -> dict:
    ks = iter(jax.random.split(key, 16))
    p = {"stem": _conv_init(next(ks), 2 * width, 3, 3, 3),
         "stem_b": jnp.zeros((2 * width,))}
    c = 2 * width
    for i in range(3):
        sq, ex = width * (i + 1), 2 * width * (i + 1)
        p[f"f{i}_s"] = _conv_init(next(ks), sq, c, 1, 1)
        p[f"f{i}_e1"] = _conv_init(next(ks), ex, sq, 1, 1)
        p[f"f{i}_e3"] = _conv_init(next(ks), ex, sq, 3, 3)
        c = 2 * ex
    p["head"] = _lin_init(next(ks), c, n_classes)
    p["head_b"] = jnp.zeros((n_classes,))
    return p


def squeezenet_forward(p: dict, x: Array, acfg: Optional[ApproxConfig] = None) -> Array:
    pool = lambda t: jax.lax.reduce_window(
        t, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
    x = pool(conv2d_block(x, p["stem"], p["stem_b"], acfg=acfg,
                          activation=jax.nn.relu))
    for i in range(3):
        s = conv2d_block(x, p[f"f{i}_s"], None, padding="VALID", acfg=acfg,
                         activation=jax.nn.relu)
        e1 = conv2d_block(s, p[f"f{i}_e1"], None, padding="VALID", acfg=acfg,
                          activation=jax.nn.relu)
        e3 = conv2d_block(s, p[f"f{i}_e3"], None, acfg=acfg,
                          activation=jax.nn.relu)
        x = jnp.concatenate([e1, e3], axis=1)
        if i < 2:
            x = pool(x)
    x = x.mean(axis=(2, 3))
    return approx_dense(x, p["head"], p["head_b"], acfg)


# ---------------------------------------------------------------------------
# VAE (MNIST-style 28x28) and GAN (Fashion-MNIST-style) — MLP variants
# ---------------------------------------------------------------------------

def init_vae(key, d_in: int = 784, d_h: int = 256, d_z: int = 32) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "enc1": _lin_init(ks[0], d_in, d_h), "enc1_b": jnp.zeros((d_h,)),
        "mu": _lin_init(ks[1], d_h, d_z), "mu_b": jnp.zeros((d_z,)),
        "logvar": _lin_init(ks[2], d_h, d_z), "logvar_b": jnp.zeros((d_z,)),
        "dec1": _lin_init(ks[3], d_z, d_h), "dec1_b": jnp.zeros((d_h,)),
        "dec2": _lin_init(ks[4], d_h, d_in), "dec2_b": jnp.zeros((d_in,)),
    }


def vae_forward(p: dict, x: Array, key, acfg: Optional[ApproxConfig] = None):
    h = jax.nn.relu(approx_dense(x, p["enc1"], p["enc1_b"], acfg))
    mu = approx_dense(h, p["mu"], p["mu_b"], acfg)
    logvar = approx_dense(h, p["logvar"], p["logvar_b"], acfg)
    eps = jax.random.normal(key, mu.shape)
    z = mu + jnp.exp(0.5 * logvar) * eps
    h = jax.nn.relu(approx_dense(z, p["dec1"], p["dec1_b"], acfg))
    recon = jax.nn.sigmoid(approx_dense(h, p["dec2"], p["dec2_b"], acfg))
    return recon, mu, logvar


def vae_loss(p: dict, x: Array, key, acfg=None) -> Array:
    recon, mu, logvar = vae_forward(p, x, key, acfg)
    bce = -(x * jnp.log(recon + 1e-7) +
            (1 - x) * jnp.log(1 - recon + 1e-7)).sum(-1).mean()
    kl = -0.5 * (1 + logvar - mu ** 2 - jnp.exp(logvar)).sum(-1).mean()
    return bce + kl


def init_gan(key, d_z: int = 64, d_h: int = 256, d_out: int = 784) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "g1": _lin_init(ks[0], d_z, d_h), "g1_b": jnp.zeros((d_h,)),
        "g2": _lin_init(ks[1], d_h, d_out), "g2_b": jnp.zeros((d_out,)),
        "d1": _lin_init(ks[2], d_out, d_h), "d1_b": jnp.zeros((d_h,)),
        "d2": _lin_init(ks[3], d_h, 1), "d2_b": jnp.zeros((1,)),
    }


def gan_generator(p: dict, z: Array, acfg: Optional[ApproxConfig] = None) -> Array:
    h = jax.nn.relu(approx_dense(z, p["g1"], p["g1_b"], acfg))
    return jax.nn.sigmoid(approx_dense(h, p["g2"], p["g2_b"], acfg))


def gan_discriminator(p: dict, x: Array, acfg: Optional[ApproxConfig] = None) -> Array:
    h = jax.nn.leaky_relu(approx_dense(x, p["d1"], p["d1_b"], acfg), 0.2)
    return approx_dense(h, p["d2"], p["d2_b"], acfg)
