"""Pure-jnp oracle for the fused quantize->LUT-GEMM->dequant pipeline.

Mirrors the unfused reference path operation for operation (same quantizer
expression, same int32 accumulate, same single combined-scale dequant
``acc * (xs * ws)``) so the Pallas kernel can be checked for bit-exactness
against it.
"""
from __future__ import annotations

import jax.numpy as jnp


def fused_lut_dense_ref(x: jnp.ndarray, wq: jnp.ndarray,
                        lut_flat: jnp.ndarray, offset: int, n_codes: int,
                        x_scale, x_zp, w_scale, *, bits: int = 8) -> jnp.ndarray:
    """out = xs * ws[n] * sum_k LUT[q(x[m,k]) - xz + off, wq[k,n] + off].

    O(MKN) memory — test oracle only.
    """
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    xs = jnp.asarray(x_scale, jnp.float32)
    xz = jnp.asarray(x_zp, jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / xs + xz), lo, hi
                 ).astype(jnp.int32)
    a = q - xz.astype(jnp.int32) + offset
    w = wq.astype(jnp.int32) + offset
    idx = a[:, :, None] * n_codes + w[None, :, :]
    acc = jnp.take(lut_flat, idx.reshape(-1)).reshape(idx.shape).sum(axis=1)
    ws = jnp.asarray(w_scale, jnp.float32).reshape(1, -1)
    return acc.astype(jnp.float32) * (xs * ws)


def fused_lut_bwd_ref(a: jnp.ndarray, b: jnp.ndarray, lut_flat: jnp.ndarray,
                      offset: int, n_codes: int, a_scale, b_scale, *,
                      bits: int = 8) -> jnp.ndarray:
    """Backward flavor: both operands quantized per-tensor symmetric
    (zero-point 0), then the same LUT gather, int32 sum, and single
    combined-scale dequant. O(MKN) memory — test oracle only."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    sa = jnp.asarray(a_scale, jnp.float32)
    sb = jnp.asarray(b_scale, jnp.float32)
    qa = jnp.clip(jnp.round(a.astype(jnp.float32) / sa), lo, hi
                  ).astype(jnp.int32) + offset
    qb = jnp.clip(jnp.round(b.astype(jnp.float32) / sb), lo, hi
                  ).astype(jnp.int32) + offset
    idx = qa[:, :, None] * n_codes + qb[None, :, :]
    acc = jnp.take(lut_flat, idx.reshape(-1)).reshape(idx.shape).sum(axis=1)
    return acc.astype(jnp.float32) * (sa * sb)
