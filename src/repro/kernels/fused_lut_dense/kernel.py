"""Pallas TPU kernel: fused quantize -> LUT-gather GEMM -> affine dequant.

One ``pallas_call`` for the whole approximate dense forward:

``out[m, n] = xs * ws[n] * sum_k LUT[q(x[m, k]) - xz + off, wq[k, n] + off]``

with ``q(x) = clip(round(x / xs + xz), lo, hi)`` — the per-tile activation
quantizer. Compared to the unfused pipeline (``kernels/quantize`` ->
``kernels/lut_matmul`` -> jnp dequant) this removes two HBM round-trips per
layer: the (M, K) int32 activation-code tensor and the (M, N) int32
accumulator never leave VMEM. The weight side stays pre-quantized (codes are
produced once per layer, not once per tile), matching the paper's "LUTs are
populated once" regime.

Structure mirrors ``lut_matmul``: the (2^b, 2^b) product table is pinned in
VMEM for the whole grid; each (bm, bk) x (bk, bn) tile quantizes its
activation block on the VPU, performs vectorized gathers in ``inner``-row
sub-slices, and accumulates int32 into a persistent VMEM scratch tile. The
final K step applies the affine dequant (per-tensor activation scale x
per-channel weight scale row) and writes the float32 output tile — the only
HBM store.

K-padding correction happens *in integer space* (``k_pad * LUT[off, off]``
subtracted from the accumulator before dequant) so padded shapes stay
bit-exact vs the unpadded oracle — a float-space correction after dequant
would not round-trip exactly.

VMEM @ defaults (bm=bk=bn=128, 8-bit, inner=32): LUT 256 KiB + gather working
set 128*32*128*4 B = 2 MiB + acc tile 64 KiB — comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, lut_ref, xs_ref, xz_ref, ws_ref, o_ref, acc_ref, *,
            offset: int, n_codes: int, lo: int, hi: int, inner: int,
            k_pad: int, emit_acc: bool):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = xs_ref[0]                                 # per-tensor activation scale
    xz = xz_ref[0]                                 # activation zero-point (code)
    x = x_ref[...].astype(jnp.float32)             # (bm, bk)
    q = jnp.clip(jnp.round(x / xs + xz), lo, hi).astype(jnp.int32)
    a = q - xz.astype(jnp.int32) + offset          # shifted code, index space
    w = w_ref[...].astype(jnp.int32) + offset      # (bk, bn)
    lut = lut_ref[...]                             # (n_codes * n_codes,)
    bm, bk = a.shape
    bn = w.shape[1]

    def body(i, acc):
        a_sl = jax.lax.dynamic_slice(a, (0, i * inner), (bm, inner))
        w_sl = jax.lax.dynamic_slice(w, (i * inner, 0), (inner, bn))
        idx = a_sl[:, :, None] * n_codes + w_sl[None, :, :]   # (bm, inner, bn)
        prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                         indices_are_sorted=False).reshape(bm, inner, bn)
        return acc + prods.sum(axis=1)

    acc_ref[...] += jax.lax.fori_loop(0, bk // inner, body,
                                      jnp.zeros((bm, bn), jnp.int32))

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _dequant():
        acc = acc_ref[...]
        if k_pad:  # padded k entries each contributed LUT[off, off] = M[0, 0]
            acc = acc - k_pad * lut[offset * n_codes + offset]
        if emit_acc:
            # mesh contraction sharding: partial int32 accumulators leave the
            # kernel, psum across K shards, dequant once after the collective
            o_ref[...] = acc
        else:
            # one combined-scale multiply: a * xs * ws chains get reassociated
            # by the XLA simplifier under shard_map, breaking bit-exactness
            o_ref[...] = acc.astype(jnp.float32) * (xs * ws_ref[...])


def _bwd_kernel(a_ref, b_ref, lut_ref, as_ref, bs_ref, o_ref, acc_ref, *,
                offset: int, n_codes: int, lo: int, hi: int, inner: int,
                k_pad: int, emit_acc: bool):
    """Backward flavor: BOTH operands arrive as float residuals and are
    quantized in-kernel with per-tensor *symmetric* scales (zero-point 0 —
    gradients are zero-centred, and a zp-free quantizer keeps the combined
    dequant a single scale multiply). Everything downstream is the forward
    kernel verbatim: shifted-code LUT gathers, int32 accumulate, integer-space
    K-pad correction, one combined-scale dequant."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sa = as_ref[0]                                 # per-tensor symmetric scales
    sb = bs_ref[0]
    af = a_ref[...].astype(jnp.float32)            # (bm, bk)
    bf = b_ref[...].astype(jnp.float32)            # (bk, bn)
    a = jnp.clip(jnp.round(af / sa), lo, hi).astype(jnp.int32) + offset
    b = jnp.clip(jnp.round(bf / sb), lo, hi).astype(jnp.int32) + offset
    lut = lut_ref[...]                             # (n_codes * n_codes,)
    bm, bk = a.shape
    bn = b.shape[1]

    def body(i, acc):
        a_sl = jax.lax.dynamic_slice(a, (0, i * inner), (bm, inner))
        b_sl = jax.lax.dynamic_slice(b, (i * inner, 0), (inner, bn))
        idx = a_sl[:, :, None] * n_codes + b_sl[None, :, :]   # (bm, inner, bn)
        prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                         indices_are_sorted=False).reshape(bm, inner, bn)
        return acc + prods.sum(axis=1)

    acc_ref[...] += jax.lax.fori_loop(0, bk // inner, body,
                                      jnp.zeros((bm, bn), jnp.int32))

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _dequant():
        acc = acc_ref[...]
        if k_pad:  # zero pads quantize to code 0 -> LUT[off, off] = M[0, 0]
            acc = acc - k_pad * lut[offset * n_codes + offset]
        if emit_acc:
            o_ref[...] = acc
        else:
            o_ref[...] = acc.astype(jnp.float32) * (sa * sb)


@functools.partial(jax.jit, static_argnames=("offset", "n_codes", "lo", "hi",
                                             "k_pad", "bm", "bk", "bn",
                                             "inner", "interpret", "emit_acc"))
def fused_lut_bwd_kernel(a: jnp.ndarray, b: jnp.ndarray,
                         lut_flat: jnp.ndarray, a_scale: jnp.ndarray,
                         b_scale: jnp.ndarray, *, offset: int, n_codes: int,
                         lo: int, hi: int, k_pad: int = 0, bm: int = 128,
                         bk: int = 128, bn: int = 128, inner: int = 32,
                         interpret: bool | None = None,
                         emit_acc: bool = False) -> jnp.ndarray:
    """a: (M, K) float; b: (K, N) float; both quantized in-kernel with the
    per-tensor symmetric scales ``a_scale``/``b_scale`` (shape-(1,) f32).
    Returns (M, N) float32 — or the raw int32 accumulator with
    ``emit_acc=True`` (the sharded contraction route psums those partials
    and dequantizes once after the collective)."""
    M, K = a.shape
    _, N = b.shape
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    inner = min(inner, bk)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % inner == 0, (
        f"shape {(M, K, N)} not divisible by tile {(bm, bk, bn)}/{inner}")
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, offset=offset, n_codes=n_codes, lo=lo,
                          hi=hi, inner=inner, k_pad=k_pad, emit_acc=emit_acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_codes * n_codes,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N),
                                       jnp.int32 if emit_acc else jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(a, b, lut_flat, a_scale, b_scale)


@functools.partial(jax.jit, static_argnames=("offset", "n_codes", "lo", "hi",
                                             "k_pad", "bm", "bk", "bn",
                                             "inner", "interpret", "emit_acc"))
def fused_lut_dense_kernel(x: jnp.ndarray, wq: jnp.ndarray,
                           lut_flat: jnp.ndarray, x_scale: jnp.ndarray,
                           x_zp: jnp.ndarray, w_scale_row: jnp.ndarray, *,
                           offset: int, n_codes: int, lo: int, hi: int,
                           k_pad: int = 0, bm: int = 128, bk: int = 128,
                           bn: int = 128, inner: int = 32,
                           interpret: bool | None = None,
                           emit_acc: bool = False) -> jnp.ndarray:
    """x: (M, K) float; wq: (K, N) shifted int weight codes;
    lut_flat: (n_codes**2,) int32; x_scale/x_zp: shape-(1,) f32;
    w_scale_row: (1, N) f32. Returns (M, N) float32 — or the raw (M, N)
    int32 accumulator with ``emit_acc=True`` (sharded contraction: the
    caller psums partials across K shards and dequantizes after)."""
    M, K = x.shape
    _, N = wq.shape
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    inner = min(inner, bk)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % inner == 0, (
        f"shape {(M, K, N)} not divisible by tile {(bm, bk, bn)}/{inner}")
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, offset=offset, n_codes=n_codes, lo=lo,
                          hi=hi, inner=inner, k_pad=k_pad, emit_acc=emit_acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_codes * n_codes,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N),
                                       jnp.int32 if emit_acc else jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(x, wq, lut_flat, x_scale, x_zp, w_scale_row)
