"""jit'd public wrapper for the fused quantize->LUT-GEMM->dequant kernel.

Pads every dim to a tile multiple. Padding is exact end to end:

* activation k-pad uses 0.0, which the in-kernel quantizer maps to the
  zero-point and hence to shifted code 0 (``affine_qparams`` clips the
  zero-point into the code range, so ``clip(round(z), lo, hi) == z``);
* weight k-pad uses shifted code 0 directly;
* each padded k therefore contributes ``LUT[off, off] = M[0, 0]`` per output,
  which the kernel subtracts from the int32 accumulator *before* dequant
  (float-space correction would break bit-exactness vs the unpadded oracle).
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import fused_lut_bwd_kernel, fused_lut_dense_kernel


def fused_lut_dense(x: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray,
                    offset: int, x_scale, x_zp, w_scale, *, bits: int = 8,
                    bm: int = 128, bk: int = 256, bn: int = 128,
                    inner: int = 32, interpret: bool | None = None,
                    emit_acc: bool = False) -> jnp.ndarray:
    """Fused approximate dense forward.

    ``x``: (M, K) float activations; ``wq``: (K, N) shifted int weight codes
    (``code - zero_point``); ``lut`` may be (n_codes, n_codes) or flattened;
    ``x_scale``/``x_zp``: per-tensor activation qparams; ``w_scale``: scalar
    or (N,) per-output-channel weight scale; ``bits``: activation code width
    (clip range), which may be narrower than the ACU's operand width.
    Returns (M, N) float32, bit-exact vs quantize -> LUT GEMM -> dequant.

    ``emit_acc=True`` skips the in-kernel dequant and returns the raw (M, N)
    int32 accumulator (tile padding still corrected in integer space) — the
    mesh contraction-sharded route psums these partials across K shards and
    dequantizes once after the collective.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    M, K = x.shape
    _, N = wq.shape
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    xs = jnp.asarray(x_scale, jnp.float32).reshape(1)
    xz = jnp.asarray(x_zp, jnp.float32).reshape(1)
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32).reshape(1, -1),
                          (1, N))
    # M/N tiles cap at 128 so the padding granularity below always matches
    # the tile the kernel picks (K is the streamed dim and handled apart)
    bm, bn = min(bm, 128), min(bn, 128)
    pm = (-M) % min(bm, 128)
    pk = (-K) % 128
    pn = (-N) % min(bn, 128)
    if pm or pk or pn:
        x = jnp.pad(x, ((0, pm), (0, pk)))
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
        ws = jnp.pad(ws, ((0, 0), (0, pn)))
    # single K grid step when the whole row strip fits VMEM comfortably;
    # otherwise a k-tile that divides the (128-multiple) padded K
    kp = K + pk
    bk = kp if kp <= 512 else (bk if kp % bk == 0 else 128)
    out = fused_lut_dense_kernel(x, wq, lut_flat, xs, xz, ws,
                                 offset=offset, n_codes=n_codes, lo=lo, hi=hi,
                                 k_pad=pk, bm=bm, bk=bk, bn=bn, inner=inner,
                                 interpret=interpret, emit_acc=emit_acc)
    return out[:M, :N]


def fused_lut_bwd(a: jnp.ndarray, b: jnp.ndarray, lut: jnp.ndarray,
                  offset: int, a_scale, b_scale, *, bits: int = 8,
                  bm: int = 128, bk: int = 256, bn: int = 128,
                  inner: int = 32, interpret: bool | None = None,
                  emit_acc: bool = False) -> jnp.ndarray:
    """Fused approximate backward GEMM: quantize BOTH float operands
    in-kernel (per-tensor symmetric, zero-point 0), LUT-gather GEMM, int32
    accumulate, single combined-scale dequant ``acc * (sa * sb)``.

    ``a``: (M, K) float; ``b``: (K, N) float — the incoming gradient and the
    saved fake-quantized residual (in either operand order, depending on
    which grad GEMM this is). Zero padding quantizes to code 0 under a
    symmetric quantizer, so each padded k contributes ``LUT[off, off] =
    M[0, 0]`` — subtracted from the accumulator in integer space exactly like
    the forward. ``emit_acc=True`` returns the raw int32 accumulator for the
    mesh contraction-sharded route (psum, correct once, dequant after).
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    M, K = a.shape
    _, N = b.shape
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    sa = jnp.asarray(a_scale, jnp.float32).reshape(1)
    sb = jnp.asarray(b_scale, jnp.float32).reshape(1)
    bm, bn = min(bm, 128), min(bn, 128)
    pm = (-M) % min(bm, 128)
    pk = (-K) % 128
    pn = (-N) % min(bn, 128)
    if pm or pk or pn:
        a = jnp.pad(a, ((0, pm), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, pn)))
    kp = K + pk
    bk = kp if kp <= 512 else (bk if kp % bk == 0 else 128)
    out = fused_lut_bwd_kernel(a, b, lut_flat, sa, sb, offset=offset,
                               n_codes=n_codes, lo=lo, hi=hi, k_pad=pk,
                               bm=bm, bk=bk, bn=bn, inner=inner,
                               interpret=interpret, emit_acc=emit_acc)
    return out[:M, :N]
