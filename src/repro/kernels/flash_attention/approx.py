"""Approximate flash attention: LUT-gather GEMMs inside the online softmax.

Extends the fused quantize->LUT-GEMM->dequant scheme (kernels/fused_lut_dense)
into the streaming-softmax loop. Per (batch*head, q_block) grid step:

* Q is quantized in-kernel (per-tensor symmetric, shifted ACU codes) once;
* each KV block quantizes K/V in-kernel, computes QK^T as an int32 LUT-gather
  GEMM over the head dim (d-pad corrected with ``(dp - d) * LUT[off, off]``
  in integer space), dequantizes with ONE pre-pinned combined scale
  ``pin(pin(sq*sk) / sqrt(d))`` folded together with the 1/sqrt(d) softmax
  scale, then applies softcap/masking and the running (m, l, acc) rescale;
* the probabilities are quantized to static-scale codes ``round(p * hi)``
  (p is in [0, 1] post-softmax, so the scale needs no amax) and PV is a
  second int32 LUT-gather GEMM over the key block, Sk-pad corrected in int
  space, dequantized with the pre-pinned ``pin(sv / hi)`` scale into the
  float accumulator rescale.

Emulation semantics (what "approximate attention on the ACU" means here):

* *structural* padding this wrapper introduces (head-dim pad to the gather
  chunk, Sk pad to the key-block multiple) is corrected in integer space, so
  the result is independent of the tile geometry — exactly like the dense
  and conv kernels;
* *masked keys that exist in the input* (left-pad slots below ``kv_start``,
  cache positions at/above ``kv_len``, causally-future or out-of-window
  keys) get probability 0.0, which quantizes to code 0 — and the ACU still
  multiplies code 0 by the key's V codes, contributing ``LUT[0, v]`` per
  masked key. That is the faithful hardware emulation (a real ACU array
  multiplies everything in the tile); for every registered multiplier
  ``M[0, x] == 0`` so the contribution vanishes, and for biased synthetic
  multipliers the oracle reproduces it bit-for-bit;
* the causal block-skip bound (blocks no query in the tile can see are never
  executed) is part of the defined semantics, and the oracle replicates it.

The running max/exp/rescale stays in float32, and float32 online-softmax
arithmetic is where the bitwise contract gets subtle: XLA's CPU backend
contracts ``a*b + c`` into an FMA under jit — straight through
``optimization_barrier`` and even bitcast round-trips (the same contraction
behind the documented 1-ulp partitioned bias-add caveat from the sharding
work). No graph-level fence stops it, so instead of trying to pin each
multiply we pin the *structure*: the entire per-KV-block update lives in
:func:`_online_block`, shared verbatim by the Pallas kernel and the jnp
oracle (the PR-4 "shared tap-accumulate core" idiom). Both sides compile
the identical ``fori_loop`` body — a loop body is its own XLA computation,
so surrounding context cannot re-fuse it — and both public entry points run
their math under jit, which is why they agree bit for bit. Scales are
pinned with ``pin_rounding`` OUTSIDE the kernel and passed in as (1,)
operands, so single-device and sharded runs also see identical bits.

GQA shares KV through the BlockSpec index map (``b // rep``) — repeated K/V
never exist in HBM.

Paged KV (:func:`approx_flash_attention_paged`): the serving engines store
KV in fixed-size *physical blocks* drawn from a global pool instead of one
contiguous row per sequence, and the kernel reads them through a per-row
page table. The per-row ``rowinfo=[q_base, kv_start, kv_len]`` extents
already decouple logical from physical layout, so this is not a kernel
rewrite: the KV block size *is* the kernel's ``bk`` tile, the pool arrives
as one ``(Hkv, P*bk, D)`` operand (each grid row selects its KV head via
the same ``(b // rep)`` BlockSpec index map, now mod ``Hkv``), and the only
change inside the loop is where logical block ``ki`` starts —
``page_table[ki] * bk`` instead of ``ki * bk``. ``_online_block`` grows an
optional ``kv_blocks`` operand for exactly that indirection; with
``kv_blocks=None`` the body is byte-identical to the contiguous path, and
the paged oracle (:func:`~.ref.approx_attention_paged_ref`) drives the same
body with the same page table, so paged == contiguous == oracle bitwise
whenever the gathered blocks hold the same values as the contiguous layout
(masked keys keep the faithful ``LUT[0, ·]`` contribution either way —
which is why pool blocks must be zeroed on allocation, not on free: a
recycled block's stale codes would be observable under biased multipliers).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantization import pin_rounding
from repro.kernels.runtime import resolve_interpret

from .kernel import NEG_INF


def _mul_barrier(a, b):
    """``a * b`` behind an optimization barrier.

    NOT sufficient on its own — XLA CPU contracts through barriers (see
    module docstring) — but it keeps the graphs conservative on backends
    that do honor it. The real bitwise guarantee is the shared
    ``_online_block`` body.
    """
    return jax.lax.optimization_barrier(a * b)


def _quantize_sym(x, scale, lo, hi, offset):
    """Per-tensor symmetric quantize to shifted ACU codes (zero-point 0)."""
    return jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32) + offset


def attn_scales(q_scale, k_scale, v_scale, d_real: int, hi: int):
    """The two combined dequant scales, pinned outside the kernel.

    ``score = pin(pin(sq*sk) * (1/sqrt(d)))`` dequantizes the QK^T int32
    accumulator straight into softmax logits; ``pv = pin(sv * (1/hi))``
    dequantizes the PV accumulator (p codes carry the static 1/hi scale).
    """
    inv_sqrt_d = jnp.float32(1.0 / math.sqrt(d_real))
    score = pin_rounding(pin_rounding(q_scale * k_scale) * inv_sqrt_d)
    pv = pin_rounding(v_scale * jnp.float32(1.0 / hi))
    return score, pv


def _lut_gemm(a_codes, b_codes, lut, inner: int, n_codes: int):
    """``out[i, n] = sum_j LUT[a[i, j], b[j, n]]`` — int32, streamed in
    ``inner``-wide contraction chunks so the gather working set stays
    (m, inner, n)."""
    m_dim, k_dim = a_codes.shape
    n_dim = b_codes.shape[1]

    def step(i, acc):
        a_sl = jax.lax.dynamic_slice(a_codes, (0, i * inner), (m_dim, inner))
        b_sl = jax.lax.dynamic_slice(b_codes, (i * inner, 0), (inner, n_dim))
        idx = a_sl[:, :, None] * n_codes + b_sl[None, :, :]
        prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                         indices_are_sorted=False).reshape(m_dim, inner, n_dim)
        return acc + prods.sum(axis=1)

    return jax.lax.fori_loop(0, k_dim // inner, step,
                             jnp.zeros((m_dim, n_dim), jnp.int32))


def _online_block(ki, carry, *, qq, q_pos, k_all, v_all, lut, m00, sks, svs,
                  score_scale, pv_scale, kv_start, kv_len, bq: int, bk: int,
                  seq_k_real: int, d_real: int, n_codes: int, offset: int,
                  lo: int, hi: int, causal: bool, window: int | None,
                  softcap: float | None, inner_d: int, inner_k: int,
                  kv_blocks=None):
    """One KV block of the approximate online softmax — the shared core.

    Kernel and oracle both drive this exact function inside the same
    ``fori_loop`` shape; its body compiles once per program as its own XLA
    computation, which is what makes the two bitwise-identical (module
    docstring: FMA contraction cannot be fenced op-by-op on XLA CPU).

    ``kv_blocks``: optional (n_logical_blocks,) int32 page-table row mapping
    logical KV block ``ki`` to its physical block in the pool ``k_all`` /
    ``v_all`` are laid out as. ``None`` keeps the contiguous layout
    (physical start = ``ki * bk``) with a body byte-identical to the
    pre-paged kernel; masking, positions and pad corrections always speak
    *logical* coordinates, so the two layouts agree bit for bit when the
    gathered blocks hold the same values.
    """
    m, l, acc = carry
    dp = k_all.shape[-1]
    if kv_blocks is None:
        start = ki * bk
    else:
        start = jax.lax.dynamic_index_in_dim(
            kv_blocks, ki, keepdims=False).astype(jnp.int32) * bk
    kf = jax.lax.dynamic_slice(k_all, (start, 0), (bk, dp)
                               ).astype(jnp.float32)
    vf = jax.lax.dynamic_slice(v_all, (start, 0), (bk, dp)
                               ).astype(jnp.float32)
    kq = _quantize_sym(kf, sks, lo, hi, offset)
    vq = _quantize_sym(vf, svs, lo, hi, offset)

    s_int = _lut_gemm(qq, kq.T, lut, inner_d, n_codes)         # (bq, bk)
    s_int = s_int - (dp - d_real) * m00
    s = _mul_barrier(s_int.astype(jnp.float32), score_scale)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos >= kv_start) & (k_pos < kv_len)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m - m_new)
    # the normalizer accumulates the FLOAT probabilities; only the PV
    # contraction runs on the ACU
    l_new = _mul_barrier(alpha, l) + p.sum(axis=-1)
    pq = jnp.clip(jnp.round(p * hi), 0, hi).astype(jnp.int32) + offset
    pv_int = _lut_gemm(pq, vq, lut, inner_k, n_codes)          # (bq, dp)
    pv_int = pv_int - jnp.clip((ki + 1) * bk - seq_k_real, 0, bk) * m00
    pv = _mul_barrier(pv_int.astype(jnp.float32), pv_scale)
    acc_new = _mul_barrier(acc, alpha[:, None]) + pv
    return m_new, l_new, acc_new


def causal_block_bound(q_base, qi: int, bq: int, bk: int, n_kv: int):
    """Index one past the last kv block any query row of tile ``qi`` can see
    (``q_base`` shifts the tile to its absolute cache position). Part of the
    defined semantics: blocks beyond the bound are never executed, which is
    observable under biased multipliers (``M[0, x] != 0``), so the oracle
    uses the same bound."""
    return jnp.minimum(n_kv, (q_base + (qi + 1) * bq - 1) // bk + 1)


def _approx_kernel(q_ref, k_ref, v_ref, lut_ref, info_ref, sq_ref, sk_ref,
                   sv_ref, ss_ref, pvs_ref, o_ref, *, bq: int, bk: int,
                   seq_k: int, seq_k_real: int, d_real: int, n_codes: int,
                   offset: int, lo: int, hi: int, causal: bool,
                   window: int | None, softcap: float | None, inner_d: int,
                   inner_k: int):
    qi = pl.program_id(1)
    dp = q_ref.shape[-1]
    lut = lut_ref[...]
    m00 = lut[offset * n_codes + offset]
    info = info_ref[...]
    q_base, kv_start, kv_len = info[0, 0], info[0, 1], info[0, 2]

    qf = q_ref[...][0].astype(jnp.float32)                     # (bq, dp)
    qq = _quantize_sym(qf, sq_ref[0], lo, hi, offset)
    q_pos = (q_base + qi * bq
             + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))

    k_all = k_ref[...][0]                                      # (seq_k, dp)
    v_all = v_ref[...][0]

    n_kv = seq_k // bk
    if causal:
        n_kv_eff = causal_block_bound(q_base, qi, bq, bk, n_kv)
    else:
        n_kv_eff = n_kv

    body = functools.partial(
        _online_block, qq=qq, q_pos=q_pos, k_all=k_all, v_all=v_all, lut=lut,
        m00=m00, sks=sk_ref[0], svs=sv_ref[0], score_scale=ss_ref[0],
        pv_scale=pvs_ref[0], kv_start=kv_start, kv_len=kv_len, bq=bq, bk=bk,
        seq_k_real=seq_k_real, d_real=d_real, n_codes=n_codes, offset=offset,
        lo=lo, hi=hi, causal=causal, window=window, softcap=softcap,
        inner_d=inner_d, inner_k=inner_k)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dp), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None]


@functools.partial(jax.jit, static_argnames=(
    "seq_k_real", "d_real", "n_codes", "offset", "lo", "hi", "causal",
    "window", "softcap", "bq", "bk", "rep", "inner_d", "inner_k", "interpret"))
def approx_flash_attention_kernel(q, k, v, lut_flat, rowinfo, sqs, sks, svs,
                                  score_scale, pv_scale, *, seq_k_real: int,
                                  d_real: int, n_codes: int, offset: int,
                                  lo: int, hi: int, causal: bool,
                                  window: int | None, softcap: float | None,
                                  bq: int, bk: int, rep: int, inner_d: int,
                                  inner_k: int,
                                  interpret: bool | None = None):
    """Pre-padded entry: q (B*Hq, Sq_p, Dp) f32, k/v (B*Hkv, Sk_p, Dp),
    ``rowinfo`` (B*Hq, 3) int32 rows ``[q_base, kv_start, kv_len]``, five
    (1,)-shaped f32 scale operands. Returns (B*Hq, Sq_p, Dp) float32."""
    bh, sq_p, dp = q.shape
    bh_kv, sk_p, _ = k.shape
    assert bh == bh_kv * rep, (bh, bh_kv, rep)
    assert sq_p % bq == 0 and sk_p % bk == 0, (sq_p, sk_p, bq, bk)
    assert dp % inner_d == 0 and bk % inner_k == 0, (dp, inner_d, bk, inner_k)
    grid = (bh, sq_p // bq)
    scale_spec = pl.BlockSpec((1,), lambda b, i: (0,))
    return pl.pallas_call(
        functools.partial(_approx_kernel, bq=bq, bk=bk, seq_k=sk_p,
                          seq_k_real=seq_k_real, d_real=d_real,
                          n_codes=n_codes, offset=offset, lo=lo, hi=hi,
                          causal=causal, window=window, softcap=softcap,
                          inner_d=inner_d, inner_k=inner_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk_p, dp), lambda b, i: (b // rep, 0, 0)),
            pl.BlockSpec((1, sk_p, dp), lambda b, i: (b // rep, 0, 0)),
            pl.BlockSpec((n_codes * n_codes,), lambda b, i: (0,)),
            pl.BlockSpec((1, 3), lambda b, i: (b, 0)),
            scale_spec, scale_spec, scale_spec, scale_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(q, k, v, lut_flat, rowinfo, sqs, sks, svs, score_scale, pv_scale)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def prepare_approx_attention(q, k, v, lut, offset, q_scale, k_scale, v_scale,
                             *, bits: int, rowinfo, bq: int, bk: int):
    """Shared padding/geometry/scale resolution for the kernel wrapper AND
    the jnp oracle — both must see byte-identical padded operands and
    statics for the bitwise contract to be meaningful.

    Returns ``(operands, statics)``: operands is the tuple the kernel takes
    positionally; statics is a dict of the static keyword arguments.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = jnp.asarray(lut).reshape(-1).astype(jnp.int32)
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    rep = bh // bh_kv
    assert bh == bh_kv * rep, (bh, bh_kv)
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    # q tiles align to 8 sublanes, kv blocks to the 128-lane tile; small
    # sequences shrink the block instead of padding to the full default
    bq = min(bq, _round_up(sq, 8))
    bk = min(bk, _round_up(sk, 128))
    dp = _round_up(d, 16)
    inner_d = 16
    inner_k = next(x for x in (32, 16, 8, 4, 2, 1) if bk % x == 0)
    sq_p = _round_up(sq, bq)
    sk_p = _round_up(sk, bk)
    qf = jnp.asarray(q, jnp.float32)
    kf = jnp.asarray(k, jnp.float32)
    vf = jnp.asarray(v, jnp.float32)
    if sq_p != sq or dp != d:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, dp - d)))
    if sk_p != sk or dp != d:
        kf = jnp.pad(kf, ((0, 0), (0, sk_p - sk), (0, dp - d)))
        vf = jnp.pad(vf, ((0, 0), (0, sk_p - sk), (0, dp - d)))
    if rowinfo is None:
        # decode convention: queries end-aligned to the key sequence
        row = jnp.array([sk - sq, 0, sk], jnp.int32)
        rowinfo = jnp.broadcast_to(row, (bh, 3))
    rowinfo = jnp.asarray(rowinfo, jnp.int32)
    assert rowinfo.shape == (bh, 3), rowinfo.shape
    sqs = jnp.asarray(q_scale, jnp.float32).reshape(1)
    sks = jnp.asarray(k_scale, jnp.float32).reshape(1)
    svs = jnp.asarray(v_scale, jnp.float32).reshape(1)
    score_scale, pv_scale = attn_scales(sqs, sks, svs, d, hi)
    operands = (qf, kf, vf, lut_flat, rowinfo, sqs, sks, svs, score_scale,
                pv_scale)
    statics = dict(seq_k_real=sk, d_real=d, n_codes=n_codes, offset=offset,
                   lo=lo, hi=hi, bq=bq, bk=bk, rep=rep, inner_d=inner_d,
                   inner_k=inner_k)
    return operands, statics


def approx_flash_attention(q, k, v, lut, offset, q_scale, k_scale, v_scale, *,
                           bits: int = 8, causal: bool = True,
                           window: int | None = None,
                           softcap: float | None = None, rowinfo=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool | None = None):
    """Approximate GQA flash attention on the ACU.

    ``q``: (B*Hq, Sq, D) float; ``k``/``v``: (B*Hkv, Sk, D) float with
    ``Hq % Hkv == 0`` folded into the leading dim; ``lut`` the ACU product
    table ((n, n) or flattened) with shifted-code ``offset``;
    ``q_scale``/``k_scale``/``v_scale`` per-tensor symmetric scales (compute
    with ``inline_symmetric_scale`` so they are pinned and context-safe).
    ``rowinfo``: optional (B*Hq, 3) int32 ``[q_base, kv_start, kv_len]`` —
    the absolute cache position of query row 0, and the half-open valid key
    range (serving: left-pad offset and written-cache length). Defaults to
    the end-aligned decode convention over the full key sequence.
    Returns (B*Hq, Sq, D) float32, bitwise-identical to
    ``approx_attention_ref``.
    """
    sq, d = q.shape[1], q.shape[2]
    operands, statics = prepare_approx_attention(
        q, k, v, lut, offset, q_scale, k_scale, v_scale, bits=bits,
        rowinfo=rowinfo, bq=bq, bk=bk)
    out = approx_flash_attention_kernel(
        *operands, causal=causal, window=window, softcap=softcap,
        interpret=interpret, **statics)
    return out[:, :sq, :d]


# ---------------------------------------------------------------------------
# paged KV: same online softmax, KV read through a per-row page table
# ---------------------------------------------------------------------------

def _approx_paged_kernel(q_ref, k_ref, v_ref, lut_ref, info_ref, pt_ref,
                         sq_ref, sk_ref, sv_ref, ss_ref, pvs_ref, o_ref, *,
                         bq: int, bk: int, n_logical: int, d_real: int,
                         n_codes: int, offset: int, lo: int, hi: int,
                         causal: bool, window: int | None,
                         softcap: float | None, inner_d: int, inner_k: int):
    """Paged twin of ``_approx_kernel``: ``k_ref``/``v_ref`` hold one KV
    head's slice of the physical block pool, ``pt_ref`` the row's page
    table; the loop body is the same ``_online_block`` with the
    ``kv_blocks`` indirection. ``seq_k_real`` is always the full logical
    extent (``n_logical * bk``) — pool blocks are whole by construction, so
    there is no structural tail pad to correct; validity lives entirely in
    ``kv_len``."""
    qi = pl.program_id(1)
    dp = q_ref.shape[-1]
    lut = lut_ref[...]
    m00 = lut[offset * n_codes + offset]
    info = info_ref[...]
    q_base, kv_start, kv_len = info[0, 0], info[0, 1], info[0, 2]
    pt = pt_ref[...][0]                                        # (n_logical,)

    qf = q_ref[...][0].astype(jnp.float32)                     # (bq, dp)
    qq = _quantize_sym(qf, sq_ref[0], lo, hi, offset)
    q_pos = (q_base + qi * bq
             + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))

    k_all = k_ref[...][0]                                      # (P*bk, dp)
    v_all = v_ref[...][0]

    if causal:
        n_kv_eff = causal_block_bound(q_base, qi, bq, bk, n_logical)
    else:
        n_kv_eff = n_logical

    body = functools.partial(
        _online_block, qq=qq, q_pos=q_pos, k_all=k_all, v_all=v_all, lut=lut,
        m00=m00, sks=sk_ref[0], svs=sv_ref[0], score_scale=ss_ref[0],
        pv_scale=pvs_ref[0], kv_start=kv_start, kv_len=kv_len, bq=bq, bk=bk,
        seq_k_real=n_logical * bk, d_real=d_real, n_codes=n_codes,
        offset=offset, lo=lo, hi=hi, causal=causal, window=window,
        softcap=softcap, inner_d=inner_d, inner_k=inner_k, kv_blocks=pt)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dp), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None]


@functools.partial(jax.jit, static_argnames=(
    "d_real", "n_codes", "offset", "lo", "hi", "causal", "window", "softcap",
    "bq", "bk", "rep", "inner_d", "inner_k", "interpret"))
def approx_flash_attention_paged_kernel(q, k_pool, v_pool, lut_flat, rowinfo,
                                        page_table, sqs, sks, svs,
                                        score_scale, pv_scale, *,
                                        d_real: int, n_codes: int,
                                        offset: int, lo: int, hi: int,
                                        causal: bool, window: int | None,
                                        softcap: float | None, bq: int,
                                        bk: int, rep: int, inner_d: int,
                                        inner_k: int,
                                        interpret: bool | None = None):
    """Pre-padded paged entry: q (B*Hq, Sq_p, Dp) f32; ``k_pool``/``v_pool``
    (Hkv, P*bk, Dp) — the physical block pool, one row per KV head, blocks
    laid out back to back; ``rowinfo`` (B*Hq, 3) int32
    ``[q_base, kv_start, kv_len]`` in *logical* coordinates; ``page_table``
    (B*Hq, n_logical) int32 mapping each row's logical block to a physical
    block index into the pool. Returns (B*Hq, Sq_p, Dp) float32."""
    bh, sq_p, dp = q.shape
    hkv, pool_len, _ = k_pool.shape
    n_logical = page_table.shape[1]
    assert page_table.shape[0] == bh and rowinfo.shape == (bh, 3)
    assert sq_p % bq == 0 and pool_len % bk == 0, (sq_p, pool_len, bq, bk)
    assert dp % inner_d == 0 and bk % inner_k == 0, (dp, inner_d, bk, inner_k)
    grid = (bh, sq_p // bq)
    scale_spec = pl.BlockSpec((1,), lambda b, i: (0,))
    return pl.pallas_call(
        functools.partial(_approx_paged_kernel, bq=bq, bk=bk,
                          n_logical=n_logical, d_real=d_real,
                          n_codes=n_codes, offset=offset, lo=lo, hi=hi,
                          causal=causal, window=window, softcap=softcap,
                          inner_d=inner_d, inner_k=inner_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, pool_len, dp),
                         lambda b, i: ((b // rep) % hkv, 0, 0)),
            pl.BlockSpec((1, pool_len, dp),
                         lambda b, i: ((b // rep) % hkv, 0, 0)),
            pl.BlockSpec((n_codes * n_codes,), lambda b, i: (0,)),
            pl.BlockSpec((1, 3), lambda b, i: (b, 0)),
            pl.BlockSpec((1, n_logical), lambda b, i: (b, 0)),
            scale_spec, scale_spec, scale_spec, scale_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, bq, dp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(q, k_pool, v_pool, lut_flat, rowinfo, page_table, sqs, sks, svs,
      score_scale, pv_scale)


def prepare_approx_attention_paged(q, k_pool, v_pool, lut, offset, q_scale,
                                   k_scale, v_scale, *, bits: int, rowinfo,
                                   page_table, bq: int):
    """Shared padding/geometry/scale resolution for the paged kernel AND its
    jnp oracle (mirror of :func:`prepare_approx_attention`). The KV block
    size is fixed by the pool layout (``bk = pool block extent``), so only
    q-side geometry adapts; the pool's head dim is padded to the gather
    chunk exactly like the contiguous operands."""
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = jnp.asarray(lut).reshape(-1).astype(jnp.int32)
    bh, sq, d = q.shape
    hkv, n_phys, bk, _ = k_pool.shape
    page_table = jnp.asarray(page_table, jnp.int32)
    rowinfo = jnp.asarray(rowinfo, jnp.int32)
    assert rowinfo.shape == (bh, 3), rowinfo.shape
    assert page_table.shape[0] == bh, (page_table.shape, bh)
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    bq = min(bq, _round_up(sq, 8))
    dp = _round_up(d, 16)
    inner_d = 16
    inner_k = next(x for x in (32, 16, 8, 4, 2, 1) if bk % x == 0)
    sq_p = _round_up(sq, bq)
    qf = jnp.asarray(q, jnp.float32)
    kp = jnp.asarray(k_pool, jnp.float32).reshape(hkv, n_phys * bk, d)
    vp = jnp.asarray(v_pool, jnp.float32).reshape(hkv, n_phys * bk, d)
    if sq_p != sq or dp != d:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, dp - d)))
    if dp != d:
        kp = jnp.pad(kp, ((0, 0), (0, 0), (0, dp - d)))
        vp = jnp.pad(vp, ((0, 0), (0, 0), (0, dp - d)))
    sqs = jnp.asarray(q_scale, jnp.float32).reshape(1)
    sks = jnp.asarray(k_scale, jnp.float32).reshape(1)
    svs = jnp.asarray(v_scale, jnp.float32).reshape(1)
    score_scale, pv_scale = attn_scales(sqs, sks, svs, d, hi)
    operands = (qf, kp, vp, lut_flat, rowinfo, page_table, sqs, sks, svs,
                score_scale, pv_scale)
    statics = dict(d_real=d, n_codes=n_codes, offset=offset, lo=lo, hi=hi,
                   bq=bq, bk=bk, inner_d=inner_d, inner_k=inner_k)
    return operands, statics


def approx_flash_attention_paged(q, k_pool, v_pool, lut, offset, q_scale,
                                 k_scale, v_scale, *, rowinfo, page_table,
                                 rep: int, bits: int = 8, causal: bool = True,
                                 window: int | None = None,
                                 softcap: float | None = None, bq: int = 128,
                                 interpret: bool | None = None):
    """Approximate GQA flash attention over block-paged KV.

    ``q``: (B*Hq, Sq, D) float; ``k_pool``/``v_pool``: (Hkv, P, bk, D) —
    the physical KV block pool shared by every sequence (``P`` physical
    blocks of ``bk`` positions each, per KV head); ``page_table``:
    (B*Hq, n_logical) int32, each row mapping its logical KV blocks to
    physical block indices (entries past the row's allocation should point
    at an always-zero block so non-causal masks still see the contiguous
    layout's zeros); ``rowinfo``: (B*Hq, 3) int32 logical
    ``[q_base, kv_start, kv_len]`` — REQUIRED here, there is no full-pool
    default that makes sense. ``rep = Hq // Hkv`` maps query row
    ``b`` to pool row ``(b // rep) % Hkv``.

    Bitwise-identical to ``approx_attention_paged_ref``, and to the
    contiguous :func:`approx_flash_attention` at ``bk = block size`` when
    the gathered blocks hold the same values as the contiguous layout.
    """
    sq, d = q.shape[1], q.shape[2]
    operands, statics = prepare_approx_attention_paged(
        q, k_pool, v_pool, lut, offset, q_scale, k_scale, v_scale,
        bits=bits, rowinfo=rowinfo, page_table=page_table, bq=bq)
    out = approx_flash_attention_paged_kernel(
        *operands, causal=causal, window=window, softcap=softcap, rep=rep,
        interpret=interpret, **statics)
    return out[:, :sq, :d]
