"""Pure-jnp oracles: naive exact attention, and the unfused approximate
composition the approx kernel must match bitwise.

The approximate oracle is deliberately NOT an independent re-derivation of
the float arithmetic: XLA CPU contracts ``a*b + c`` into an FMA under jit,
straight through ``optimization_barrier`` (see the approx module docstring),
so two independently-written online-softmax loops land 1 ulp apart. Instead
the oracle drives the same :func:`~.approx._online_block` the kernel runs,
inside the same ``fori_loop`` shape, under jit — identical loop-body jaxprs
compile to identical machine code, which is the bitwise contract. What the
oracle independently exercises is the *orchestration*: python loops over
(row, q-block) instead of a Pallas grid, whole-array indexing instead of
BlockSpec pipelines, and the GQA ``b // rep`` mapping as plain indexing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None) -> jnp.ndarray:
    """q: (BH, Sq, D), k/v: (BH, Sk, D). Queries are aligned to the END of the
    key sequence when Sq != Sk (decode convention)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "seq_k_real", "d_real", "n_codes",
    "offset", "lo", "hi", "bq", "bk", "rep", "inner_d", "inner_k"))
def _approx_ref_core(qp, kp, vp, lut_flat, info, sqs, sks, svs, score_scale,
                     pv_scale, *, causal: bool, window: int | None,
                     softcap: float | None, seq_k_real: int, d_real: int,
                     n_codes: int, offset: int, lo: int, hi: int, bq: int,
                     bk: int, rep: int, inner_d: int, inner_k: int):
    from .approx import NEG_INF, _online_block, _quantize_sym, \
        causal_block_bound

    bh, sq_p, dp = qp.shape
    sk_p = kp.shape[1]
    n_kv = sk_p // bk
    m00 = lut_flat[offset * n_codes + offset]
    out_rows = []
    for b in range(bh):
        q_base, kv_start, kv_len = info[b, 0], info[b, 1], info[b, 2]
        k_all = kp[b // rep]
        v_all = vp[b // rep]
        q_blocks = []
        for qi in range(sq_p // bq):
            qf = qp[b, qi * bq:(qi + 1) * bq].astype(jnp.float32)
            qq = _quantize_sym(qf, sqs[0], lo, hi, offset)
            q_pos = (q_base + qi * bq
                     + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            if causal:
                n_kv_eff = causal_block_bound(q_base, qi, bq, bk, n_kv)
            else:
                n_kv_eff = n_kv
            body = functools.partial(
                _online_block, qq=qq, q_pos=q_pos, k_all=k_all, v_all=v_all,
                lut=lut_flat, m00=m00, sks=sks[0], svs=svs[0],
                score_scale=score_scale[0], pv_scale=pv_scale[0],
                kv_start=kv_start, kv_len=kv_len, bq=bq, bk=bk,
                seq_k_real=seq_k_real, d_real=d_real, n_codes=n_codes,
                offset=offset, lo=lo, hi=hi, causal=causal, window=window,
                softcap=softcap, inner_d=inner_d, inner_k=inner_k)
            m0 = jnp.full((bq,), NEG_INF, jnp.float32)
            l0 = jnp.zeros((bq,), jnp.float32)
            acc0 = jnp.zeros((bq, dp), jnp.float32)
            m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
            q_blocks.append(acc / jnp.maximum(l, 1e-30)[:, None])
        out_rows.append(jnp.concatenate(q_blocks, axis=0))
    return jnp.stack(out_rows)


def approx_attention_ref(q, k, v, lut, offset, q_scale, k_scale, v_scale, *,
                         bits: int = 8, causal: bool = True,
                         window: int | None = None,
                         softcap: float | None = None, rowinfo=None,
                         bq: int = 128, bk: int = 128):
    """Unfused oracle for ``approx_flash_attention`` — same operand
    preparation (``prepare_approx_attention``), same per-KV-block update
    (``_online_block``), different orchestration. Bitwise-identical output
    by construction; see the module docstring for why sharing the block
    update is load-bearing."""
    from .approx import prepare_approx_attention

    sq, d = q.shape[1], q.shape[2]
    operands, statics = prepare_approx_attention(
        q, k, v, lut, offset, q_scale, k_scale, v_scale, bits=bits,
        rowinfo=rowinfo, bq=bq, bk=bk)
    out = _approx_ref_core(*operands, causal=causal, window=window,
                           softcap=softcap, **statics)
    return out[:, :sq, :d]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "d_real", "n_codes", "offset", "lo", "hi",
    "bq", "bk", "rep", "inner_d", "inner_k"))
def _approx_paged_ref_core(qp, kp, vp, lut_flat, info, page_table, sqs, sks,
                           svs, score_scale, pv_scale, *, causal: bool,
                           window: int | None, softcap: float | None,
                           d_real: int, n_codes: int, offset: int, lo: int,
                           hi: int, bq: int, bk: int, rep: int, inner_d: int,
                           inner_k: int):
    from .approx import NEG_INF, _online_block, _quantize_sym, \
        causal_block_bound

    bh, sq_p, dp = qp.shape
    hkv = kp.shape[0]
    n_logical = page_table.shape[1]
    m00 = lut_flat[offset * n_codes + offset]
    out_rows = []
    for b in range(bh):
        q_base, kv_start, kv_len = info[b, 0], info[b, 1], info[b, 2]
        k_all = kp[(b // rep) % hkv]
        v_all = vp[(b // rep) % hkv]
        pt = page_table[b]
        q_blocks = []
        for qi in range(sq_p // bq):
            qf = qp[b, qi * bq:(qi + 1) * bq].astype(jnp.float32)
            qq = _quantize_sym(qf, sqs[0], lo, hi, offset)
            q_pos = (q_base + qi * bq
                     + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
            if causal:
                n_kv_eff = causal_block_bound(q_base, qi, bq, bk, n_logical)
            else:
                n_kv_eff = n_logical
            body = functools.partial(
                _online_block, qq=qq, q_pos=q_pos, k_all=k_all, v_all=v_all,
                lut=lut_flat, m00=m00, sks=sks[0], svs=svs[0],
                score_scale=score_scale[0], pv_scale=pv_scale[0],
                kv_start=kv_start, kv_len=kv_len, bq=bq, bk=bk,
                seq_k_real=n_logical * bk, d_real=d_real, n_codes=n_codes,
                offset=offset, lo=lo, hi=hi, causal=causal, window=window,
                softcap=softcap, inner_d=inner_d, inner_k=inner_k,
                kv_blocks=pt)
            m0 = jnp.full((bq,), NEG_INF, jnp.float32)
            l0 = jnp.zeros((bq,), jnp.float32)
            acc0 = jnp.zeros((bq, dp), jnp.float32)
            m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
            q_blocks.append(acc / jnp.maximum(l, 1e-30)[:, None])
        out_rows.append(jnp.concatenate(q_blocks, axis=0))
    return jnp.stack(out_rows)


def approx_attention_paged_ref(q, k_pool, v_pool, lut, offset, q_scale,
                               k_scale, v_scale, *, rowinfo, page_table,
                               rep: int, bits: int = 8, causal: bool = True,
                               window: int | None = None,
                               softcap: float | None = None, bq: int = 128):
    """Unfused oracle for ``approx_flash_attention_paged`` — same operand
    preparation (``prepare_approx_attention_paged``), same per-KV-block
    update with the same ``kv_blocks`` page-table indirection, python
    orchestration. Bitwise-identical output by construction."""
    from .approx import prepare_approx_attention_paged

    sq, d = q.shape[1], q.shape[2]
    operands, statics = prepare_approx_attention_paged(
        q, k_pool, v_pool, lut, offset, q_scale, k_scale, v_scale,
        bits=bits, rowinfo=rowinfo, page_table=page_table, bq=bq)
    out = _approx_paged_ref_core(*operands, causal=causal, window=window,
                                 softcap=softcap, rep=rep, **statics)
    return out[:, :sq, :d]
