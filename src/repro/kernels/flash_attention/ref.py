"""Pure-jnp oracle: naive attention with causal/window/softcap masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None) -> jnp.ndarray:
    """q: (BH, Sq, D), k/v: (BH, Sk, D). Queries are aligned to the END of the
    key sequence when Sq != Sk (decode convention)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
