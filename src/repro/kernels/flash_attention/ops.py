"""Public wrappers: (B, H, S, D) GQA attention via the flash kernels.

GQA no longer materializes ``jnp.repeat(k, rep, axis=1)`` (which copied K/V
``rep×`` in HBM before the kernel ever ran) — the kernels map query-head
blocks onto their shared KV head through the BlockSpec index map.
"""
from __future__ import annotations

import jax.numpy as jnp

from .approx import approx_flash_attention  # noqa: F401  (re-export)
from .kernel import flash_attention_kernel


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, bq: int = 256,
                    bk: int = 256, interpret: bool | None = None
                    ) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0 (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    assert hq == hkv * rep, (hq, hkv)
    out = flash_attention_kernel(
        q.reshape(b * hq, s, d), k.reshape(b * hkv, s, d),
        v.reshape(b * hkv, s, d), causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, rep=rep, interpret=interpret)
    return out.reshape(b, hq, s, d)
