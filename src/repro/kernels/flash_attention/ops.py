"""Public wrapper: (B, H, S, D) GQA attention via the flash kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention_kernel


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, bq: int = 256,
                    bk: int = 256, interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0 (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    out = flash_attention_kernel(
        q.reshape(b * hq, s, d), k.reshape(b * hq, s, d),
        v.reshape(b * hq, s, d), causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, interpret=interpret)
    return out.reshape(b, hq, s, d)
