"""Pallas TPU kernel: flash attention (online-softmax, causal / sliding-window).

Substrate hot-spot for the LM architectures: O(S) memory attention. Grid is
(batch*heads, q_blocks); each step scans KV blocks with running (m, l, acc)
online-softmax state. Causal masking skips fully-masked KV blocks via the
block index bound; sliding-window masking (gemma2 local layers) and logit
soft-capping are fused in.

VMEM @ defaults (bq=bk=256, d=128): q/k/v tiles 3*256*128*4 = 384 KiB +
scores 256*256*4 = 256 KiB + state — comfortably inside 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, seq_k: int,
            causal: bool, window: int | None, softcap: float | None,
            scale: float):
    qi = pl.program_id(1)
    q = q_ref[...][0].astype(jnp.float32) * scale        # (bq, d)
    d = q.shape[-1]
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    n_kv = seq_k // bk
    if causal:
        # last kv block that any query in this q block can see
        n_kv_eff = jnp.minimum(n_kv, (qi + 1) * bq // bk + 1)
    else:
        n_kv_eff = n_kv

    k_all = k_ref[...][0]                                # (seq_k, d), VMEM-resident
    v_all = v_ref[...][0]

    def body(ki, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_all, (ki * bk, 0), (bk, d)
                                  ).astype(jnp.float32)  # (bk, d)
        v = jax.lax.dynamic_slice(v_all, (ki * bk, 0), (bk, d)
                                  ).astype(jnp.float32)
        s = q @ k.T                                      # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "rep", "interpret"))
def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           softcap: float | None = None, bq: int = 256,
                           bk: int = 256, rep: int = 1,
                           interpret: bool | None = None) -> jnp.ndarray:
    """q: (B*Hq, Sq, D), k/v: (B*Hkv, Sk, D) — heads pre-folded into batch.

    GQA never materializes repeated KV: ``rep = Hq // Hkv`` query-head rows
    share one KV row through the BlockSpec index map (``b // rep``), so K/V
    stay at their (B*Hkv, Sk, D) HBM footprint.
    """
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    assert bh == bh_kv * rep, (bh, bh_kv, rep)
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    scale = 1.0 / (d ** 0.5)
    grid = (bh, sq // bq)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, seq_k=sk, causal=causal,
                          window=window, softcap=softcap, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk, d), lambda b, i: (b // rep, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda b, i: (b // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=resolve_interpret(interpret),
    )(q, k, v)
