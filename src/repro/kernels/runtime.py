"""Process-wide execution-mode knob for every Pallas kernel wrapper.

Every kernel in this package historically hardcoded ``interpret=True`` in its
own signature (the dev container has no TPU, so kernels run under the Pallas
interpreter on CPU). That scattered default made the ROADMAP real-hardware
item an N-file sweep. It now lives here, once:

* wrappers declare ``interpret: bool | None = None`` and resolve the actual
  value with :func:`resolve_interpret` right before ``pallas_call``;
* the default is env-overridable — ``REPRO_INTERPRET=0`` flips the whole
  package to compiled Mosaic kernels without touching a call site.

Explicitly passing ``interpret=True/False`` at a call site still wins (tests
pin interpret mode that way); only the *default* is centralized. The env var
is read when a kernel is traced, so it is a process-level switch, not a
per-call one. ``tests/test_runtime.py`` asserts no kernel wrapper regresses
to a hardcoded default.
"""
from __future__ import annotations

import os

_ENV = "REPRO_INTERPRET"
_FALSY = {"0", "false", "no", "off", ""}


def interpret_default() -> bool:
    """The package-wide default for ``pallas_call(interpret=...)``.

    ``True`` unless ``REPRO_INTERPRET`` is set to a falsy value (``0``,
    ``false``, ``no``, ``off``) — the one-switch flip for running on real
    TPU hardware.
    """
    v = os.environ.get(_ENV)
    if v is None:
        return True
    return v.strip().lower() not in _FALSY


def resolve_interpret(value: bool | None) -> bool:
    """Resolve a wrapper's ``interpret`` argument: an explicit ``True`` /
    ``False`` wins; ``None`` (the signature default everywhere) defers to
    :func:`interpret_default`."""
    if value is None:
        return interpret_default()
    return bool(value)
