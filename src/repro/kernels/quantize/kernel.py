"""Pallas TPU kernel: fused affine quantize (scale / shift / round / clip).

The paper reports ~10% overhead from per-layer quantize/dequantize; fusing the
whole affine pipeline into one VMEM pass removes the intermediate HBM round
trips. Elementwise, so the BlockSpec just tiles rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, s_ref, z_ref, o_ref, *, lo: int, hi: int):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[0]
    z = z_ref[0]
    q = jnp.clip(jnp.round(x / s + z), lo, hi)
    o_ref[...] = q.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def quantize_kernel(x: jnp.ndarray, scale: jnp.ndarray, zero_point: jnp.ndarray,
                    *, bits: int = 8, block: int = 1024,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Per-tensor affine quantization of a flattened tensor.

    x: (N,) float; scale/zero_point: scalars as shape-(1,) arrays.
    """
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0, (n, block)
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return pl.pallas_call(
        functools.partial(_kernel, lo=lo, hi=hi),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(x, scale, zero_point)
