"""Pure-jnp oracle for the fused quantize kernel."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, scale, zero_point, bits: int = 8) -> jnp.ndarray:
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    q = jnp.round(x.astype(jnp.float32) / scale + zero_point)
    return jnp.clip(q, lo, hi).astype(jnp.int32)
