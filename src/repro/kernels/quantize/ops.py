"""Public wrapper: quantize arbitrary-shape tensors via the fused kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import quantize_kernel


def quantize_op(x: jnp.ndarray, scale, zero_point, *, bits: int = 8,
                interpret: bool | None = None) -> jnp.ndarray:
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = 1024
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    s = jnp.asarray(scale, jnp.float32).reshape(1)
    z = jnp.asarray(zero_point, jnp.float32).reshape(1)
    q = quantize_kernel(flat, s, z, bits=bits, block=block, interpret=interpret)
    return q[:n].reshape(shape)
