"""Pure-jnp oracle for the patch-streaming fused conv kernels.

The reference IS the retired eager path: materialize the im2col patch tensor,
then run the fused dense reference (same quantizer expression, same int32
accumulate, same single combined-scale dequant). Both Pallas kernels — the
whole-image one and the spatially-tiled one, at every band height — must
match it bit for bit; that equality is the whole contract of the refactor
(int32 tap accumulation is order-independent, so tiling can only move work
between grid steps, never change a single bit of the result).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_lut_dense.ref import fused_lut_dense_ref


def fused_lut_conv_ref(x: jnp.ndarray, wq: jnp.ndarray, lut_flat: jnp.ndarray,
                       offset: int, n_codes: int, x_scale, x_zp, w_scale, *,
                       stride=(1, 1), padding=((0, 0), (0, 0)),
                       dilation=(1, 1), bits: int = 8) -> jnp.ndarray:
    """x: (N, C, H, W) float; wq: (Cout, C, kh, kw) shifted weight codes.
    Returns (N, Ho, Wo, Cout) float32. O(N*P*C*kh*kw*Cout) memory — test
    oracle only."""
    # the oracle uses the SAME patch extraction as the production eager
    # route — two copies could drift apart and green-light a broken
    # bit-exactness claim
    from repro.core.approx_ops import _im2col
    cout, _, kh, kw = wq.shape
    cols, (ho, wo) = _im2col(x, kh, kw, stride, padding, dilation)
    m = cols.reshape(-1, cols.shape[-1])                 # (N*P, C*kh*kw)
    wmat = wq.reshape(cout, -1).T                        # (C*kh*kw, Cout)
    out = fused_lut_dense_ref(m, wmat, lut_flat, offset, n_codes,
                              x_scale, x_zp, w_scale, bits=bits)
    return out.reshape(x.shape[0], ho, wo, cout)
