"""Pallas TPU kernels: patch-streaming im2col -> quantize -> LUT-GEMM -> dequant.

One ``pallas_call`` for the whole approximate conv2d forward, in two spatial
flavours that share one tap-accumulate core (:func:`_acc_taps`):

* **whole-image** (:func:`fused_lut_conv_kernel`) — the PR 3 kernel. The
  BlockSpec index maps stream whole padded *images* (the raw input bytes, no
  duplication) into VMEM and keep them resident across the ``(i, j)``
  sub-grid. Bounded to images whose working set fits the VMEM budget.
* **spatially tiled** (:func:`fused_lut_conv_tiled_kernel`) — the PR 4
  kernel that lifts that bound. The grid runs over *output-row bands*; per
  band only the ``(bh-1)*stride + (kh-1)*dilation + 1`` halo'd input rows
  are resident. Pallas block index maps are block-granular, so the
  overlapping halo windows are expressed by passing the padded image
  ``n_copies`` times with row-shifted index maps (``i``, ``i+1``, ...,
  each a ``bh*stride``-row block): band ``i`` sees rows ``[i*S, (i +
  n_copies)*S)`` which cover its halo'd window, and consecutive bands
  re-stream only the ~1 halo block they share — never the whole image,
  never the ``kh*kw``-times-larger patch tensor.

The eager conv path materialized the (N*Ho*Wo, C*kh*kw) im2col patch tensor
in HBM before handing it to ``fused_lut_dense`` — an HBM round-trip
``kh*kw`` times larger than the input itself. Here the patch tensor never
exists anywhere. Per image (whole-image) or per band (tiled) the float block
is quantized ONCE into a persistent int32 VMEM scratch at the first ``j``
step, so the quantizer runs per input pixel — not per patch entry, which
duplicates every pixel up to ``kh*kw`` times in the im2col formulation.
Each grid step then loops over the ``kh*kw`` taps:

1. **tap window slice (VPU)** — a strided ``lax.slice`` of the resident code
   rows picks the ``(C, bh, Wo)`` window for tap ``(u, v)`` under
   (stride, dilation); transposed to a ``(bh*Wo, C)`` operand tile.
2. **LUT gathers** — the (2^b, 2^b) product table is pinned in VMEM for the
   whole grid (same trick as ``fused_lut_dense``); gathers run in ``inner``-
   channel sub-slices against the tap's ``(C, bn)`` weight-code slab.
3. **int32 accumulate** — taps and channel chunks add associatively, so the
   accumulator equals the im2col GEMM's bit for bit, in any order — which is
   also why *any* spatial tiling (whole image, in-kernel bands, mesh-level
   band shards) produces bit-identical outputs.
4. **affine dequant** — ``acc * (x_scale * w_scale[n])``, the same single
   combined-scale multiply as ``fused_lut_dense``; the f32 output strip is
   the only HBM store. ``emit_acc=True`` skips it and emits the raw int32
   accumulator for the channel-contraction-sharded route.

Channel padding (C up to a multiple of ``inner``) feeds shifted code 0
through every tap, contributing ``kh*kw * LUT[off, off] = kh*kw * M[0, 0]``
per padded channel per output; the correction is subtracted *in integer
space* before dequant (``c_pad_corr``), exactly like the K-pad correction in
the dense kernel. Spatial (SAME) padding needs NO correction: the im2col
oracle also quantizes its 0.0 pad entries to shifted code 0, so both paths
accumulate the same ``M[0, 0]`` terms and stay bit-exact.

VMEM: the whole-image kernel holds ``8 * C * Hp * Wp`` bytes of image block
+ code scratch; the tiled kernel holds ``8 * C * (n_copies * bh * sh) * Wp``
— at a 224x224x64 ImageNet-scale layer that is ~26 MiB vs ~450 KiB per band.
``conv_plan`` audits both against the budget and picks the route
(``core.acu._conv_vmem_estimate`` / ``pick_conv_spatial_tiling``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret
from jax.experimental.pallas import tpu as pltpu


def _acc_taps(a_img, w, lut, *, n_codes: int, inner: int, kh: int,
              kw: int, sh: int, sw: int, dh: int, dw: int, bh: int,
              wo: int, row0):
    """The shared tap-accumulate core: ``a_img`` is the resident (C, rows,
    cols) shifted-code block (whole image or halo'd band), ``w`` the
    (kh*kw, C, bn) tap-major weight codes, ``lut`` the flat product table.
    Returns the (bh*wo, bn) int32 accumulator for the output-row strip
    whose first tap reads input row ``row0``."""
    c = a_img.shape[0]
    bn = w.shape[2]
    bm = bh * wo
    acc = jnp.zeros((bm, bn), jnp.int32)
    for t in range(kh * kw):                        # static tap loop
        u, v = divmod(t, kw)
        win = jax.lax.dynamic_slice(
            a_img, (0, row0 + u * dh, v * dw),
            (c, (bh - 1) * sh + 1, (wo - 1) * sw + 1))
        win = jax.lax.slice(win, (0, 0, 0), win.shape, (1, sh, sw))  # (C, bh, wo)
        a_t = win.transpose(1, 2, 0).reshape(bm, c)  # (bm, C) patch rows
        w_t = w[t]                                   # (C, bn)

        def body(ci, acc):
            a_sl = jax.lax.dynamic_slice(a_t, (0, ci * inner), (bm, inner))
            w_sl = jax.lax.dynamic_slice(w_t, (ci * inner, 0), (inner, bn))
            idx = a_sl[:, :, None] * n_codes + w_sl[None, :, :]
            prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                             indices_are_sorted=False).reshape(bm, inner, bn)
            return acc + prods.sum(axis=1)

        acc = jax.lax.fori_loop(0, c // inner, body, acc)
    return acc


def _quantize_codes(img, xs, xz, *, lo: int, hi: int, offset: int):
    """float block -> shifted codes in LUT index space. Spatial pad pixels
    are 0.0, which quantizes to the zero-point, i.e. index ``offset`` —
    exactly what the im2col oracle's 0.0 patch entries produce."""
    q = jnp.clip(jnp.round(img.astype(jnp.float32) / xs + xz), lo, hi)
    return q.astype(jnp.int32) - xz.astype(jnp.int32) + offset


def _kernel(x_ref, w_ref, lut_ref, xs_ref, xz_ref, ws_ref, o_ref, aimg_ref, *,
            offset: int, n_codes: int, lo: int, hi: int, inner: int,
            kh: int, kw: int, sh: int, sw: int, dh: int, dw: int,
            bh: int, wo: int, c_pad_corr: int, emit_acc: bool):
    i = pl.program_id(1)
    j = pl.program_id(2)
    xs = xs_ref[0]                                  # per-tensor activation scale
    xz = xz_ref[0]                                  # activation zero-point (code)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _quantize_image():
        # once per image (scratch persists across the (i, j) sub-grid)
        aimg_ref[...] = _quantize_codes(x_ref[...][0], xs, xz, lo=lo, hi=hi,
                                        offset=offset)

    a_img = aimg_ref[...]                           # (C, Hp, Wp) index space
    w = w_ref[...].astype(jnp.int32) + offset       # (kh*kw, C, bn)
    lut = lut_ref[...]                              # (n_codes * n_codes,)
    bn = w.shape[2]
    row0 = i * bh * sh                              # first input row this strip

    acc = _acc_taps(a_img, w, lut, n_codes=n_codes, inner=inner, kh=kh,
                    kw=kw, sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, wo=wo,
                    row0=row0)

    if c_pad_corr:  # padded channels contributed LUT[off, off] = M[0, 0]
        acc = acc - c_pad_corr * lut[offset * n_codes + offset]
    if emit_acc:
        # channel-contraction sharding: partial int32 accumulators leave the
        # kernel, psum across C shards, dequant once after the collective
        o_ref[...] = acc.reshape(1, bh, wo, bn)
    else:
        # one combined-scale multiply, same expression as fused_lut_dense
        out = acc.astype(jnp.float32) * (xs * ws_ref[...])
        o_ref[...] = out.reshape(1, bh, wo, bn)


@functools.partial(jax.jit, static_argnames=(
    "offset", "n_codes", "lo", "hi", "inner", "kh", "kw", "sh", "sw",
    "dh", "dw", "bh", "bn", "wo", "ho_pad", "c_pad_corr", "interpret",
    "emit_acc"))
def fused_lut_conv_kernel(xp: jnp.ndarray, wq: jnp.ndarray,
                          lut_flat: jnp.ndarray, x_scale: jnp.ndarray,
                          x_zp: jnp.ndarray, w_scale_row: jnp.ndarray, *,
                          offset: int, n_codes: int, lo: int, hi: int,
                          inner: int, kh: int, kw: int, sh: int, sw: int,
                          dh: int, dw: int, bh: int, bn: int, wo: int,
                          ho_pad: int, c_pad_corr: int = 0,
                          interpret: bool | None = None,
                          emit_acc: bool = False) -> jnp.ndarray:
    """Whole-image variant. xp: (N, C, Hp, Wp) float, spatially pre-padded,
    C a multiple of ``inner``; wq: (kh*kw, C, Cout) shifted int weight codes,
    tap-major; lut_flat: (n_codes**2,) int32; x_scale/x_zp: shape-(1,) f32;
    w_scale_row: (1, Cout) f32. Returns (N, ho_pad, Wo, Cout) float32 — or
    the raw int32 accumulator with ``emit_acc=True``."""
    n, c, hp, wp = xp.shape
    cout = wq.shape[2]
    assert c % inner == 0 and cout % bn == 0 and ho_pad % bh == 0, (
        f"conv tiling mismatch: C={c}/inner={inner}, Cout={cout}/bn={bn}, "
        f"Ho_pad={ho_pad}/bh={bh}")
    grid = (n, ho_pad // bh, cout // bn)
    return pl.pallas_call(
        functools.partial(_kernel, offset=offset, n_codes=n_codes, lo=lo,
                          hi=hi, inner=inner, kh=kh, kw=kw, sh=sh, sw=sw,
                          dh=dh, dw=dw, bh=bh, wo=wo, c_pad_corr=c_pad_corr,
                          emit_acc=emit_acc),
        grid=grid,
        in_specs=[
            # the whole padded image streams in once per n (the block index
            # is constant over the (i, j) sub-grid) — raw input bytes, never
            # the kh*kw-times-larger patch tensor
            pl.BlockSpec((1, c, hp, wp), lambda n, i, j: (n, 0, 0, 0)),
            pl.BlockSpec((kh * kw, c, bn), lambda n, i, j: (0, 0, j)),
            pl.BlockSpec((n_codes * n_codes,), lambda n, i, j: (0,)),
            pl.BlockSpec((1,), lambda n, i, j: (0,)),
            pl.BlockSpec((1,), lambda n, i, j: (0,)),
            pl.BlockSpec((1, bn), lambda n, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bh, wo, bn), lambda n, i, j: (n, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (n, ho_pad, wo, cout), jnp.int32 if emit_acc else jnp.float32),
        scratch_shapes=[pltpu.VMEM((c, hp, wp), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(xp, wq, lut_flat, x_scale, x_zp, w_scale_row)


def _bwd_w_kernel(*refs, offset: int, n_codes: int, lo: int, hi: int,
                  mc: int, kh: int, kw: int, sh: int, sw: int, dh: int,
                  dw: int, bh: int, wo: int, n_copies: int, pad_m: int):
    """Banded conv weight-grad: ``gw[t*C + ci, o] = sum_p M[x_tap, g]``.

    The contraction runs over output *pixels* — the rows of the implicit
    im2col GEMM — so the grid streams the same halo'd input-row bands as the
    tiled forward (``n_copies`` row-shifted blocks) plus the matching
    ``(bh, Wo, bn)`` strip of the incoming gradient, and the ``(kh*kw*C, bn)``
    accumulator persists in VMEM across every ``(n, band)`` step (the Cout
    grid dim is outermost so the scratch is coherent per ``j``). Both
    operands are float residuals quantized in-kernel per-tensor *symmetric*
    (zero-point 0), like the dense backward kernel.

    ``rmask`` is an explicit 0/1 input: output rows past ``Ho`` (band
    alignment padding — and, under the mesh wrap, dead band-slab rows)
    contribute ``M[x, 0]`` per product, which is *not* a constant, so they
    are masked multiplicatively before the pixel sum instead of corrected
    after it. Patch rows pad to a ``mc`` multiple with mask 0 the same way.
    Spatial 0.0 padding needs no mask: the im2col oracle's patch tensor
    carries the same quantized-zero codes. The kernel always emits the raw
    int32 accumulator — the planning layer owns the single combined-scale
    dequant (and the mesh route psums these partials over band shards first).
    """
    x_refs = refs[:n_copies]
    (g_ref, rm_ref, lut_ref, xs_ref, gs_ref, o_ref, acc_ref) = refs[n_copies:]
    n_i = pl.program_id(1)
    i = pl.program_id(2)
    first = jnp.logical_and(n_i == 0, i == 0)
    last = jnp.logical_and(n_i == pl.num_programs(1) - 1,
                           i == pl.num_programs(2) - 1)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = xs_ref[0]
    gs = gs_ref[0]
    # re-quantized once per (j; n, band) step — j outermost means each band
    # is revisited per Cout tile, the price of a coherent gw accumulator;
    # the quantizer is deterministic so every visit produces the same codes
    band = jnp.concatenate([r[...][0] for r in x_refs], axis=1)
    a_band = jnp.clip(jnp.round(band.astype(jnp.float32) / xs), lo, hi
                      ).astype(jnp.int32) + offset      # (C, rows, Wp)
    gq = jnp.clip(jnp.round(g_ref[...][0].astype(jnp.float32) / gs), lo, hi
                  ).astype(jnp.int32) + offset          # (bh, wo, bn)
    lut = lut_ref[...]
    c = a_band.shape[0]
    bn = gq.shape[2]
    bm = bh * wo
    g2 = gq.reshape(bm, bn)
    mask = jnp.broadcast_to(rm_ref[...].reshape(bh, 1),
                            (bh, wo)).reshape(bm, 1)    # 0/1 row validity
    if pad_m:  # patch rows up to a mc multiple; padded rows mask to 0
        g2 = jnp.pad(g2, ((0, pad_m), (0, 0)))
        mask = jnp.pad(mask, ((0, pad_m), (0, 0)))
    nm = (bm + pad_m) // mc

    taps = []
    for t in range(kh * kw):                            # static tap loop
        u, v = divmod(t, kw)
        win = jax.lax.dynamic_slice(
            a_band, (0, u * dh, v * dw),
            (c, (bh - 1) * sh + 1, (wo - 1) * sw + 1))
        win = jax.lax.slice(win, (0, 0, 0), win.shape, (1, sh, sw))
        a_t = win.transpose(1, 2, 0).reshape(bm, c)     # (bm, C) patch rows
        if pad_m:
            a_t = jnp.pad(a_t, ((0, pad_m), (0, 0)))

        def body(mi, acc_t, a_t=a_t):
            a_sl = jax.lax.dynamic_slice(a_t, (mi * mc, 0), (mc, c))
            g_sl = jax.lax.dynamic_slice(g2, (mi * mc, 0), (mc, bn))
            m_sl = jax.lax.dynamic_slice(mask, (mi * mc, 0), (mc, 1))
            idx = a_sl[:, :, None] * n_codes + g_sl[:, None, :]  # (mc, C, bn)
            prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                             indices_are_sorted=False).reshape(mc, c, bn)
            return acc_t + (prods * m_sl[:, :, None]).sum(axis=0)

        taps.append(jax.lax.fori_loop(0, nm, body,
                                      jnp.zeros((c, bn), jnp.int32)))

    acc_ref[...] += jnp.concatenate(taps, axis=0)       # (kh*kw*C, bn)

    @pl.when(last)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "offset", "n_codes", "lo", "hi", "mc", "kh", "kw", "sh", "sw", "dh",
    "dw", "bh", "bn", "wo", "ho_pad", "n_copies", "interpret"))
def fused_lut_conv_bwd_w_kernel(xp: jnp.ndarray, g: jnp.ndarray,
                                rmask: jnp.ndarray, lut_flat: jnp.ndarray,
                                x_scale: jnp.ndarray, g_scale: jnp.ndarray, *,
                                offset: int, n_codes: int, lo: int, hi: int,
                                mc: int, kh: int, kw: int, sh: int, sw: int,
                                dh: int, dw: int, bh: int, bn: int, wo: int,
                                ho_pad: int, n_copies: int,
                                interpret: bool | None = None) -> jnp.ndarray:
    """Banded approximate conv weight-grad. ``xp``: (N, C, Hp, Wp) float
    residuals, spatially pre-padded like the tiled forward (rows to
    ``(n_bands + n_copies - 1) * bh * sh``); ``g``: (N, ho_pad, Wo, Cout)
    float incoming gradient; ``rmask``: (N, ho_pad) int32 0/1 output-row
    validity; scales: shape-(1,) f32 per-tensor symmetric. Returns the raw
    (kh*kw*C, Cout) int32 accumulator, tap-major — the full ``(N*Ho*Wo,
    kh*kw*C)`` patch tensor never exists anywhere."""
    n, c, hp, wp = xp.shape
    cout = g.shape[3]
    n_bands = ho_pad // bh
    s_rows = bh * sh
    bm = bh * wo
    assert cout % bn == 0 and ho_pad % bh == 0, (
        f"conv bwd tiling mismatch: Cout={cout}/bn={bn}, "
        f"Ho_pad={ho_pad}/bh={bh}")
    assert hp == (n_bands + n_copies - 1) * s_rows, (
        f"banded row padding mismatch: Hp={hp} != "
        f"({n_bands} + {n_copies} - 1) * {s_rows}")
    grid = (cout // bn, n, n_bands)   # j outermost: acc coherent per j

    def x_spec(k):
        return pl.BlockSpec((1, c, s_rows, wp),
                            lambda j, n, i, k=k: (n, 0, i + k, 0))

    return pl.pallas_call(
        functools.partial(_bwd_w_kernel, offset=offset, n_codes=n_codes,
                          lo=lo, hi=hi, mc=mc, kh=kh, kw=kw, sh=sh, sw=sw,
                          dh=dh, dw=dw, bh=bh, wo=wo, n_copies=n_copies,
                          pad_m=(-bm) % mc),
        grid=grid,
        in_specs=[x_spec(k) for k in range(n_copies)] + [
            pl.BlockSpec((1, bh, wo, bn), lambda j, n, i: (n, i, 0, j)),
            pl.BlockSpec((1, bh), lambda j, n, i: (n, i)),
            pl.BlockSpec((n_codes * n_codes,), lambda j, n, i: (0,)),
            pl.BlockSpec((1,), lambda j, n, i: (0,)),
            pl.BlockSpec((1,), lambda j, n, i: (0,)),
        ],
        out_specs=pl.BlockSpec((kh * kw * c, bn), lambda j, n, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((kh * kw * c, cout), jnp.int32),
        scratch_shapes=[pltpu.VMEM((kh * kw * c, bn), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(*([xp] * n_copies), g, rmask, lut_flat, x_scale, g_scale)


def _tiled_kernel(*refs, offset: int, n_codes: int, lo: int, hi: int,
                  inner: int, kh: int, kw: int, sh: int, sw: int, dh: int,
                  dw: int, bh: int, wo: int, n_copies: int, c_pad_corr: int,
                  emit_acc: bool):
    x_refs = refs[:n_copies]
    w_ref, lut_ref, xs_ref, xz_ref, ws_ref, o_ref, aband_ref = refs[n_copies:]
    j = pl.program_id(2)
    xs = xs_ref[0]
    xz = xz_ref[0]

    @pl.when(j == 0)
    def _quantize_band():
        # once per (n, band): the n_copies row-shifted blocks concatenate to
        # the halo'd band [i*S, (i + n_copies)*S); quantized codes persist in
        # the band scratch across the Cout sub-grid. Halo rows shared with
        # the neighbouring band are re-quantized there — the quantizer is
        # deterministic, so the codes (and the accumulators built from them)
        # are identical either way.
        band = jnp.concatenate([r[...][0] for r in x_refs], axis=1)
        aband_ref[...] = _quantize_codes(band, xs, xz, lo=lo, hi=hi,
                                         offset=offset)

    a_band = aband_ref[...]                         # (C, n_copies*S, Wp)
    w = w_ref[...].astype(jnp.int32) + offset       # (kh*kw, C, bn)
    lut = lut_ref[...]
    bn = w.shape[2]

    # band-local coordinates: the band block already starts at input row
    # i*bh*sh, so every tap offset is static (row0 = 0)
    acc = _acc_taps(a_band, w, lut, n_codes=n_codes, inner=inner, kh=kh,
                    kw=kw, sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, wo=wo,
                    row0=0)

    if c_pad_corr:
        acc = acc - c_pad_corr * lut[offset * n_codes + offset]
    if emit_acc:
        o_ref[...] = acc.reshape(1, bh, wo, bn)
    else:
        out = acc.astype(jnp.float32) * (xs * ws_ref[...])
        o_ref[...] = out.reshape(1, bh, wo, bn)


@functools.partial(jax.jit, static_argnames=(
    "offset", "n_codes", "lo", "hi", "inner", "kh", "kw", "sh", "sw",
    "dh", "dw", "bh", "bn", "wo", "ho_pad", "n_copies", "c_pad_corr",
    "interpret", "emit_acc"))
def fused_lut_conv_tiled_kernel(xp: jnp.ndarray, wq: jnp.ndarray,
                                lut_flat: jnp.ndarray, x_scale: jnp.ndarray,
                                x_zp: jnp.ndarray, w_scale_row: jnp.ndarray,
                                *, offset: int, n_codes: int, lo: int,
                                hi: int, inner: int, kh: int, kw: int,
                                sh: int, sw: int, dh: int, dw: int, bh: int,
                                bn: int, wo: int, ho_pad: int, n_copies: int,
                                c_pad_corr: int = 0, interpret: bool | None = None,
                                emit_acc: bool = False) -> jnp.ndarray:
    """Spatially-tiled variant. Same operand layout as
    :func:`fused_lut_conv_kernel`, but ``xp`` rows must be padded to
    ``(ho_pad // bh + n_copies - 1) * bh * sh`` so the ``n_copies``
    row-shifted input blocks stay in bounds for the last band. Only the
    halo'd band — never the whole image — is VMEM-resident per grid step."""
    n, c, hp, wp = xp.shape
    cout = wq.shape[2]
    n_bands = ho_pad // bh
    s_rows = bh * sh
    assert c % inner == 0 and cout % bn == 0 and ho_pad % bh == 0, (
        f"conv tiling mismatch: C={c}/inner={inner}, Cout={cout}/bn={bn}, "
        f"Ho_pad={ho_pad}/bh={bh}")
    assert hp == (n_bands + n_copies - 1) * s_rows, (
        f"banded row padding mismatch: Hp={hp} != "
        f"({n_bands} + {n_copies} - 1) * {s_rows}")
    grid = (n, n_bands, cout // bn)

    def x_spec(k):
        # block k of the halo stack: rows [(i + k)*S, (i + k + 1)*S)
        return pl.BlockSpec((1, c, s_rows, wp),
                            lambda n, i, j, k=k: (n, 0, i + k, 0))

    return pl.pallas_call(
        functools.partial(_tiled_kernel, offset=offset, n_codes=n_codes,
                          lo=lo, hi=hi, inner=inner, kh=kh, kw=kw, sh=sh,
                          sw=sw, dh=dh, dw=dw, bh=bh, wo=wo,
                          n_copies=n_copies, c_pad_corr=c_pad_corr,
                          emit_acc=emit_acc),
        grid=grid,
        in_specs=[x_spec(k) for k in range(n_copies)] + [
            pl.BlockSpec((kh * kw, c, bn), lambda n, i, j: (0, 0, j)),
            pl.BlockSpec((n_codes * n_codes,), lambda n, i, j: (0,)),
            pl.BlockSpec((1,), lambda n, i, j: (0,)),
            pl.BlockSpec((1,), lambda n, i, j: (0,)),
            pl.BlockSpec((1, bn), lambda n, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bh, wo, bn), lambda n, i, j: (n, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (n, ho_pad, wo, cout), jnp.int32 if emit_acc else jnp.float32),
        scratch_shapes=[pltpu.VMEM((c, n_copies * s_rows, wp), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(*([xp] * n_copies), wq, lut_flat, x_scale, x_zp, w_scale_row)
