"""jit'd public wrapper for the patch-streaming fused conv kernel.

Resolves geometry and padding so every pad stays exact end to end:

* **spatial padding** (explicit per-edge pairs, resolved from SAME/VALID by
  the planning layer) uses 0.0, which the in-kernel quantizer maps to the
  zero-point and hence to shifted code 0 — identical to the 0.0 entries the
  im2col oracle's patch tensor carries, so no correction is needed;
* **row padding** (Ho up to a multiple of the row-strip tile ``bh``) only
  produces output rows that are sliced away; the input is padded tall enough
  that the extra strips read zeros;
* **channel padding** (C up to a multiple of the gather chunk ``inner``)
  feeds shifted code 0 through every tap; the kernel subtracts
  ``pad_c * kh * kw * LUT[off, off]`` from the int32 accumulator *before*
  dequant (integer-space correction, like the dense kernel's K-pad);
* **output-channel padding** (Cout up to a multiple of ``bn``) uses shifted
  code 0 weights and scale 0 — discarded columns.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import fused_lut_conv_kernel


def conv_out_size(size: int, k: int, stride: int, dilation: int,
                  pad: tuple[int, int]) -> int:
    """Output extent of one spatial dim under explicit padding."""
    eff_k = (k - 1) * dilation + 1
    return (size + pad[0] + pad[1] - eff_k) // stride + 1


def pick_conv_tiling(c: int, ho: int, wo: int, cout: int, *,
                     inner: int = 32, bh: int = 0, bn: int = 128
                     ) -> tuple[int, int, int]:
    """The (inner, bh, bn) tile sizes the kernel runs with at this geometry —
    the single source of truth shared by :func:`fused_lut_conv` and the
    planning layer's VMEM estimate (``core.acu._conv_vmem_estimate``), so
    tuning one can never silently diverge from the other."""
    inner = min(inner, c)
    if bh <= 0:  # target ~256 patch rows per strip
        bh = max(1, min(ho, 256 // max(wo, 1)))
    bh = min(bh, ho)
    bn = min(bn, cout)
    return inner, bh, bn


def fused_lut_conv(x: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray,
                   offset: int, x_scale, x_zp, w_scale, *,
                   stride=(1, 1), padding=((0, 0), (0, 0)), dilation=(1, 1),
                   bits: int = 8, inner: int = 32, bh: int = 0, bn: int = 128,
                   interpret: bool = True, emit_acc: bool = False
                   ) -> jnp.ndarray:
    """Fused approximate conv2d forward.

    ``x``: (N, C, H, W) float activations; ``wq``: (Cout, C, kh, kw) shifted
    int weight codes (``code - zero_point``); ``lut`` may be (n_codes,
    n_codes) or flattened; ``x_scale``/``x_zp``: per-tensor activation
    qparams; ``w_scale``: scalar or (Cout,) per-output-channel scale;
    ``padding``: explicit ((ph_lo, ph_hi), (pw_lo, pw_hi)) pairs (resolve
    SAME/VALID in the planning layer). Returns (N, Ho, Wo, Cout) float32,
    bit-exact vs eager im2col + ``fused_lut_dense``. ``bh=0`` auto-picks the
    output-row strip height. ``emit_acc=True`` returns the raw int32
    accumulator (channel padding already corrected) for the
    channel-contraction-sharded route.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    n, c, h, w_in = x.shape
    cout, cin_w, kh, kw = wq.shape
    assert cin_w == c, (cin_w, c)
    sh, sw = stride
    dh, dw = dilation
    (ph0, ph1), (pw0, pw1) = padding
    ho = conv_out_size(h, kh, sh, dh, (ph0, ph1))
    wo = conv_out_size(w_in, kw, sw, dw, (pw0, pw1))
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1

    inner, bh, bn = pick_conv_tiling(c, ho, wo, cout, inner=inner, bh=bh,
                                     bn=bn)
    pad_c = (-c) % inner
    ho_pad = -(-ho // bh) * bh
    pad_n = (-cout) % bn

    # pad the image: conv padding + enough extra rows/cols that every tap of
    # every (padded) output row stays in bounds
    need_h = (ho_pad - 1) * sh + (kh - 1) * dh + 1
    need_w = (wo - 1) * sw + (kw - 1) * dw + 1
    extra_h = max(0, need_h - (h + ph0 + ph1))
    extra_w = max(0, need_w - (w_in + pw0 + pw1))
    xp = jnp.pad(x, ((0, 0), (0, pad_c), (ph0, ph1 + extra_h),
                     (pw0, pw1 + extra_w)))

    # weight codes to tap-major (kh*kw, C_pad, Cout_pad): each tap's (C, bn)
    # slab is a contiguous block for the kernel's per-tap GEMM
    wq_t = wq.transpose(2, 3, 1, 0).reshape(kh * kw, c, cout)
    if pad_c or pad_n:
        wq_t = jnp.pad(wq_t, ((0, 0), (0, pad_c), (0, pad_n)))

    xs = jnp.asarray(x_scale, jnp.float32).reshape(1)
    xz = jnp.asarray(x_zp, jnp.float32).reshape(1)
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32).reshape(1, -1),
                          (1, cout))
    if pad_n:
        ws = jnp.pad(ws, ((0, 0), (0, pad_n)))

    out = fused_lut_conv_kernel(
        xp, wq_t, lut_flat, xs, xz, ws,
        offset=offset, n_codes=n_codes, lo=lo, hi=hi, inner=inner,
        kh=kh, kw=kw, sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, bn=bn, wo=wo,
        ho_pad=ho_pad, c_pad_corr=pad_c * kh * kw, interpret=interpret,
        emit_acc=emit_acc)
    return out[:, :ho, :, :cout]
