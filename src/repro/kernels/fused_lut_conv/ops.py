"""jit'd public wrappers for the patch-streaming fused conv kernels.

Resolves geometry and padding so every pad stays exact end to end:

* **spatial padding** (explicit per-edge pairs, resolved from SAME/VALID by
  the planning layer) uses 0.0, which the in-kernel quantizer maps to the
  zero-point and hence to shifted code 0 — identical to the 0.0 entries the
  im2col oracle's patch tensor carries, so no correction is needed;
* **row padding** (Ho up to a multiple of the row-strip tile ``bh``; for the
  tiled kernel additionally up to the ``n_copies`` halo blocks the last band
  reads) only produces output rows that are sliced away; the input is padded
  tall enough that the extra strips read zeros;
* **channel padding** (C up to a multiple of the gather chunk ``inner``)
  feeds shifted code 0 through every tap; the kernel subtracts
  ``pad_c * kh * kw * LUT[off, off]`` from the int32 accumulator *before*
  dequant (integer-space correction, like the dense kernel's K-pad);
* **output-channel padding** (Cout up to a multiple of ``bn``) uses shifted
  code 0 weights and scale 0 — discarded columns.

This module also owns the **VMEM model**: :func:`conv_vmem_bytes` /
:func:`conv_tiled_vmem_bytes` compute the exact working set of each kernel
at a tiling, from the same padded geometry (:func:`conv_padded_geometry`)
the wrappers allocate — the single source of truth the planning layer
(``core.acu``) budgets against, so the estimate can never silently diverge
from the allocation.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .kernel import (fused_lut_conv_bwd_w_kernel, fused_lut_conv_kernel,
                     fused_lut_conv_tiled_kernel)

# conservative per-core VMEM budget for the fused conv kernels; images whose
# whole-image working set exceeds it take the spatially-tiled kernel (and
# geometries where even a one-row band exceeds it fall back to eager im2col)
CONV_VMEM_BUDGET = 12 << 20

# halo blocks per band the tiled kernel will stream before the planning
# layer calls the geometry degenerate (each copy is a bh*stride-row block;
# >4 means the dilated tap span dwarfs the band itself)
MAX_BAND_COPIES = 4


def conv_out_size(size: int, k: int, stride: int, dilation: int,
                  pad: tuple[int, int]) -> int:
    """Output extent of one spatial dim under explicit padding."""
    eff_k = (k - 1) * dilation + 1
    return (size + pad[0] + pad[1] - eff_k) // stride + 1


def conv_padded_geometry(h: int, w: int, kh: int, kw: int, sh: int, sw: int,
                         dh: int, dw: int,
                         padding: tuple[tuple[int, int], tuple[int, int]],
                         bh: int) -> tuple[int, int, int, int, int]:
    """(ho, wo, ho_pad, hp, wp) at row-strip height ``bh``: the exact padded
    input extents the whole-image kernel allocates — conv padding plus
    enough extra rows/cols that every tap of every (padded-to-``bh``) output
    row stays in bounds, including the ``(kh-1)*dilation`` tap span that a
    stride-only estimate misses."""
    (ph0, ph1), (pw0, pw1) = padding
    ho = conv_out_size(h, kh, sh, dh, (ph0, ph1))
    wo = conv_out_size(w, kw, sw, dw, (pw0, pw1))
    ho_pad = -(-ho // bh) * bh
    need_h = (ho_pad - 1) * sh + (kh - 1) * dh + 1
    need_w = (wo - 1) * sw + (kw - 1) * dw + 1
    hp = max(h + ph0 + ph1, need_h)
    wp = max(w + pw0 + pw1, need_w)
    return ho, wo, ho_pad, hp, wp


def pick_conv_tiling(c: int, ho: int, wo: int, cout: int, *,
                     inner: int = 32, bh: int = 0, bn: int = 128
                     ) -> tuple[int, int, int]:
    """The (inner, bh, bn) tile sizes the whole-image kernel runs with at
    this geometry — the single source of truth shared by
    :func:`fused_lut_conv` and the planning layer's VMEM estimate
    (``core.acu._conv_vmem_estimate``), so tuning one can never silently
    diverge from the other."""
    inner = min(inner, c)
    if bh <= 0:  # target ~256 patch rows per strip
        bh = max(1, min(ho, 256 // max(wo, 1)))
    bh = min(bh, ho)
    bn = min(bn, cout)
    return inner, bh, bn


def _grid_step_bytes(c_pad: int, bh: int, wo: int, sh: int, sw: int,
                     inner: int, bn: int) -> int:
    """Per-grid-step working set shared by both kernels: the tap window
    before/after the strided slice, the gather index/product tensors, and
    the accumulator + output tile."""
    bm = bh * wo
    win_rows = (bh - 1) * sh + 1
    win_cols = (wo - 1) * sw + 1
    return (4 * c_pad * win_rows * win_cols    # pre-stride tap window
            + 4 * bm * c_pad                   # strided a_t operand tile
            + 8 * bm * inner * bn              # gather: idx + prods tensors
            + 8 * bm * bn)                     # acc + out tile


def conv_vmem_bytes(c: int, h: int, w: int, cout: int, kh: int, kw: int,
                    sh: int, sw: int, dh: int, dw: int,
                    padding: tuple[tuple[int, int], tuple[int, int]],
                    n_codes: int, *, inner: int = 32, bh: int = 0,
                    bn: int = 128) -> int:
    """Working-set bytes of the *whole-image* kernel at this geometry, using
    the kernel's own tile picks and the exact padded extents it allocates
    (``conv_padded_geometry`` — including the dilated tap span that the
    pre-PR 4 estimate omitted, which let near-budget dilated convs pick an
    overflowing tile)."""
    ho, wo, _, _, _ = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                           padding, 1)
    inner, bh, bn = pick_conv_tiling(c, ho, wo, cout, inner=inner, bh=bh,
                                     bn=bn)
    _, _, _, hp, wp = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                           padding, bh)
    c_pad = c + (-c) % inner
    return (8 * c_pad * hp * wp                # f32 image block + i32 scratch
            + 4 * n_codes * n_codes            # LUT
            + 4 * kh * kw * c_pad * bn         # tap-major weight codes
            + _grid_step_bytes(c_pad, bh, wo, sh, sw, inner, bn))


def band_copies(bh: int, kh: int, sh: int, dh: int) -> int:
    """Halo blocks per band: a band needs ``(bh-1)*sh + (kh-1)*dh + 1``
    input rows; the tiled kernel streams them as ``n_copies`` row-shifted
    blocks of ``bh*sh`` rows each."""
    s_rows = bh * sh
    need = (bh - 1) * sh + (kh - 1) * dh + 1
    return -(-need // s_rows)


def conv_tiled_vmem_bytes(c: int, h: int, w: int, cout: int, kh: int,
                          kw: int, sh: int, sw: int, dh: int, dw: int,
                          padding: tuple[tuple[int, int], tuple[int, int]],
                          n_codes: int, *, inner: int, bh: int, bn: int
                          ) -> int:
    """Working-set bytes of the *tiled* kernel at band height ``bh``: only
    the ``n_copies`` halo blocks are resident, never the whole image."""
    ho, wo, _, _, wp = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                            padding, bh)
    c_pad = c + (-c) % inner
    rows = band_copies(bh, kh, sh, dh) * bh * sh
    return (8 * c_pad * rows * wp              # f32 halo blocks + i32 scratch
            + 4 * n_codes * n_codes            # LUT
            + 4 * kh * kw * c_pad * bn         # tap-major weight codes
            + _grid_step_bytes(c_pad, bh, wo, sh, sw, inner, bn))


def pick_conv_spatial_tiling(c: int, h: int, w: int, cout: int, kh: int,
                             kw: int, sh: int, sw: int, dh: int, dw: int,
                             padding: tuple[tuple[int, int], tuple[int, int]],
                             n_codes: int, *,
                             budget: int = CONV_VMEM_BUDGET,
                             inner: int = 32, bn: int = 128
                             ) -> Optional[tuple[int, int, int, int]]:
    """Choose (inner, bh, bn, n_copies) for the spatially-tiled kernel from
    the VMEM model: the tallest output-row band whose halo'd working set
    fits ``budget`` (taller bands = fewer grid steps and less halo
    re-streaming). Returns ``None`` when the geometry is degenerate — even a
    one-row band exceeds the budget (image too wide / too many channels) or
    the dilated tap span needs more than :data:`MAX_BAND_COPIES` halo blocks
    at every feasible band height — in which case the planning layer keeps
    the audited eager-im2col fallback."""
    ho, wo, _, _, _ = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                           padding, 1)
    inner = min(inner, c)
    bn = min(bn, cout)
    for bh in range(min(ho, 64), 0, -1):
        n_copies = band_copies(bh, kh, sh, dh)
        if n_copies > MAX_BAND_COPIES:
            continue
        if conv_tiled_vmem_bytes(c, h, w, cout, kh, kw, sh, sw, dh, dw,
                                 padding, n_codes, inner=inner, bh=bh,
                                 bn=bn) <= budget:
            return inner, bh, bn, n_copies
    return None


def conv_bwd_w_vmem_bytes(c: int, h: int, w: int, cout: int, kh: int,
                          kw: int, sh: int, sw: int, dh: int, dw: int,
                          padding: tuple[tuple[int, int], tuple[int, int]],
                          n_codes: int, *, bh: int, bn: int, mc: int
                          ) -> int:
    """Working-set bytes of the banded weight-grad kernel at band height
    ``bh``: the halo'd input band (float + quantized codes), the gradient
    strip, the persistent ``(kh*kw*C, bn)`` accumulator, and the per-tap /
    per-chunk gather tensors. The contraction over output pixels streams in
    ``mc``-row chunks, so nothing grows with ``Ho`` except the grid."""
    ho, wo, _, _, wp = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                            padding, bh)
    rows = band_copies(bh, kh, sh, dh) * bh * sh
    bm = bh * wo
    bm_pad = bm + (-bm) % mc
    win_rows = (bh - 1) * sh + 1
    win_cols = (wo - 1) * sw + 1
    return (8 * c * rows * wp              # f32 halo blocks + code band
            + 4 * n_codes * n_codes        # LUT
            + 8 * bh * wo * bn             # f32 gradient strip + codes
            + 8 * kh * kw * c * bn         # acc scratch + step contribution
            + 4 * c * win_rows * win_cols  # pre-stride tap window
            + 4 * bm_pad * c               # strided a_t patch-row tile
            + 8 * mc * c * bn)             # gather: idx + prods chunk


def pick_conv_bwd_tiling(c: int, h: int, w: int, cout: int, kh: int,
                         kw: int, sh: int, sw: int, dh: int, dw: int,
                         padding: tuple[tuple[int, int], tuple[int, int]],
                         n_codes: int, *, budget: int = CONV_VMEM_BUDGET,
                         bn: int = 128, mc: int = 8
                         ) -> Optional[tuple[int, int, int, int]]:
    """Choose (bh, bn, mc, n_copies) for the banded weight-grad kernel from
    its VMEM model — the tallest band under ``budget``, mirroring
    :func:`pick_conv_spatial_tiling`. Returns ``None`` on degenerate
    geometry (even a one-row band over budget), in which case the planning
    layer keeps the materialized-im2col approximate backward."""
    ho, _, _, _, _ = conv_padded_geometry(h, w, kh, kw, sh, sw, dh, dw,
                                          padding, 1)
    bn = min(bn, cout)
    for bh in range(min(ho, 64), 0, -1):
        n_copies = band_copies(bh, kh, sh, dh)
        if n_copies > MAX_BAND_COPIES:
            continue
        if conv_bwd_w_vmem_bytes(c, h, w, cout, kh, kw, sh, sw, dh, dw,
                                 padding, n_codes, bh=bh, bn=bn,
                                 mc=mc) <= budget:
            return bh, bn, mc, n_copies
    return None


def _conv_operands(x, wq, x_scale, x_zp, w_scale, *, inner, bn,
                   hp_rows, padding, bits):
    """Shared operand prep: pad the image to exactly ``hp_rows`` x ``wp``
    (conv padding + tile alignment; rows past ``hp_rows`` are never read by
    any tap and are sliced off), rearrange weight codes tap-major, pad
    channels/output-channels, broadcast the scales."""
    n, c, h, w_in = x.shape
    cout, cin_w, kh, kw = wq.shape
    assert cin_w == c, (cin_w, c)
    (ph0, ph1), (pw0, pw1) = padding
    pad_c = (-c) % inner
    pad_n = (-cout) % bn

    xp = jnp.pad(x, ((0, 0), (0, pad_c), (ph0, ph1), (pw0, pw1)))
    if xp.shape[2] < hp_rows:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, hp_rows - xp.shape[2]), (0, 0)))
    else:
        xp = xp[:, :, :hp_rows, :]

    # weight codes to tap-major (kh*kw, C_pad, Cout_pad): each tap's (C, bn)
    # slab is a contiguous block for the kernel's per-tap GEMM
    wq_t = wq.transpose(2, 3, 1, 0).reshape(kh * kw, c, cout)
    if pad_c or pad_n:
        wq_t = jnp.pad(wq_t, ((0, 0), (0, pad_c), (0, pad_n)))

    xs = jnp.asarray(x_scale, jnp.float32).reshape(1)
    xz = jnp.asarray(x_zp, jnp.float32).reshape(1)
    ws = jnp.broadcast_to(jnp.asarray(w_scale, jnp.float32).reshape(1, -1),
                          (1, cout))
    if pad_n:
        ws = jnp.pad(ws, ((0, 0), (0, pad_n)))
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    return xp, wq_t, xs, xz, ws, pad_c, lo, hi


def fused_lut_conv(x: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray,
                   offset: int, x_scale, x_zp, w_scale, *,
                   stride=(1, 1), padding=((0, 0), (0, 0)), dilation=(1, 1),
                   bits: int = 8, inner: int = 32, bh: int = 0, bn: int = 128,
                   interpret: bool | None = None, emit_acc: bool = False
                   ) -> jnp.ndarray:
    """Fused approximate conv2d forward (whole-image kernel).

    ``x``: (N, C, H, W) float activations; ``wq``: (Cout, C, kh, kw) shifted
    int weight codes (``code - zero_point``); ``lut`` may be (n_codes,
    n_codes) or flattened; ``x_scale``/``x_zp``: per-tensor activation
    qparams; ``w_scale``: scalar or (Cout,) per-output-channel scale;
    ``padding``: explicit ((ph_lo, ph_hi), (pw_lo, pw_hi)) pairs (resolve
    SAME/VALID in the planning layer). Returns (N, Ho, Wo, Cout) float32,
    bit-exact vs eager im2col + ``fused_lut_dense``. ``bh=0`` auto-picks the
    output-row strip height. ``emit_acc=True`` returns the raw int32
    accumulator (channel padding already corrected) for the
    channel-contraction-sharded route.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    n, c, h, w_in = x.shape
    cout, _, kh, kw = wq.shape
    sh, sw = stride
    dh, dw = dilation
    ho, wo, _, _, _ = conv_padded_geometry(h, w_in, kh, kw, sh, sw, dh, dw,
                                           padding, 1)
    inner, bh, bn = pick_conv_tiling(c, ho, wo, cout, inner=inner, bh=bh,
                                     bn=bn)
    _, _, ho_pad, hp, wp = conv_padded_geometry(h, w_in, kh, kw, sh, sw, dh,
                                                dw, padding, bh)
    xp, wq_t, xs, xz, ws, pad_c, lo, hi = _conv_operands(
        x, wq, x_scale, x_zp, w_scale, inner=inner, bn=bn,
        hp_rows=hp, padding=padding, bits=bits)
    if xp.shape[3] < wp:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, wp - xp.shape[3])))

    out = fused_lut_conv_kernel(
        xp, wq_t, lut_flat, xs, xz, ws,
        offset=offset, n_codes=n_codes, lo=lo, hi=hi, inner=inner,
        kh=kh, kw=kw, sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, bn=bn, wo=wo,
        ho_pad=ho_pad, c_pad_corr=pad_c * kh * kw, interpret=interpret,
        emit_acc=emit_acc)
    return out[:, :ho, :, :cout]


def fused_lut_conv_tiled(x: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray,
                         offset: int, x_scale, x_zp, w_scale, *,
                         stride=(1, 1), padding=((0, 0), (0, 0)),
                         dilation=(1, 1), bits: int = 8, inner: int = 0,
                         bh: int = 0, bn: int = 0,
                         budget: int = CONV_VMEM_BUDGET,
                         interpret: bool | None = None, emit_acc: bool = False
                         ) -> jnp.ndarray:
    """Fused approximate conv2d forward, spatially tiled over output-row
    bands — same contract and operand layout as :func:`fused_lut_conv`, but
    only the ``bh*stride + (kh-1)*dilation`` halo'd input rows of one band
    are VMEM-resident per grid step, so ImageNet-scale (224^2) feature maps
    run fused instead of falling back to eager im2col.

    ``bh=0`` picks the band height from the VMEM model
    (:func:`pick_conv_spatial_tiling`; raises ``ValueError`` on degenerate
    geometry); an explicit ``bh`` pins it (tests sweep tilings — every
    choice is bit-identical, tiling only moves work between grid steps).
    Bit-exact vs the whole-image kernel and the eager im2col +
    ``fused_lut_dense`` oracle.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    n, c, h, w_in = x.shape
    cout, _, kh, kw = wq.shape
    sh, sw = stride
    dh, dw = dilation
    if bh <= 0:
        tiling = pick_conv_spatial_tiling(
            c, h, w_in, cout, kh, kw, sh, sw, dh, dw, padding, n_codes,
            budget=budget, inner=inner if inner > 0 else 32,
            bn=bn if bn > 0 else 128)
        if tiling is None:
            raise ValueError(
                f"spatial tiling infeasible: even a one-row band exceeds the "
                f"{budget >> 20} MiB VMEM budget at C={c}, W={w_in}")
        inner, bh, bn, n_copies = tiling
    else:
        inner = min(inner if inner > 0 else 32, c)
        bn = min(bn if bn > 0 else 128, cout)
        n_copies = band_copies(bh, kh, sh, dh)

    ho, wo, ho_pad, _, wp = conv_padded_geometry(h, w_in, kh, kw, sh, sw,
                                                 dh, dw, padding, bh)
    n_bands = ho_pad // bh
    s_rows = bh * sh
    hp_rows = (n_bands + n_copies - 1) * s_rows
    xp, wq_t, xs, xz, ws, pad_c, lo, hi = _conv_operands(
        x, wq, x_scale, x_zp, w_scale, inner=inner, bn=bn,
        hp_rows=hp_rows, padding=padding, bits=bits)
    if xp.shape[3] < wp:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, wp - xp.shape[3])))

    out = fused_lut_conv_tiled_kernel(
        xp, wq_t, lut_flat, xs, xz, ws,
        offset=offset, n_codes=n_codes, lo=lo, hi=hi, inner=inner,
        kh=kh, kw=kw, sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, bn=bn, wo=wo,
        ho_pad=ho_pad, n_copies=n_copies, c_pad_corr=pad_c * kh * kw,
        interpret=interpret, emit_acc=emit_acc)
    return out[:, :ho, :, :cout]


def fused_lut_conv_bwd_w(x: jnp.ndarray, g: jnp.ndarray, lut: jnp.ndarray,
                         offset: int, x_scale, g_scale, *,
                         ksize: tuple[int, int], stride=(1, 1),
                         padding=((0, 0), (0, 0)), dilation=(1, 1),
                         bits: int = 8, bh: int = 0, bn: int = 0, mc: int = 8,
                         budget: int = CONV_VMEM_BUDGET,
                         interpret: bool | None = None,
                         rmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Banded approximate conv weight-grad (ApproxTrain regime).

    ``x``: (N, C, H, W) float residuals (the saved fake-quantized input);
    ``g``: (N, Ho, Wo, Cout) float incoming gradient in the fused forward's
    output layout; scales: per-tensor *symmetric* quantizer scales computed
    by the caller on the full tensors. The kernel streams halo'd input-row
    bands (PR 4's row-shifted BlockSpec machinery) and contracts over output
    pixels in-kernel, so the ``(N*Ho*Wo, kh*kw*C)`` im2col patch tensor
    never exists in HBM. Returns the raw (kh*kw, C, Cout) int32 accumulator,
    tap-major — the planning layer owns the single combined-scale dequant
    ``acc * (sx * sg)`` and the transpose to (Cout, C, kh, kw), and the mesh
    route psums these partials over band shards before either.

    ``bh=0`` picks the band height from the backward VMEM model
    (:func:`pick_conv_bwd_tiling`; raises ``ValueError`` on degenerate
    geometry); an explicit ``bh`` pins it — every choice is bit-identical.
    ``rmask`` overrides the (N, ho_pad) 0/1 output-row validity mask (the
    mesh wrap marks its dead band-slab rows); default marks rows past
    ``Ho`` — band alignment padding — invalid.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    n, c, h, w_in = x.shape
    cout = g.shape[3]
    kh, kw = ksize
    sh, sw = stride
    dh, dw = dilation
    if bh <= 0:
        tiling = pick_conv_bwd_tiling(
            c, h, w_in, cout, kh, kw, sh, sw, dh, dw, padding, n_codes,
            budget=budget, bn=bn if bn > 0 else 128, mc=mc)
        if tiling is None:
            raise ValueError(
                f"bwd banding infeasible: even a one-row band exceeds the "
                f"{budget >> 20} MiB VMEM budget at C={c}, W={w_in}")
        bh, bn, mc, n_copies = tiling
    else:
        bn = min(bn if bn > 0 else 128, cout)
        n_copies = band_copies(bh, kh, sh, dh)

    ho, wo, ho_pad, _, wp = conv_padded_geometry(h, w_in, kh, kw, sh, sw,
                                                 dh, dw, padding, bh)
    n_bands = ho_pad // bh
    s_rows = bh * sh
    hp_rows = (n_bands + n_copies - 1) * s_rows
    (ph0, ph1), (pw0, pw1) = padding

    xp = jnp.pad(x, ((0, 0), (0, 0), (ph0, ph1), (pw0, pw1)))
    if xp.shape[2] < hp_rows:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, hp_rows - xp.shape[2]), (0, 0)))
    else:
        xp = xp[:, :, :hp_rows, :]
    if xp.shape[3] < wp:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, wp - xp.shape[3])))

    pad_n = (-cout) % bn
    g_p = g.astype(jnp.float32)
    if ho_pad > ho or pad_n:   # padded rows masked out; padded couts sliced
        g_p = jnp.pad(g_p, ((0, 0), (0, ho_pad - ho), (0, 0), (0, pad_n)))
    if rmask is None:
        rmask = jnp.ones((n, ho), jnp.int32)
    rmask = rmask.astype(jnp.int32)
    if rmask.shape[1] < ho_pad:   # band-alignment pad rows are never valid
        rmask = jnp.pad(rmask, ((0, 0), (0, ho_pad - rmask.shape[1])))
    xs = jnp.asarray(x_scale, jnp.float32).reshape(1)
    gs = jnp.asarray(g_scale, jnp.float32).reshape(1)
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1

    acc = fused_lut_conv_bwd_w_kernel(
        xp, g_p, rmask, lut_flat, xs, gs,
        offset=offset, n_codes=n_codes, lo=lo, hi=hi, mc=mc, kh=kh, kw=kw,
        sh=sh, sw=sw, dh=dh, dw=dw, bh=bh, bn=bn, wo=wo, ho_pad=ho_pad,
        n_copies=n_copies, interpret=interpret)
    return acc[:, :cout].reshape(kh * kw, c, cout)
