"""Pure-jnp oracle for the low-rank error-corrected GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def err_matmul_ref(a: jnp.ndarray, w: jnp.ndarray, f: jnp.ndarray,
                   g: jnp.ndarray, offset: int) -> jnp.ndarray:
    exact = (a.astype(jnp.int32) @ w.astype(jnp.int32)).astype(jnp.float32)
    fa = jnp.take(f, a.astype(jnp.int32) + offset, axis=0)   # (M, K, r)
    gw = jnp.take(g, w.astype(jnp.int32) + offset, axis=0)   # (K, N, r)
    corr = jnp.einsum("mkr,knr->mn", fa, gw)
    return exact + corr
