"""jit'd public wrapper for the error-corrected GEMM: pads to tile multiples."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import err_matmul_kernel


def err_matmul(a: jnp.ndarray, w: jnp.ndarray, f: jnp.ndarray, g: jnp.ndarray,
               offset: int, *, bm: int = 128, bk: int = 128, bn: int = 128,
               interpret: bool | None = None) -> jnp.ndarray:
    """Exact-int-matmul + low-rank error correction, padded to tile multiples.

    Padding uses code 0; the correction contribution of padded ks is
    ``f[off] . g[off]`` per pad and is subtracted afterwards (the exact term's
    pad contribution is 0 * 0 = 0).
    """
    M, K = a.shape
    _, N = w.shape
    pm = (-M) % min(bm, 128)
    pk = (-K) % min(bk, 128)
    pn = (-N) % min(bn, 128)
    if pm or pk or pn:
        a = jnp.pad(a, ((0, pm), (0, pk)))
        w = jnp.pad(w, ((0, pk), (0, pn)))
    rank = f.shape[1]
    out = err_matmul_kernel(a, w, f, g, offset=offset, rank=rank,
                            bm=bm, bk=bk, bn=bn, interpret=interpret)
    if pk:
        out = out - pk * jnp.dot(f[offset], g[offset])
    return out[:M, :N]
