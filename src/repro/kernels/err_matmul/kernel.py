"""Pallas TPU kernel: low-rank error-corrected approximate GEMM (beyond-paper).

``out = A @ W  +  fA @ gW^T``  where  ``fA[m, (k,r)] = f[a[m,k]+off, r]`` and
``gW[(k,r), n] = g[w[k,n]+off, r]`` — DESIGN.md §3.

The exact term runs on the MXU (int8 x int8 -> int32). The correction term is
two tiny 1-D VMEM gathers (256 x r tables) plus one (bm, bk*r) x (bk*r, bn)
MXU matmul — the 2-D LUT gather of the faithful kernel is gone entirely,
moving emulation from VPU-gather-bound to MXU-bound.

VMEM @ defaults (bm=bn=128, bk=128, r=8): f/g tables 2*256*8*4 = 16 KiB,
fA tile 128*1024*4 = 512 KiB, gW tile 512 KiB, operand/acc tiles < 200 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(a_ref, w_ref, f_ref, g_ref, o_ref, *, offset: int, rank: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                                  # (bm, bk) int8/int32 codes
    w = w_ref[...]                                  # (bk, bn)
    bm, bk = a.shape
    bn = w.shape[1]

    # exact MXU term
    exact = jnp.dot(a.astype(jnp.int8), w.astype(jnp.int8),
                    preferred_element_type=jnp.int32).astype(jnp.float32)

    # low-rank error correction: 1-D gathers + MXU matmul
    f = f_ref[...]                                  # (n_codes, r) f32
    g = g_ref[...]                                  # (n_codes, r) f32
    fa = jnp.take(f, a.astype(jnp.int32).reshape(-1) + offset, axis=0)
    fa = fa.reshape(bm, bk * rank)                  # (bm, bk*r)
    gw = jnp.take(g, w.astype(jnp.int32).reshape(-1) + offset, axis=0)
    gw = gw.reshape(bk, bn, rank).transpose(0, 2, 1).reshape(bk * rank, bn)
    corr = jnp.dot(fa, gw, preferred_element_type=jnp.float32)

    o_ref[...] += exact + corr


@functools.partial(jax.jit, static_argnames=("offset", "rank", "bm", "bk",
                                             "bn", "interpret"))
def err_matmul_kernel(a: jnp.ndarray, w: jnp.ndarray, f: jnp.ndarray,
                      g: jnp.ndarray, *, offset: int, rank: int,
                      bm: int = 128, bk: int = 128, bn: int = 128,
                      interpret: bool | None = None) -> jnp.ndarray:
    M, K = a.shape
    _, N = w.shape
    n_codes = f.shape[0]
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, offset=offset, rank=rank),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_codes, rank), lambda i, j, k: (0, 0)),
            pl.BlockSpec((n_codes, rank), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(a, w, f, g)
