"""Pallas TPU kernel: ragged grouped fused LUT-GEMM for MoE expert dispatch.

ONE ``pallas_call`` runs all E expert GEMMs of an MoE layer. The input is the
dispatched capacity buffer flattened to ``(G * Cp, K)`` rows, where each of
the ``G`` groups (``G = nb * E`` dispatch blocks x experts) owns a contiguous
strip of ``Cp`` padded capacity rows and multiplies against the weights of
expert ``g % E``. The grid walks ``(group, row-block, n-block, k-block)`` and
a per-group ``groupinfo = [row_base, row_count]`` operand — the same pattern
as flash-attention's per-row ``rowinfo`` extents — tells the kernel how many
of each group's capacity rows actually hold routed tokens, so row-blocks past
the live count skip the quantize + LUT-gather work entirely instead of
grinding through dead padded slots. That skip is the whole point: a capacity
buffer at ``moe_capacity`` 1.25+ with realistic (skewed) routing is mostly
dead rows.

Inside a live block the body is the established fused recipe, verbatim from
``fused_lut_dense``: per-tensor in-kernel activation quantization, shifted
code LUT gathers in ``inner``-row sub-slices, int32 accumulate into a
persistent VMEM scratch tile, integer-space K-pad correction, and ONE
combined-scale dequant (``acc * (xs * ws)``) on the final K step. int32 adds
are associative and the k-chunk order matches the dense kernel's, so each
live row is bit-identical to the per-expert ``fused_lut_dense`` call.

Dead rows (``row >= row_count``) write exactly 0.0. This is a deliberate
contract, not just hygiene: a zero *input* row still produces
``sum_k LUT[off, wq + off] != 0`` under biased-M00 multipliers (masking is
not slicing — same lesson as the attention kernel's masked-key semantics),
and the combine step downstream must be able to rely on dead slots
contributing nothing.

``emit_acc=True`` (the mesh contraction-sharded route) returns the raw int32
accumulator with dead rows zeroed in integer space; the sharded wrapper psums
partials across K shards, applies the mesh-level pad correction, dequantizes
once, and re-masks (the uniform correction un-zeroes dead rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret


def _kernel(x_ref, w_ref, lut_ref, xs_ref, xz_ref, ws_ref, info_ref,
            o_ref, acc_ref, *, offset: int, n_codes: int, lo: int, hi: int,
            inner: int, k_pad: int, emit_acc: bool):
    m_step = pl.program_id(1)
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bm = acc_ref.shape[0]
    count = info_ref[0, 1]                 # live rows in this group
    live = count - m_step * bm             # live rows at/after this row-block

    @pl.when(live > 0)
    def _accumulate():
        # fused_lut_dense recipe verbatim — only executed for row-blocks that
        # intersect the group's live rows; dead blocks skip straight past the
        # quantize + gather work (the ragged-dispatch win)
        xs = xs_ref[0]                             # per-tensor activation scale
        xz = xz_ref[0]                             # activation zero-point (code)
        x = x_ref[...].astype(jnp.float32)         # (bm, bk)
        q = jnp.clip(jnp.round(x / xs + xz), lo, hi).astype(jnp.int32)
        a = q - xz.astype(jnp.int32) + offset      # shifted code, index space
        w = w_ref[0].astype(jnp.int32) + offset    # (bk, bn): expert g % E
        lut = lut_ref[...]                         # (n_codes * n_codes,)
        bm_, bk = a.shape
        bn = w.shape[1]

        def body(i, acc):
            a_sl = jax.lax.dynamic_slice(a, (0, i * inner), (bm_, inner))
            w_sl = jax.lax.dynamic_slice(w, (i * inner, 0), (inner, bn))
            idx = a_sl[:, :, None] * n_codes + w_sl[None, :, :]
            prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                             indices_are_sorted=False).reshape(bm_, inner, bn)
            return acc + prods.sum(axis=1)

        acc_ref[...] += jax.lax.fori_loop(0, bk // inner, body,
                                          jnp.zeros((bm_, bn), jnp.int32))

    @pl.when(k_step == pl.num_programs(3) - 1)
    def _dequant():
        acc = acc_ref[...]
        if k_pad:  # padded k entries each contributed LUT[off, off] = M[0, 0]
            # applied unconditionally: dead row-blocks never accumulated, so
            # their value here is garbage either way — the row mask below is
            # what guarantees they emit exactly zero
            acc = acc - k_pad * lut_ref[offset * n_codes + offset]
        row = m_step * bm + jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0)
        if emit_acc:
            # contraction sharding: masked int32 partials leave the kernel;
            # the wrapper psums across K shards and dequantizes after
            o_ref[...] = jnp.where(row < count, acc, 0)
        else:
            # one combined-scale multiply, same association as
            # fused_lut_dense so live rows stay bitwise identical to the
            # per-expert route; dead rows write exactly 0.0
            xs = xs_ref[0]
            o_ref[...] = jnp.where(
                row < count, acc.astype(jnp.float32) * (xs * ws_ref[0]), 0.0)


@functools.partial(jax.jit, static_argnames=("offset", "n_codes", "lo", "hi",
                                             "k_pad", "cp", "bm", "bk", "bn",
                                             "inner", "interpret", "emit_acc"))
def fused_lut_grouped_kernel(x: jnp.ndarray, wq: jnp.ndarray,
                             lut_flat: jnp.ndarray, x_scale: jnp.ndarray,
                             x_zp: jnp.ndarray, w_scale: jnp.ndarray,
                             info: jnp.ndarray, *, offset: int, n_codes: int,
                             lo: int, hi: int, cp: int, k_pad: int = 0,
                             bm: int = 128, bk: int = 128, bn: int = 128,
                             inner: int = 32, interpret: bool | None = None,
                             emit_acc: bool = False) -> jnp.ndarray:
    """x: (G * cp, K) float rows, group g owning rows [g*cp, (g+1)*cp);
    wq: (E, K, N) shifted int weight codes (group g uses expert g % E);
    lut_flat: (n_codes**2,) int32; x_scale/x_zp: shape-(1,) f32;
    w_scale: (E, 1, N) f32; info: (G, 2) int32 ``[row_base, row_count]``.
    Returns (G * cp, N) float32 with rows >= row_count exactly 0.0 — or the
    raw int32 accumulator (dead rows zeroed) with ``emit_acc=True``."""
    Gm, K = x.shape
    E, _, N = wq.shape
    G = Gm // cp
    bm, bk, bn = min(bm, cp), min(bk, K), min(bn, N)
    inner = min(inner, bk)
    assert Gm == G * cp and G % E == 0, (Gm, cp, E)
    assert cp % bm == 0 and K % bk == 0 and N % bn == 0 and bk % inner == 0, (
        f"shape {(cp, K, N)} not divisible by tile {(bm, bk, bn)}/{inner}")
    mblocks = cp // bm
    grid = (G, mblocks, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, offset=offset, n_codes=n_codes, lo=lo,
                          hi=hi, inner=inner, k_pad=k_pad, emit_acc=emit_acc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda g, m, n, k: (g * mblocks + m, k)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g % E, k, n)),
            pl.BlockSpec((n_codes * n_codes,), lambda g, m, n, k: (0,)),
            pl.BlockSpec((1,), lambda g, m, n, k: (0,)),
            pl.BlockSpec((1,), lambda g, m, n, k: (0,)),
            pl.BlockSpec((1, 1, bn), lambda g, m, n, k: (g % E, 0, n)),
            pl.BlockSpec((1, 2), lambda g, m, n, k: (g, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda g, m, n, k: (g * mblocks + m, n)),
        out_shape=jax.ShapeDtypeStruct((Gm, N),
                                       jnp.int32 if emit_acc else jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(x, wq, lut_flat, x_scale, x_zp, w_scale, info)
