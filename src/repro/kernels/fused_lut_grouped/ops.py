"""jit'd public wrapper for the ragged grouped fused LUT-GEMM.

Pads capacity / K / N to tile multiples with the same exact-padding
discipline as ``fused_lut_dense`` (zero activation rows quantize to the
zero-point -> shifted code 0 -> ``LUT[off, off]`` per padded k, corrected in
integer space), builds the per-group ``groupinfo = [row_base, row_count]``
operand, and slices the padded output back to ``(G, C, N)``.

The row-block tile shrinks to the smallest multiple of 8 covering the
capacity when ``C < 128`` — MoE capacity buffers are often much shorter than
a dense GEMM's M, and a 128-row tile over a 24-row capacity would throw away
the ragged skip granularity entirely.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import fused_lut_grouped_kernel


def fused_lut_grouped(x: jnp.ndarray, wq: jnp.ndarray, lut: jnp.ndarray,
                      offset: int, x_scale, x_zp, w_scale,
                      counts: jnp.ndarray, *, bits: int = 8, bm: int = 128,
                      bk: int = 256, bn: int = 128, inner: int = 32,
                      interpret: bool | None = None,
                      emit_acc: bool = False) -> jnp.ndarray:
    """Ragged grouped approximate GEMM over MoE capacity buffers.

    ``x``: (G, C, K) float dispatched activations — G groups of C capacity
    rows; group ``g`` multiplies against expert ``g % E``. ``wq``: (E, K, N)
    shifted int weight codes; ``lut`` may be (n_codes, n_codes) or flattened;
    ``x_scale``/``x_zp``: per-tensor activation qparams SHARED by all groups
    (the caller pins one scale over the whole dispatched tensor so grouped ==
    per-expert-vmap bitwise); ``w_scale``: (E,) or (E, N) per-expert weight
    scales; ``counts``: (G,) int — live rows per group; row-blocks past a
    group's count are skipped in-kernel.

    Returns (G, C, N) float32 with rows ``>= counts[g]`` exactly 0.0, each
    live row bit-exact vs the per-expert ``fused_lut_dense`` call. With
    ``emit_acc=True`` returns the raw (G, C, N) int32 accumulator (dead rows
    zeroed; tile padding corrected in integer space) for the mesh
    contraction-sharded route.
    """
    n_codes = int(round(lut.size ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    G, C, K = x.shape
    E, _, N = wq.shape
    assert G % E == 0, f"groups {G} not a multiple of experts {E}"
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    xs = jnp.asarray(x_scale, jnp.float32).reshape(1)
    xz = jnp.asarray(x_zp, jnp.float32).reshape(1)
    ws = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(E, 1, -1), (E, 1, N))
    bm, bn = min(bm, 128), min(bn, 128)
    if C < bm:  # keep skip granularity on short capacity buffers
        bm = max(8, -(-C // 8) * 8)
    pc = (-C) % bm
    pk = (-K) % 128
    pn = (-N) % min(bn, 128)
    if pc or pk:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pk)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, 0), (0, pk), (0, pn)))
        ws = jnp.pad(ws, ((0, 0), (0, 0), (0, pn)))
    cp = C + pc
    kp = K + pk
    # single K grid step when the whole row strip fits VMEM comfortably;
    # otherwise a k-tile that divides the (128-multiple) padded K
    bk = kp if kp <= 512 else (bk if kp % bk == 0 else 128)
    info = jnp.stack(
        [jnp.arange(G, dtype=jnp.int32) * cp,
         jnp.clip(counts.astype(jnp.int32), 0, C)], axis=1)
    out = fused_lut_grouped_kernel(
        x.reshape(G * cp, kp), wq, lut_flat, xs, xz, ws, info,
        offset=offset, n_codes=n_codes, lo=lo, hi=hi, k_pad=pk, cp=cp,
        bm=bm, bk=bk, bn=bn, inner=inner, interpret=interpret,
        emit_acc=emit_acc)
    return out.reshape(G, cp, N + pn)[:, :C, :N]
