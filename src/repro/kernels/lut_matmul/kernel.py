"""Pallas TPU kernel: LUT-gather GEMM (paper §4, TPU adaptation).

``out[m, n] = sum_k LUT[a[m, k] + off, w[k, n] + off]``

The (2^b, 2^b) product table is pinned in VMEM for the whole grid (BlockSpec
maps every grid step to the same full-table block — the Mosaic pipeline keeps
it resident, the TPU analogue of AdaPT "populating the CPU cache with the
LUTs"). Each (bm, bk) x (bk, bn) tile performs vectorized VPU gathers —
the AVX2 ``vgather`` role — and accumulates into an (bm, bn) VMEM tile.

VMEM budget @ defaults (bm=bk=bn=128, 8-bit): LUT 256 KiB + idx/prod tile
(128*128*128 int32 would blow VMEM, so the bk dimension is processed in
sub-slices of ``inner`` rows) — inner=8 keeps the gather working set at
128*8*128*4 B = 512 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(a_ref, w_ref, lut_ref, o_ref, *, offset: int, n_codes: int,
            inner: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32) + offset      # (bm, bk)
    w = w_ref[...].astype(jnp.int32) + offset      # (bk, bn)
    lut = lut_ref[...]                             # (n_codes * n_codes,)
    bm, bk = a.shape
    bn = w.shape[1]

    def body(i, acc):
        a_sl = jax.lax.dynamic_slice(a, (0, i * inner), (bm, inner))
        w_sl = jax.lax.dynamic_slice(w, (i * inner, 0), (inner, bn))
        idx = a_sl[:, :, None] * n_codes + w_sl[None, :, :]   # (bm, inner, bn)
        prods = jnp.take(lut, idx.reshape(-1), unique_indices=False,
                         indices_are_sorted=False).reshape(bm, inner, bn)
        return acc + prods.sum(axis=1)

    acc = jax.lax.fori_loop(0, bk // inner, body,
                            jnp.zeros((bm, bn), jnp.int32))
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("offset", "n_codes", "bm", "bk",
                                             "bn", "inner", "interpret"))
def lut_matmul_kernel(a: jnp.ndarray, w: jnp.ndarray, lut_flat: jnp.ndarray,
                      *, offset: int, n_codes: int, bm: int = 128,
                      bk: int = 128, bn: int = 128, inner: int = 8,
                      interpret: bool | None = None) -> jnp.ndarray:
    """a: (M, K) int, w: (K, N) int (signed codes); lut_flat: (n_codes**2,)."""
    M, K = a.shape
    _, N = w.shape
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    inner = min(inner, bk)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % inner == 0, (
        f"shape {(M, K, N)} not divisible by tile {(bm, bk, bn)}/{inner}")
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, offset=offset, n_codes=n_codes, inner=inner),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_codes * n_codes,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(a, w, lut_flat)
