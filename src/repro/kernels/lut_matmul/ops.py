"""jit'd public wrapper for the LUT GEMM kernel: pads to tile multiples."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import lut_matmul_kernel


def _pick_tile(dim: int, pref: int) -> int:
    for t in (pref, 64, 32, 16, 8, 4, 2, 1):
        if t <= pref and dim % t == 0:
            return t
    return 1


def lut_matmul(a: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, offset: int,
               *, bm: int = 128, bk: int = 128, bn: int = 128,
               interpret: bool | None = None) -> jnp.ndarray:
    """LUT-gather GEMM with automatic tile selection / zero-padding.

    ``lut`` may be (n_codes, n_codes) or flattened. Padding uses code 0, whose
    LUT row/col contributes ``LUT[off, off]`` per padded k — subtracted after.
    """
    n_codes = int(round(len(lut.reshape(-1)) ** 0.5)) if lut.ndim == 1 else lut.shape[0]
    lut_flat = lut.reshape(-1)
    M, K = a.shape
    _, N = w.shape
    # pad every dim up to a multiple of its preferred tile
    pm = (-M) % min(bm, 128)
    pk = (-K) % min(bk, 128)
    pn = (-N) % min(bn, 128)
    if pm or pk or pn:
        a = jnp.pad(a, ((0, pm), (0, pk)))
        w = jnp.pad(w, ((0, pk), (0, pn)))
    out = lut_matmul_kernel(a, w, lut_flat, offset=offset, n_codes=n_codes,
                            bm=bm, bk=bk, bn=bn, interpret=interpret)
    if pk:
        zz = lut_flat[offset * n_codes + offset].astype(jnp.int32)
        out = out - pk * zz
    return out[:M, :N]
