"""Pure-jnp oracle for the LUT-gather GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def lut_matmul_ref(a: jnp.ndarray, w: jnp.ndarray, lut_flat: jnp.ndarray,
                   offset: int, n_codes: int) -> jnp.ndarray:
    """out[m, n] = sum_k LUT[a[m,k]+off, w[k,n]+off] — direct gather, O(MKN) mem."""
    ai = a.astype(jnp.int32) + offset
    wi = w.astype(jnp.int32) + offset
    idx = ai[:, :, None] * n_codes + wi[None, :, :]
    prods = jnp.take(lut_flat, idx.reshape(-1)).reshape(idx.shape)
    return prods.sum(axis=1).astype(jnp.int32)
