"""Pallas TPU kernel: RWKV-6 WKV recurrence (data-dependent decay).

    out_t = r_t · (S + u ⊙ (k_tᵀ v_t));   S ← diag(w_t) S + k_tᵀ v_t

Grid is (B*H,); each step holds the (hd, hd) state in VMEM scratch and walks
the time axis with `fori_loop` — the sequential-scan structure is inherent
(data-dependent decay defeats associative reformulation at full fidelity),
so the kernel's job is keeping the state resident and the per-step math on
the VPU/MXU instead of bouncing (B,H,hd,hd) through HBM every step, which is
what the pure-jnp `lax.scan` does.

VMEM @ defaults (hd=64, T-block=256): r/k/v/w tiles 4*256*64*4 = 256 KiB,
state 16 KiB, out tile 64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref, *,
            seq: int):
    r = r_ref[...][0]        # (T, hd)
    k = k_ref[...][0]
    v = v_ref[...][0]
    w = w_ref[...][0]
    u = u_ref[...][0]        # (hd,)
    hd = r.shape[-1]

    def step(t, carry):
        s = carry            # (hd, hd)
        kt = jax.lax.dynamic_slice(k, (t, 0), (1, hd))[0]
        vt = jax.lax.dynamic_slice(v, (t, 0), (1, hd))[0]
        rt = jax.lax.dynamic_slice(r, (t, 0), (1, hd))[0]
        wt = jax.lax.dynamic_slice(w, (t, 0), (1, hd))[0]
        kv = kt[:, None] * vt[None, :]                   # (hd, hd)
        out = rt @ (s + u[:, None] * kv)                 # (hd,)
        # all-Slice index: integer dim indices break interpret-mode discharge
        pl.store(o_ref, (slice(None), pl.dslice(t, 1), slice(None)),
                 out[None, None, :])
        return wt[:, None] * s + kv

    s = jax.lax.fori_loop(0, seq, step, s0_ref[...][0])
    sT_ref[...] = s[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_kernel(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
               u: jnp.ndarray, s0: jnp.ndarray, *,
               interpret: bool | None = None):
    """r/k/v/w: (BH, T, hd) f32 with heads folded h-major (BH = B*H, row
    b*H + h); u: (H, hd) per-head bonus; s0: (BH, hd, hd).

    Returns (out (BH, T, hd), sT (BH, hd, hd)).
    """
    bh, t, hd = r.shape
    grid = (bh,)
    io_spec = pl.BlockSpec((1, t, hd), lambda b: (b, 0, 0))
    st_spec = pl.BlockSpec((1, hd, hd), lambda b: (b, 0, 0))
    n_heads = u.shape[0]  # u: (H, hd); grid cell b uses head b % H
    u_spec = pl.BlockSpec((1, hd), lambda b: (b % n_heads, 0))
    return pl.pallas_call(
        functools.partial(_kernel, seq=t),
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec, u_spec, st_spec],
        out_specs=[io_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((bh, t, hd), jnp.float32),
                   jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(r, k, v, w, u, s0)
