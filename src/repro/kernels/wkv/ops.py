"""Public wrapper: (B, T, H, hd) layout -> WKV kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import wkv_kernel


def wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
        u: jnp.ndarray, s0: jnp.ndarray, *, interpret: bool | None = None):
    """r/k/v/w: (B, T, H, hd); u: (H, hd); s0: (B, H, hd, hd).

    Returns (out (B, T, H, hd), sT (B, H, hd, hd)). Heads fold into the grid
    (row b*H + h), so the kernel's per-cell u block is ``u[cell %% H]``.
    """
    b, t, h, hd = r.shape

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, hd).astype(jnp.float32)

    out, sT = wkv_kernel(fold(r), fold(k), fold(v), fold(w),
                         u.astype(jnp.float32),
                         s0.reshape(b * h, hd, hd).astype(jnp.float32),
                         interpret=interpret)
    return (out.reshape(b, h, t, hd).transpose(0, 2, 1, 3),
            sT.reshape(b, h, hd, hd))
