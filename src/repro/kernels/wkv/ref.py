"""Pure-jnp oracle for the WKV-6 recurrence (lax.scan form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
            u: jnp.ndarray, s0: jnp.ndarray):
    """r/k/v/w: (BH, T, hd); u: (hd,); s0: (BH, hd, hd) ->
    (out (BH, T, hd), sT)."""

    def step(s, x):
        rt, kt, vt, wt = x                      # (BH, hd) each
        kv = kt[:, :, None] * vt[:, None, :]    # (BH, hd, hd)
        out = jnp.einsum("bk,bkv->bv", rt, s + u[None, :, None] * kv)
        s = wt[:, :, None] * s + kv
        return s, out

    xs = tuple(a.transpose(1, 0, 2) for a in (r, k, v, w))
    sT, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2), sT
