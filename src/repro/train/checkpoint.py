"""Mesh-agnostic checkpointing: per-leaf ``.npy`` shards + JSON manifest.

* ``save`` is atomic (write to tmp dir, rename) and optionally async (writer
  thread) so the train loop never blocks on storage.
* ``restore`` re-``device_put``s each leaf with whatever sharding the
  *restarted* job provides — checkpoints carry no mesh information, which is
  what makes elastic restart (different pod count / mesh shape) work.
* ``latest_step`` + retention give crash recovery a monotonic restore point.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    """Synchronous atomic save; returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names = []
    for i, (name, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        names.append(name)
    manifest = {"step": step, "leaves": names, "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


class AsyncSaver:
    """Single-slot background writer: a save in flight never blocks training;
    a newer snapshot supersedes a queued older one.

    The pending slot and the drainer-liveness decision share ONE lock:
    ``_drain`` only exits after clearing ``_running`` *while holding the
    lock*, and ``submit`` respawns whenever ``_running`` is false — so a
    submit can never observe a drainer that has already decided to exit but
    still reads as alive (which used to silently drop the newest snapshot).
    ``wait`` re-checks after every join for the same reason: a concurrent
    submit may have spawned a fresh thread while we were joining a stale
    handle.

    ``last_saved_step`` is the newest step whose ``save`` has durably
    completed (None before the first) — the trainer's replay-buffer trim
    point: anything newer than the last *durable* checkpoint may still be
    needed for an exact failure-resume.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: Optional[tuple] = None
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self.last_saved_step: Optional[int] = None

    def submit(self, ckpt_dir: str, step: int, tree, extra=None, keep: int = 3):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        with self._lock:
            self._pending = (ckpt_dir, step, host_tree, extra, keep)
            if not self._running:
                self._running = True
                self._thread = threading.Thread(target=self._drain, daemon=True)
                self._thread.start()

    def _drain(self):
        while True:
            with self._lock:
                if self._pending is None:
                    self._running = False
                    return
                job, self._pending = self._pending, None
            save(job[0], job[1], job[2], extra=job[3], keep=job[4])
            with self._lock:
                self.last_saved_step = job[1]

    def wait(self):
        while True:
            with self._lock:
                t = self._thread
                done = not self._running and self._pending is None
            if t is None or (done and not t.is_alive()):
                return
            t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Load leaves into the structure of ``tree_like``; ``shardings`` may be a
    matching pytree of shardings (elastic restart) or None (host arrays)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, tdef = jax.tree_util.tree_flatten(tree_like)
    n = len(leaves_like)
    assert n == len(manifest["leaves"]), (n, len(manifest["leaves"]))
    arrs = [np.load(os.path.join(d, f"leaf_{i:05d}.npy")) for i in range(n)]
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_flat)]
    else:
        arrs = [jax.numpy.asarray(a) for a in arrs]
    return tdef.unflatten(arrs), manifest


def _retain(ckpt_dir: str, keep: int):
    steps = sorted([d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                    and not d.endswith(".tmp")])
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
