"""Fault-tolerant training loop.

Features (DESIGN.md §5):
* jit'd train step with planner-driven in/out shardings and donated buffers,
* gradient accumulation (microbatching) via ``lax.scan`` over microbatches,
* gradient-noise batch damping (``optim/damping.py``): the effective batch
  grows — by accumulating whole data batches per optimizer step — as the
  measured gradient noise scale rises during QAT recovery; the per-microbatch
  (or per-mesh-shard) gradient norms the loop already computes feed the
  estimator for free,
* an explicit-collective data-parallel path (``TrainerConfig.mesh``): each
  worker grads its batch shard inside ``shard_map``, gradients all-reduce
  through the int8 error-feedback ``compressed_psum`` — whose int32 code
  psum makes the mean bitwise independent of reduction order — and the
  optimizer update runs on the replicated mean,
* periodic async checkpointing; automatic restore-and-continue on failure
  (exceptions from steps — simulating node loss — roll back to the last
  checkpoint; validated by tests/test_fault_tolerance.py). Resume is
  DETERMINISTIC: the manifest records the consumed-batch count (plus the
  damping-schedule state and the dp error-feedback residual), batches drawn
  since the last durable checkpoint replay from a bounded buffer after an
  in-process rollback, and a fresh restart fast-forwards its iterator to the
  recorded count — so a killed-and-resumed run reproduces the uninterrupted
  run exactly,
* step-time watchdog hook (straggler posture),
* QAT mode: the same loop fine-tunes through the approximate forward / exact
  STE backward (paper Fig. 1 flow).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.optim import damping as damping_lib
from repro.optim.adamw import AdamW, SGD
from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    microbatch: int = 0          # 0 = no accumulation (fixed split of a batch)
    max_failures: int = 3
    step_timeout_s: Optional[float] = None   # watchdog (logged, not killed)
    log_every: int = 10
    async_ckpt: bool = True
    # gradient-noise batch damping: when set, each optimizer step consumes
    # ``accum`` whole data batches (the schedule grows accum as gradients
    # denoise); mutually exclusive with a fixed ``microbatch``.
    damping: Optional[damping_lib.DampingConfig] = None
    # explicit-collective data parallelism: the batch shards over ``dp_axes``
    # of ``mesh``; per-worker grads all-reduce via the int8 error-feedback
    # compressed psum (optim/compression.py) whose int32 code sum keeps the
    # mean bitwise reduction-order independent.
    mesh: Optional[object] = None
    dp_axes: tuple[str, ...] = ("data",)


class Trainer:
    """Drives (params, opt_state) through a loss function with recovery."""

    def __init__(self, loss_fn: Callable, optimizer: AdamW | SGD,
                 cfg: TrainerConfig = TrainerConfig(), *,
                 in_shardings=None, donate: bool = True):
        if cfg.damping is not None and cfg.microbatch > 1:
            raise ValueError("damping drives the accumulation factor itself; "
                             "set microbatch=0 when damping is enabled")
        self.loss_fn = loss_fn
        self.opt = optimizer
        self.cfg = cfg
        self.saver = ckpt_lib.AsyncSaver()
        self.history: list[dict] = []
        self._donate = donate
        self._steps: dict[int, Callable] = {}   # jit cache keyed by n_micro
        self._ef_resid = None                   # dp error-feedback residual
        if cfg.mesh is not None:
            import numpy as np
            self._dp_workers = int(np.prod(
                [cfg.mesh.shape[a] for a in cfg.dp_axes]))
        else:
            self._dp_workers = 1

    # ------------------------------------------------------------------
    # step construction (one jit cache entry per accumulation factor)
    # ------------------------------------------------------------------

    def _get_step(self, n_micro: int) -> Callable:
        fn = self._steps.get(n_micro)
        if fn is None:
            fn = (self._build_dp_step(n_micro) if self.cfg.mesh is not None
                  else self._build_step(n_micro))
            self._steps[n_micro] = fn
        return fn

    def _grads_and_stats(self, params, batch, n_micro: int):
        """loss, mean grads, and the scan-accumulated sum of per-microbatch
        |g|^2 (the damping estimator's small-batch side, free in the scan).

        ``batch`` leaves are ``(n_micro, b, ...)`` when ``n_micro > 1``
        (stacked microbatches), flat otherwise. The scan carry is pinned to
        fp32 — a weak-typed ``0.0`` loss accumulator used to let the loss
        dtype leak into the carry.
        """
        loss_fn = self.loss_fn
        if n_micro > 1:
            def micro(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                l0, g0, sq0 = carry
                return (l0 + loss.astype(jnp.float32),
                        jax.tree.map(jnp.add, g0, grads),
                        sq0 + damping_lib.tree_sqnorm(grads)), None
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            carry0 = (jnp.zeros((), jnp.float32), zero,
                      jnp.zeros((), jnp.float32))
            (loss, gsum, sqsum), _ = jax.lax.scan(micro, carry0, batch)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            micro_sqsum = sqsum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            micro_sqsum = damping_lib.tree_sqnorm(grads)
        return loss, grads, micro_sqsum

    def _build_step(self, n_micro: int) -> Callable:
        def step_fn(params, opt_state, batch):
            loss, grads, micro_sqsum = self._grads_and_stats(
                params, batch, n_micro)
            stats = {"micro_sqsum": micro_sqsum,
                     "gsq_big": damping_lib.tree_sqnorm(grads)}
            new_params, new_state = self.opt.update(grads, opt_state, params)
            return new_params, new_state, loss, stats

        donate = (0, 1) if self._donate else ()
        return jax.jit(step_fn, donate_argnums=donate)

    def _build_dp_step(self, n_micro: int) -> Callable:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.optim.compression import EFState, compressed_psum

        cfg = self.cfg
        axes = cfg.dp_axes
        ax = axes if len(axes) > 1 else axes[0]
        p_lead = P(ax)                         # shard leading dim (resid)
        p_batch = P(None, ax) if n_micro > 1 else P(ax)

        def worker(params, resid, batch):
            loss, grads, micro_sqsum = self._grads_and_stats(
                params, batch, n_micro)
            resid = jax.tree.map(lambda r: r[0], resid)
            mean, ef = compressed_psum(grads, EFState(residual=resid), axes)
            new_resid = jax.tree.map(lambda r: r[None], ef.residual)
            loss = jax.lax.pmean(loss, axes)
            # per-worker scalars leave SHARDED: the host folds them in a
            # fixed order (fp64), so the damping schedule never depends on
            # the collective's float reduction order
            one = lambda x: jnp.reshape(x, (1,))
            return (mean, new_resid, loss,
                    one(damping_lib.tree_sqnorm(grads)),
                    one(damping_lib.tree_sqnorm(ef.residual)))

        sharded = shard_map(
            worker, mesh=cfg.mesh,
            in_specs=(P(), p_lead, p_batch),
            out_specs=(P(), p_lead, P(), p_lead, p_lead),
            check_rep=False)

        def step_fn(params, opt_state, resid, batch):
            mean, new_resid, loss, local_sq, resid_sq = sharded(
                params, resid, batch)
            # |mean|^2 on the replicated mean: identical reduction order on
            # every worker and in the single-device oracle
            stats = {"local_sq": local_sq, "resid_sq": resid_sq,
                     "gsq_big": damping_lib.tree_sqnorm(mean)}
            new_params, new_state = self.opt.update(mean, opt_state, params)
            return new_params, new_state, new_resid, loss, stats

        donate = (0, 1, 2) if self._donate else ()
        return jax.jit(step_fn, donate_argnums=donate)

    def _init_ef(self, params):
        w = self._dp_workers
        return jax.tree.map(
            lambda p: jnp.zeros((w,) + tuple(p.shape), jnp.float32), params)

    # ------------------------------------------------------------------
    # checkpoint state (dp runs carry the EF residual in the snapshot:
    # exact resume needs exactly what the optimizer hasn't seen yet)
    # ------------------------------------------------------------------

    def _ckpt_tree(self, params, opt_state):
        if self.cfg.mesh is not None:
            return (params, opt_state, self._ef_resid)
        return (params, opt_state)

    def _unpack_ckpt(self, tree):
        if self.cfg.mesh is not None:
            params, opt_state, self._ef_resid = tree
            return params, opt_state
        return tree

    def restore_or_init(self, params, opt_state):
        """Returns ``(params, opt_state, start_step, manifest_extra)``; the
        extra dict carries the consumed-batch count and damping state."""
        c = self.cfg
        if c.mesh is not None and self._ef_resid is None:
            self._ef_resid = self._init_ef(params)
        if c.ckpt_dir:
            step = ckpt_lib.latest_step(c.ckpt_dir)
            if step is not None:
                tree, man = ckpt_lib.restore(
                    c.ckpt_dir, step, self._ckpt_tree(params, opt_state))
                params, opt_state = self._unpack_ckpt(tree)
                return params, opt_state, man["step"], man.get("extra", {})
        return params, opt_state, 0, {}

    # ------------------------------------------------------------------

    def fit(self, params, opt_state, batches: Iterator[dict], n_steps: int,
            *, fail_hook: Optional[Callable[[int], None]] = None,
            step_hook: Optional[Callable] = None):
        """Run ``n_steps``; on step failure restore the last checkpoint and
        continue (up to cfg.max_failures) — deterministically: rolled-back
        batches replay from the buffer, so the resumed run is bitwise the
        run that never failed."""
        c = self.cfg
        params, opt_state, start, extra = self.restore_or_init(
            params, opt_state)
        step = start
        consumed = int(extra.get("consumed", 0))
        damp = None
        if c.damping is not None:
            damp = (damping_lib.DampingState.from_dict(extra["damping"])
                    if extra.get("damping") else
                    damping_lib.init_state(c.damping))

        it = iter(batches)
        for _ in range(consumed):     # fresh-restart fast-forward: skip
            next(it)                  # batches the checkpoint already trained on
        replay_buf: list[tuple[int, dict]] = []   # since last durable ckpt
        replay_pending: list[tuple[int, dict]] = []
        saved_consumed: dict[int, int] = {}       # ckpt step -> consumed
        if c.ckpt_dir and start > 0:
            saved_consumed[start] = consumed

        def draw():
            nonlocal consumed
            if replay_pending:
                idx, b = replay_pending.pop(0)
                assert idx == consumed, (idx, consumed)
            else:
                b = next(it)
                if c.ckpt_dir:   # no ckpt -> no rollback -> no replay need
                    replay_buf.append((consumed, b))
            consumed += 1
            return b

        def trim_replay():
            durable = (self.saver.last_saved_step if c.async_ckpt
                       else max(saved_consumed, default=None))
            if durable is None or durable not in saved_consumed:
                return
            keep_from = saved_consumed[durable]
            while replay_buf and replay_buf[0][0] < keep_from:
                replay_buf.pop(0)

        failures = 0
        while step < n_steps:
            n_micro, batch, batch_rows = self._next_batch(draw, damp)
            t0 = time.monotonic()
            try:
                if fail_hook is not None:
                    fail_hook(step)  # failure injection point (tests)
                params, opt_state, loss, stats = self._run_step(
                    params, opt_state, batch, n_micro)
                loss = float(loss)
            except Exception as e:  # noqa: BLE001 — node-failure surface
                failures += 1
                if failures > c.max_failures or not c.ckpt_dir:
                    raise
                self.saver.wait()   # in-flight snapshot becomes durable
                restored = ckpt_lib.latest_step(c.ckpt_dir)
                if restored is None:
                    raise RuntimeError("failure before first checkpoint") from e
                tree, man = ckpt_lib.restore(
                    c.ckpt_dir, restored,
                    jax.tree.map(lambda x: x,
                                 self._ckpt_tree(params, opt_state)))
                params, opt_state = self._unpack_ckpt(tree)
                step = man["step"]
                extra = man.get("extra", {})
                back_to = int(extra.get("consumed", 0))
                if damp is not None:
                    damp = (damping_lib.DampingState.from_dict(
                        extra["damping"]) if extra.get("damping") else
                        damping_lib.init_state(c.damping))
                # rewind: every batch drawn after the checkpoint replays, in
                # draw order (replay_buf is append-ordered and never
                # re-appends a replayed batch, so this filter is exact)
                replay_pending = [(i, b) for i, b in replay_buf
                                  if i >= back_to]
                consumed = back_to
                self.history.append(
                    {"step": step,
                     "event": f"restored after {type(e).__name__}"})
                continue
            dt = time.monotonic() - t0
            step += 1
            if step_hook is not None:   # eval/curve hook (benchmarks)
                step_hook(step, params, consumed)
            if damp is not None and step % c.damping.check_every == 0:
                damp = self._damping_update(damp, stats, n_micro, batch_rows)
            if c.step_timeout_s and dt > c.step_timeout_s:
                self.history.append(
                    {"step": step, "event": f"straggler: {dt:.1f}s"})
            if step % c.log_every == 0 or step == n_steps:
                h = {"step": step, "loss": loss, "dt": dt,
                     "consumed": consumed}
                if damp is not None:
                    h.update(accum=damp.accum, b_noise=damp.b_noise)
                self.history.append(h)
            if c.ckpt_dir and (step % c.ckpt_every == 0 or step == n_steps):
                extra_out = {"consumed": consumed}
                if damp is not None:
                    extra_out["damping"] = damp.to_dict()
                saved_consumed[step] = consumed
                if c.async_ckpt:
                    self.saver.submit(c.ckpt_dir, step,
                                      self._ckpt_tree(params, opt_state),
                                      extra=extra_out, keep=c.keep)
                else:
                    ckpt_lib.save(c.ckpt_dir, step,
                                  self._ckpt_tree(params, opt_state),
                                  extra=extra_out, keep=c.keep)
                trim_replay()
        self.saver.wait()
        self.consumed = consumed
        self.damp_state = damp
        return params, opt_state

    # ------------------------------------------------------------------
    # batch shaping + damping plumbing
    # ------------------------------------------------------------------

    def _next_batch(self, draw, damp):
        """Draw and shape the next step's input.

        Returns ``(n_micro, batch, batch_rows)`` where ``batch_rows`` is the
        row count of ONE drawn data batch (the unit the damping schedule
        multiplies by ``accum``).
        """
        c = self.cfg
        if damp is None:
            batch = draw()
            rows = _leading_rows(batch)
            k = c.microbatch if c.microbatch and c.microbatch > 1 else 1
            if k > 1:
                batch = _split_micro(batch, k)
            return k, batch, rows
        if damp.accum == 1:
            batch = draw()
            rows = _leading_rows(batch)
            if rows % 2 == 0:   # free noise pair: split the batch in two
                return 2, _split_micro(batch, 2), rows
            return 1, batch, rows
        drawn = [draw() for _ in range(damp.accum)]
        rows = _leading_rows(drawn[0])
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *drawn)
        return damp.accum, batch, rows

    def _run_step(self, params, opt_state, batch, n_micro):
        step = self._get_step(n_micro)
        if self.cfg.mesh is not None:
            if self._ef_resid is None:
                self._ef_resid = self._init_ef(params)
            params, opt_state, self._ef_resid, loss, stats = step(
                params, opt_state, self._ef_resid, batch)
            return params, opt_state, loss, stats
        return step(params, opt_state, batch)

    def _damping_update(self, damp, stats, n_micro, batch_rows):
        import numpy as np
        c = self.cfg
        total = batch_rows * (damp.accum if damp.accum > 1 else 1)
        if self.cfg.mesh is not None:
            # mesh pair: per-worker shard grads vs the psum'd mean; fold the
            # per-worker scalars on the host in index order (fp64)
            w = self._dp_workers
            if total % w != 0 or total // w == total:
                return damp
            st = damping_lib.NoiseStats(
                gsq_small=float(np.asarray(stats["local_sq"],
                                           np.float64).sum() / w),
                gsq_big=float(stats["gsq_big"]),
                b_small=total // w, b_big=total,
                resid_sq=float(np.asarray(stats["resid_sq"],
                                          np.float64).sum() / w))
            return damping_lib.update_state(damp, c.damping, st, batch_rows)
        if n_micro < 2:
            return damp    # no pair this step (odd batch at accum=1)
        st = damping_lib.NoiseStats(
            gsq_small=float(stats["micro_sqsum"]) / n_micro,
            gsq_big=float(stats["gsq_big"]),
            b_small=total // n_micro, b_big=total)
        return damping_lib.update_state(damp, c.damping, st, batch_rows)


def _leading_rows(batch) -> int:
    return int(jax.tree.leaves(batch)[0].shape[0])


def _split_micro(batch, k: int):
    """Reshape a flat batch into ``k`` stacked microbatches, validating
    divisibility loudly (a silent ``reshape(k, -1, ...)`` used to accept —
    and misassemble — non-divisible batches)."""
    def one(x):
        if x.shape[0] % k != 0:
            raise ValueError(
                f"microbatch={k} does not divide batch dim {x.shape[0]} "
                f"(leaf shape {x.shape}); pick a divisor of the batch size")
        return x.reshape(k, x.shape[0] // k, *x.shape[1:])
    return jax.tree.map(one, batch)
