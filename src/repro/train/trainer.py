"""Fault-tolerant training loop.

Features (DESIGN.md §5):
* jit'd train step with planner-driven in/out shardings and donated buffers,
* gradient accumulation (microbatching) via ``lax.scan`` over microbatches,
* periodic async checkpointing; automatic restore-and-continue on failure
  (exceptions from steps — simulating node loss — roll back to the last
  checkpoint; validated by tests/test_fault_tolerance.py),
* step-time watchdog hook (straggler posture),
* QAT mode: the same loop fine-tunes through the approximate forward / exact
  STE backward (paper Fig. 1 flow).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, SGD
from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    microbatch: int = 0          # 0 = no accumulation
    max_failures: int = 3
    step_timeout_s: Optional[float] = None   # watchdog (logged, not killed)
    log_every: int = 10
    async_ckpt: bool = True


class Trainer:
    """Drives (params, opt_state) through a loss function with recovery."""

    def __init__(self, loss_fn: Callable, optimizer: AdamW | SGD,
                 cfg: TrainerConfig = TrainerConfig(), *,
                 in_shardings=None, donate: bool = True):
        self.loss_fn = loss_fn
        self.opt = optimizer
        self.cfg = cfg
        self.saver = ckpt_lib.AsyncSaver()
        self.history: list[dict] = []

        def step_fn(params, opt_state, batch):
            if cfg.microbatch and cfg.microbatch > 1:
                def micro(carry, mb):
                    loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                    l0, g0 = carry
                    return (l0 + loss, jax.tree.map(jnp.add, g0, grads)), None
                mbs = jax.tree.map(
                    lambda x: x.reshape(cfg.microbatch, -1, *x.shape[1:]), batch)
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), mbs)
                loss = loss / cfg.microbatch
                grads = jax.tree.map(lambda g: g / cfg.microbatch, grads)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_state = self.opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        donate_argnums = (0, 1) if donate else ()
        self.step = jax.jit(step_fn, donate_argnums=donate_argnums)

    # ------------------------------------------------------------------

    def restore_or_init(self, params, opt_state):
        c = self.cfg
        if c.ckpt_dir:
            step = ckpt_lib.latest_step(c.ckpt_dir)
            if step is not None:
                (params, opt_state), man = ckpt_lib.restore(
                    c.ckpt_dir, step, (params, opt_state))
                return params, opt_state, man["step"]
        return params, opt_state, 0

    def fit(self, params, opt_state, batches: Iterator[dict], n_steps: int,
            *, fail_hook: Optional[Callable[[int], None]] = None):
        """Run ``n_steps``; on step failure restore the last checkpoint and
        continue (up to cfg.max_failures)."""
        c = self.cfg
        params, opt_state, start = self.restore_or_init(params, opt_state)
        step = start
        failures = 0
        it = iter(batches)
        while step < n_steps:
            batch = next(it)
            t0 = time.monotonic()
            try:
                if fail_hook is not None:
                    fail_hook(step)  # failure injection point (tests)
                params, opt_state, loss = self.step(params, opt_state, batch)
                loss = float(loss)
            except Exception as e:  # noqa: BLE001 — node-failure surface
                failures += 1
                if failures > c.max_failures or not c.ckpt_dir:
                    raise
                restored = ckpt_lib.latest_step(c.ckpt_dir)
                if restored is None:
                    raise RuntimeError("failure before first checkpoint") from e
                (params, opt_state), man = ckpt_lib.restore(
                    c.ckpt_dir, restored, jax.tree.map(lambda x: x, (params, opt_state)))
                step = man["step"]
                self.history.append({"step": step, "event": f"restored after {type(e).__name__}"})
                continue
            dt = time.monotonic() - t0
            step += 1
            if c.step_timeout_s and dt > c.step_timeout_s:
                self.history.append({"step": step, "event": f"straggler: {dt:.1f}s"})
            if step % c.log_every == 0 or step == n_steps:
                self.history.append({"step": step, "loss": loss, "dt": dt})
            if c.ckpt_dir and (step % c.ckpt_every == 0 or step == n_steps):
                if c.async_ckpt:
                    self.saver.submit(c.ckpt_dir, step, (params, opt_state),
                                      keep=c.keep)
                else:
                    ckpt_lib.save(c.ckpt_dir, step, (params, opt_state),
                                  keep=c.keep)
        self.saver.wait()
        return params, opt_state
