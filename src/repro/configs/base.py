"""Model/architecture configuration schema shared by all assigned archs."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to(n: int, mult: int) -> int:
    return n + (-n) % mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None    # default d_model // n_heads
    vocab_pad_mult: int = 256         # pad vocab so TP always divides

    # layer pattern: kinds per repeating group; n_layers % len(pattern) == 0
    #   attn, attn_local, attn_global, attn_moe, mamba, mamba_moe, rwkv
    pattern: Tuple[str, ...] = ("attn",)

    # attention
    rope: str = "rope"                # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qkv_bias: bool = False
    qk_norm: bool = False
    softcap_attn: Optional[float] = None
    softcap_final: Optional[float] = None
    window_size: Optional[int] = None  # for attn_local layers
    attn_impl: str = "chunked"         # chunked | naive | flash
    attn_chunk: int = 512
    attn_causal_blocking: bool = False  # §Perf: skip fully-masked KV blocks

    # blocks / norms
    mlp_type: str = "swiglu"           # swiglu | geglu | gelu
    norm: str = "rms"                  # rms | rms1p | ln
    post_norm: bool = False            # gemma2 sandwich norms
    parallel_block: bool = False       # command-r style
    tie_embed: bool = False
    embed_scale: bool = False          # gemma: x *= sqrt(d)

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity: float = 1.25
    # §Perf hillclimb #1: shard the dispatch capacity dim over `data`
    # (token-parallel expert compute). False reproduces the replicated-
    # dispatch baseline recorded in EXPERIMENTS.md.
    moe_shard_dispatch: bool = True

    # Mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # RWKV
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 256

    # whisper enc-dec
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_ctx: int = 1500
    max_dec_pos: int = 0               # learned decoder positions (0 = rope)

    # runtime
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"      # nothing | dots (save matmul outputs)
    scan_unroll: int = 1
    sub_quadratic: bool = False        # eligible for long_500k
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (self.name, self.n_layers,
                                                        self.pattern)

    # -- derived ----------------------------------------------------------

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_mult)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Total parameter count (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hd, h, hkv = self.head_dim, self.n_heads, self.n_kv_heads
        per_kind = {}
        attn = d * (h * hd) + 2 * d * (hkv * hd) + (h * hd) * d
        dense_mlp = 3 * d * f if self.mlp_type in ("swiglu", "geglu") else 2 * d * f
        moe_mlp = self.n_experts * 3 * d * f + d * self.n_experts
        di, ds = self.mamba_d_inner, self.mamba_d_state
        mamba = d * 2 * di + di * (self.mamba_dt_rank + 2 * ds) + \
            self.mamba_dt_rank * di + di * ds + di * d + self.mamba_d_conv * di
        rwkv = 6 * d * d + 2 * d * (4 * f // 4)  # approx: tm + cm GEMMs
        for kind in self.pattern:
            if kind.startswith("attn"):
                per_kind[kind] = attn + (moe_mlp if kind.endswith("moe") else dense_mlp)
            elif kind.startswith("mamba"):
                per_kind[kind] = mamba + (moe_mlp if kind.endswith("moe") else dense_mlp)
            elif kind == "rwkv":
                per_kind[kind] = rwkv
        body = sum(per_kind[k] for k in self.pattern) * self.n_groups
        if self.enc_dec:
            body += self.n_enc_layers * (attn + dense_mlp) + \
                self.n_layers * attn  # decoder cross-attention
        emb = v * d * (1 if self.tie_embed else 2)
        return body + emb

    def n_active_params(self) -> int:
        """Active (per-token) params for MoE FLOP accounting."""
        if self.n_experts == 0:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        full_moe = self.n_experts * 3 * d * f
        act_moe = self.moe_top_k * 3 * d * f
        n_moe_layers = sum(1 for k in self.pattern if k.endswith("moe")) * self.n_groups
        if all(not k.endswith("moe") for k in self.pattern):
            n_moe_layers = 0
        return self.n_params() - n_moe_layers * (full_moe - act_moe)
