"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) d_ff=512/expert,
MoE 40e top-8, vocab 49155. [hf:ibm-granite/granite-3.0-1b-a400m-base]

24 heads % 16 != 0 -> heads replicated under TP (planner fallback);
40 experts % 16 != 0 -> TP-in-expert (d_ff 512 / 16 = 32).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    pattern=("attn_moe",), n_experts=40, moe_top_k=8,
    notes="heads/experts not divisible by model axis: TP via d_ff+vocab; "
          "long_500k skipped (full attention).",
)
