"""qwen2.5-14b [dense]: 48L d5120 40H (GQA kv=8) d_ff=13824, vocab 152064,
QKV bias. [hf:Qwen/Qwen2.5 family]

40 heads % 16 != 0 -> heads replicated under TP (planner fallback; hillclimb
candidate: pad to 48 heads is still not divisible — TP lives on d_ff+vocab).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True,
    notes="long_500k skipped (full attention).",
)
