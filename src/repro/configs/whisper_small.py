"""whisper-small [audio]: 12L enc + 12L dec, d768 12H (kv=12) d_ff=3072,
vocab 51865, enc-dec with conv frontend STUBBED per the assignment
(input_specs() provides post-conv frame embeddings, enc_ctx=1500).
[arXiv:2212.04356; unverified]

max_dec_pos is raised to 33k so decode_32k is structurally lowerable
(real whisper caps at 448 decoder positions — noted in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    enc_dec=True, n_enc_layers=12, enc_ctx=1500, max_dec_pos=33000,
    norm="ln", mlp_type="gelu", rope="none",
    notes="12 heads % 16 != 0 -> heads replicated; long_500k skipped.",
)
