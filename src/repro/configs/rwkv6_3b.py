"""rwkv6-3b "Finch" [ssm]: 32L d2560 (attn-free, 40 wkv heads x 64),
d_ff=8960, vocab 65536, data-dependent decay. [arXiv:2404.05892]

Attention-free -> sub-quadratic -> runs long_500k. The paper's ACU technique
applies to all R/K/V/G/O + channel-mix GEMMs (DESIGN.md §6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    pattern=("rwkv",), rope="none", rwkv_head_dim=64,
    sub_quadratic=True,
)
