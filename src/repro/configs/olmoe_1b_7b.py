"""olmoe-1b-7b [moe]: 16L d2048 16H (GQA kv=16) d_ff=1024/expert,
MoE 64e top-8, vocab 50304, QK-norm. [arXiv:2409.02060]

Fully expert-parallel (64 % 16 == 0) and head-parallel (16 % 16 == 0).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    pattern=("attn_moe",), n_experts=64, moe_top_k=8, qk_norm=True,
    notes="long_500k skipped (full attention).",
)
