"""qwen2-vl-72b [vlm]: 80L d8192 64H (GQA kv=8) d_ff=29568, vocab 152064,
M-RoPE, dynamic resolution. [arXiv:2409.12191]

Backbone only per the assignment: the vision frontend is a stub —
input_specs() provides token ids (+ M-RoPE position streams collapse to text
mode); dynamic-resolution patching happens upstream of the backbone.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    rope="mrope", mrope_sections=(16, 24, 24), qkv_bias=True,
    notes="vision frontend stubbed; long_500k skipped (full attention).",
)
