"""Assigned input shapes and per-(arch x shape) eligibility."""
from __future__ import annotations

import dataclasses

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def eligible(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason when skipped (per spec:
    long_500k only for sub-quadratic families)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention architecture"
    return True, ""


def cells(configs: dict[str, ModelConfig]):
    """All (arch, shape) cells with eligibility — the 40-cell matrix."""
    out = []
    for aname, cfg in configs.items():
        for sname, sh in SHAPES.items():
            ok, why = eligible(cfg, sh)
            out.append((aname, sname, ok, why))
    return out
