"""gemma2-27b [dense]: 46L d4608 32H (GQA kv=16) d_ff=36864, vocab 256000,
local+global alternating attention, logit softcaps. [arXiv:2408.00118]

head_dim 128 (q/k/v project to 4096 != d_model, as released). Sandwich
(pre+post) RMSNorm with (1+w) parameterization; GeGLU; sqrt(d) embed scale.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=("attn_local", "attn_global"), window_size=4096,
    softcap_attn=50.0, softcap_final=30.0,
    norm="rms1p", post_norm=True, mlp_type="geglu", embed_scale=True,
    notes="long_500k skipped (global layers are full attention).",
)
