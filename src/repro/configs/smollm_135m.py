"""smollm-135m [dense]: 30L d576 9H (GQA kv=3) d_ff=1536, vocab 49152,
llama-arch small, tied embeddings. [hf:HuggingFaceTB/SmolLM-135M]

The ~100M end-to-end training demo architecture (examples/train_lm_approx.py).
9 heads % 16 != 0 -> heads replicated under TP.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab_size=49152, tie_embed=True,
    notes="long_500k skipped (full attention).",
)
