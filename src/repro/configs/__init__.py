"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib

from .base import ModelConfig
from .shapes import SHAPES, ShapeSpec, cells, eligible

_MODULES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma2-27b": "gemma2_27b",
    "smollm-135m": "smollm_135m",
    "command-r-plus-104b": "command_r_plus_104b",
    "whisper-small": "whisper_small",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def reduced_config(name: str) -> ModelConfig:
    """Smoke-test variant: same family/pattern/features, tiny dims.

    Dims are shrunk so one forward/train step runs in seconds on CPU while
    every structural feature (pattern, GQA ratio, MoE, norms, softcaps,
    biases) is preserved.
    """
    cfg = get_config(name)
    g = len(cfg.pattern)
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, heads * cfg.n_kv_heads // cfg.n_heads)
    # keep a valid GQA ratio
    while heads % kv != 0:
        kv -= 1
    head_dim = 16
    d_model = heads * head_dim if cfg.name != "gemma2-27b" else heads * head_dim + 16
    repl = dict(
        n_layers=2 * g if 2 * g <= 8 else g,
        d_model=d_model,
        n_heads=heads, n_kv_heads=kv, head_dim=head_dim,
        d_ff=4 * d_model if cfg.n_experts == 0 else 32,
        vocab_size=211,
        vocab_pad_mult=16,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        # ample capacity: smoke/parity tests must be drop-free so block-local
        # and global dispatch agree exactly
        moe_capacity=8.0,
        window_size=8 if cfg.window_size else None,
        enc_ctx=16 if cfg.enc_dec else cfg.enc_ctx,
        n_enc_layers=2 if cfg.enc_dec else 0,
        max_dec_pos=128 if cfg.max_dec_pos else 0,
        rwkv_head_dim=16,
        rwkv_chunk=8,
        mrope_sections=(4, 2, 2) if cfg.rope == "mrope" else cfg.mrope_sections,
        dtype="float32",
        remat=False,
        name=f"{cfg.name}-smoke",
    )
    return dataclasses.replace(cfg, **repl)


__all__ = ["ModelConfig", "SHAPES", "ShapeSpec", "cells", "eligible",
           "ARCH_NAMES", "get_config", "all_configs", "reduced_config"]
