"""jamba-v0.1-52b [hybrid]: 32L d4096 32H (GQA kv=8) d_ff=14336,
MoE 16e top-2, Mamba+attn 1:7 interleave, vocab 65536. [arXiv:2403.19887]

Layer period 8: attention at offset 4, MoE every other layer (as released).
Sub-quadratic (SSM-dominated) -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
             "attn", "mamba_moe", "mamba", "mamba_moe"),
    n_experts=16, moe_top_k=2,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    sub_quadratic=True,
)
