"""Serving engines: batched LM prefill/decode and batched vision inference.

Requests are served in *waves*: up to ``slots`` prompts are padded to a
common length, prefilled in one batched call, then decoded in lockstep (one
jit'd decode step per token for the whole batch). Per-request early stop
masks finished rows. Both steps are jit'd once and reused for every wave.

(True per-slot continuous batching needs per-row cache positions — a vmap'd
cache update — which trades compile complexity for admission latency; the
wave design keeps the decode step identical to the dry-run ``serve_step``,
which is what the multi-pod config proves out.)
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import apply_model, init_cache
from repro.parallel.sharding import MeshContext, use_mesh, use_mesh_context


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    """``mesh`` (a ``jax.sharding.Mesh`` or an existing
    :class:`~repro.parallel.sharding.MeshContext`) activates mesh-aware
    execution for both jits: prefill/decode trace under
    :func:`~repro.parallel.sharding.use_mesh`, so every ``matmul_plan``
    inside `apply_model` resolves to its sharded route (and the models'
    logical-axis ``shard()`` annotations become real constraints) instead of
    silently running replicated. ``mesh=None`` keeps the single-device
    behavior bit-for-bit."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, acfg=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.acfg = acfg
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            # verbatim: a context whose rules omit keys means "replicated
            # there" — re-entering via use_mesh would re-merge DEFAULT_RULES
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill(params, cache, tokens, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=0,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        def decode(params, cache, tokens, pos, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos, decode=True,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _wave(self, reqs: list[Request],
              on_token: Optional[Callable[[int, int], None]]) -> None:
        b = self.slots
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        offs = np.zeros(b, np.int32)           # per-request left-pad counts
        valid = np.zeros((b, self.max_seq), bool)
        for i, r in enumerate(reqs):
            off = plen - len(r.prompt)
            toks[i, off:] = r.prompt           # left-pad
            offs[i] = off
            valid[i, off:] = True              # pad slots masked for the wave
        cache = init_cache(self.cfg, b, self.max_seq)
        offs_j, valid_j = jnp.asarray(offs), jnp.asarray(valid)
        with self._mesh_scope():
            logits, cache = self._prefill(self.params, cache,
                                          jnp.asarray(toks), offs_j, valid_j)
        cur = np.asarray(jnp.argmax(logits, -1))
        max_new = max(r.max_new_tokens for r in reqs)
        budget = max(0, min(max_new, self.max_seq - plen))
        out = np.zeros((b, budget), np.int32)  # preallocated (was O(n^2)
        n_out = np.zeros(b, np.int32)          # np.append per token)
        alive = np.ones(b, bool)
        for t in range(budget):
            for i in np.flatnonzero(alive):
                out[i, t] = cur[i]
                n_out[i] += 1
                if on_token:
                    on_token(int(i), int(cur[i]))
                if n_out[i] >= reqs[i].max_new_tokens:
                    alive[i] = False
            # no decode once every slot is done, nor for the step whose
            # logits nothing would consume (the old loop ran one extra)
            if not alive.any() or t == budget - 1:
                break
            with self._mesh_scope():
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur)[:, None],
                                             plen + t, offs_j, valid_j)
            cur = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(reqs):
            r.out = out[i, :n_out[i]].copy()

    def run(self, requests: list[Request],
            on_token: Optional[Callable[[int, int], None]] = None) -> list[Request]:
        """Serve all requests (waves of ``slots``); returns them with .out."""
        reqs = list(requests)
        for i in range(0, len(reqs), self.slots):
            wave = reqs[i:i + self.slots]
            while len(wave) < self.slots:       # pad the wave with a dummy
                wave.append(Request(prompt=np.zeros(1, np.int32),
                                    max_new_tokens=1))
            self._wave(wave, on_token)
        return requests


class VisionServeEngine:
    """Batched image-inference serving: fixed-size waves through one jitted
    forward, mesh-aware like :class:`ServeEngine`.

    ``forward_fn(params, images, acfg) -> logits`` is any vision model
    forward (``repro.models.vision.cnn_forward`` / ``resnet_forward`` / ...);
    every conv inside it resolves a :func:`~repro.core.acu.conv_plan`, so
    with a LUT-Pallas ``acfg`` the whole stack rides the fused
    patch-streaming conv kernels — including ImageNet-scale (224^2) inputs,
    which since PR 4 resolve to the spatially-tiled kernel instead of
    reporting the eager-im2col VMEM fallback (``plan_report`` shows the
    chosen banding) — and with ``mesh=...`` the waves run under the
    ``acu_conv`` partition (batch x output-row bands over
    ``("pod", "data")``, output channels over ``("model",)``) — bit-for-bit
    the single-device logits.
    """

    def __init__(self, params, forward_fn: Callable, *, slots: int = 8,
                 acfg=None, mesh=None):
        self.params = params
        self.slots = slots
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)
        self._infer = jax.jit(lambda p, imgs: forward_fn(p, imgs, acfg))

    def plan_report(self, image_shape, w_shape, acfg, **geom) -> dict:
        """The conv route one layer takes under this engine's mesh scope
        (see :func:`repro.core.approx_ops.conv_plan_report`)."""
        from repro.core.approx_ops import conv_plan_report
        with self._mesh_scope():
            return conv_plan_report(image_shape, w_shape, acfg, **geom)

    def run(self, images: np.ndarray) -> np.ndarray:
        """images: (B, C, H, W) -> logits (B, n_classes), served in waves of
        ``slots`` (the last wave zero-padded and sliced)."""
        b = images.shape[0]
        outs = []
        for i in range(0, b, self.slots):
            wave = np.asarray(images[i:i + self.slots], np.float32)
            pad = self.slots - wave.shape[0]
            if pad:
                wave = np.concatenate(
                    [wave, np.zeros((pad, *wave.shape[1:]), wave.dtype)])
            with self._mesh_scope():
                logits = self._infer(self.params, jnp.asarray(wave))
            outs.append(np.asarray(logits)[:self.slots - pad])
        return np.concatenate(outs, axis=0)
