"""Serving engines: batched LM prefill/decode and batched vision inference.

Three LM engines share the jitted ``apply_model`` steps:

* :class:`ServeEngine` — *waves*: up to ``slots`` prompts are padded to a
  common length, prefilled in one batched call, then decoded in lockstep
  (one jit'd decode step per token for the whole batch). Per-request early
  stop masks finished rows, but a finished slot idles until the whole wave
  drains, and arrivals queue behind the current wave.
* :class:`ContinuousServeEngine` — true continuous batching: every slot
  advances at its *own* cache position (``cache_pos`` is a (slots,) vector;
  the KV append is a vmap'd per-row ``dynamic_update_slice``), a finished
  slot is evicted and refilled immediately (batch-1 bucketed prefill +
  jitted row insertion into the batched cache), so the decode batch stays
  full under load. Sustained tokens/s under a Poisson arrival trace is the
  ``[serve]`` benchmark's headline number.
* :class:`PagedContinuousServeEngine` — the same continuous scheduler over
  a block-paged KV cache (vLLM's PagedAttention is the exemplar): KV lives
  in fixed-size physical blocks handed out by a free-list
  :class:`BlockAllocator` under a global HBM budget, each slot addresses
  them through a per-slot page table, prompts prefill in block-aligned
  chunks, shared prompt prefixes become refcounted cache hits (full-block
  granularity, chained hashes, copy-on-write on the decode tail), and
  memory pressure is resolved by LRU prefix-cache eviction first,
  youngest-request preemption second — so admission is bounded by *blocks
  in use*, not slot count times ``max_seq``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import apply_model, init_cache, init_paged_cache
from repro.parallel.sharding import MeshContext, use_mesh, use_mesh_context


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    """``mesh`` (a ``jax.sharding.Mesh`` or an existing
    :class:`~repro.parallel.sharding.MeshContext`) activates mesh-aware
    execution for both jits: prefill/decode trace under
    :func:`~repro.parallel.sharding.use_mesh`, so every ``matmul_plan``
    inside `apply_model` resolves to its sharded route (and the models'
    logical-axis ``shard()`` annotations become real constraints) instead of
    silently running replicated. ``mesh=None`` keeps the single-device
    behavior bit-for-bit."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, acfg=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.acfg = acfg
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            # verbatim: a context whose rules omit keys means "replicated
            # there" — re-entering via use_mesh would re-merge DEFAULT_RULES
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill(params, cache, tokens, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=0,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        def decode(params, cache, tokens, pos, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos, decode=True,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _wave(self, reqs: list[Request],
              on_token: Optional[Callable[[int, int], None]]) -> None:
        b = self.slots
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        offs = np.zeros(b, np.int32)           # per-request left-pad counts
        valid = np.zeros((b, self.max_seq), bool)
        for i, r in enumerate(reqs):
            off = plen - len(r.prompt)
            toks[i, off:] = r.prompt           # left-pad
            offs[i] = off
            valid[i, off:] = True              # pad slots masked for the wave
        cache = init_cache(self.cfg, b, self.max_seq)
        offs_j, valid_j = jnp.asarray(offs), jnp.asarray(valid)
        with self._mesh_scope():
            logits, cache = self._prefill(self.params, cache,
                                          jnp.asarray(toks), offs_j, valid_j)
        cur = np.asarray(jnp.argmax(logits, -1))
        max_new = max(r.max_new_tokens for r in reqs)
        budget = max(0, min(max_new, self.max_seq - plen))
        out = np.zeros((b, budget), np.int32)  # preallocated (was O(n^2)
        n_out = np.zeros(b, np.int32)          # np.append per token)
        alive = np.ones(b, bool)
        for t in range(budget):
            for i in np.flatnonzero(alive):
                out[i, t] = cur[i]
                n_out[i] += 1
                if on_token:
                    on_token(int(i), int(cur[i]))
                if n_out[i] >= reqs[i].max_new_tokens:
                    alive[i] = False
            # no decode once every slot is done, nor for the step whose
            # logits nothing would consume (the old loop ran one extra)
            if not alive.any() or t == budget - 1:
                break
            with self._mesh_scope():
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur)[:, None],
                                             plen + t, offs_j, valid_j)
            cur = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(reqs):
            r.out = out[i, :n_out[i]].copy()

    def run(self, requests: list[Request],
            on_token: Optional[Callable[[int, int], None]] = None) -> list[Request]:
        """Serve all requests (waves of ``slots``); returns them with .out."""
        reqs = list(requests)
        for i in range(0, len(reqs), self.slots):
            wave = reqs[i:i + self.slots]
            while len(wave) < self.slots:       # pad the wave with a dummy
                wave.append(Request(prompt=np.zeros(1, np.int32),
                                    max_new_tokens=1))
            self._wave(wave, on_token)
        return requests


def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two >= n (>= lo): bounds prefill recompiles to log2
    distinct prompt shapes."""
    b = lo
    while b < n:
        b *= 2
    return b


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times for ``n`` requests, in
    decode-step units (``rate`` = mean arrivals per decode step)."""
    rng = np.random.RandomState(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


class ContinuousServeEngine:
    """Continuous-batching LM serving: slot-level admission and eviction.

    Each incoming request is prefilled alone (prompt left-padded to a
    power-of-two bucket, so at most log2(max_seq) prefill shapes compile),
    its batch-1 cache row is inserted into the live batched cache by a
    jitted ``dynamic_update_slice``, and from then on the slot decodes in
    the shared batched step at its own cache position — ``cache_pos`` is a
    (slots,) vector and every attention layer appends KV with a vmap'd
    per-row update. A slot that exhausts its ``max_new_tokens`` (honored
    exactly, per request) is evicted the same step and its slot refilled by
    the next queued arrival, so unlike the wave engine no row idles behind
    the longest request in its batch.

    ``run(requests, arrivals=None)``: ``arrivals`` are request arrival
    times in decode-step units (``None`` = all at t=0); the engine's clock
    is the decode-step counter, so a trace replays deterministically.
    ``self.stats`` afterwards holds ``decode_steps``, ``prefills``,
    ``tokens`` and mean slot ``occupancy`` per decode step.

    Same mesh contract as :class:`ServeEngine`; with a LUT-Pallas ``acfg``
    every attention layer rides the fused approximate flash kernel
    (per-row ``rowinfo`` built from the position vector and pad mask).
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, acfg=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.acfg = acfg
        self.stats: dict = {}
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill(params, cache, tokens, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=0,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask, last_only=True)
            return logits[:, -1], cache

        def insert(cache, row, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1), cache, row)

        def decode(params, cache, tokens, pos, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos,
                                        decode=True, pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        # no donation on insert: a fresh init_cache aliases its k/v leaves
        # (the same zeros array twice), which donation rejects
        self._insert = jax.jit(insert)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _admit(self, req: Request, slot: int, cache):
        """Prefill one request and insert its cache row at ``slot``.
        Returns (cache, first_token, next_pos, pad_off, budget)."""
        plen = len(req.prompt)
        bucket = min(_bucket(plen), self.max_seq)
        assert plen <= bucket, (plen, self.max_seq)  # run() rejects overlong
        off = bucket - plen
        toks = np.zeros((1, bucket), np.int32)
        toks[0, off:] = req.prompt
        valid = np.zeros((1, self.max_seq), bool)
        valid[0, off:] = True
        row_cache = init_cache(self.cfg, 1, self.max_seq)
        with self._mesh_scope():
            logits, row_cache = self._prefill(
                self.params, row_cache, jnp.asarray(toks),
                jnp.asarray([off], jnp.int32), jnp.asarray(valid))
            cache = self._insert(cache, row_cache,
                                 jnp.asarray(slot, jnp.int32))
        self.stats["prefills"] += 1
        tok = int(np.asarray(jnp.argmax(logits[0])))
        budget = max(0, min(req.max_new_tokens, self.max_seq - bucket))
        return cache, tok, bucket, off, budget

    def run(self, requests: list[Request], arrivals=None,
            on_token: Optional[Callable[[int, int], None]] = None
            ) -> list[Request]:
        reqs = list(requests)
        n = len(reqs)
        arr = (np.zeros(n) if arrivals is None
               else np.asarray(arrivals, np.float64))
        assert len(arr) == n
        order = sorted(range(n), key=lambda j: (arr[j], j))
        qi = 0
        slots = self.slots
        active = np.zeros(slots, bool)
        pos = np.zeros(slots, np.int32)
        offs = np.zeros(slots, np.int32)
        valid = np.zeros((slots, self.max_seq), bool)
        cur = np.zeros(slots, np.int32)
        n_out = np.zeros(slots, np.int64)
        budget = np.zeros(slots, np.int64)
        ridx = np.full(slots, -1, np.int64)
        outs: list[Optional[np.ndarray]] = [None] * slots
        cache = init_cache(self.cfg, slots, self.max_seq)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                      "occupancy_sum": 0, "rejected": 0}
        step = 0.0  # decode-step clock
        done = 0
        while done < n:
            # admit queued arrivals into free slots (one prefill each)
            while qi < len(order) and arr[order[qi]] <= step:
                free = np.flatnonzero(~active)
                if not free.size:
                    break
                i, j = int(free[0]), order[qi]
                qi += 1
                if len(reqs[j].prompt) > self.max_seq:
                    # over-length prompt: reject at admission (the bucketed
                    # prefill would otherwise trip its plen <= bucket
                    # invariant), report via stats, keep serving
                    reqs[j].out = np.zeros(0, np.int32)
                    self.stats["rejected"] += 1
                    done += 1
                    continue
                cache, tok, p0, off, bud = self._admit(reqs[j], i, cache)
                if bud <= 0:       # prompt fills max_seq: nothing to emit
                    reqs[j].out = np.zeros(0, np.int32)
                    done += 1
                    continue
                active[i] = True
                pos[i], offs[i], cur[i] = p0, off, tok
                valid[i] = False
                valid[i, off:] = True
                n_out[i], budget[i], ridx[i] = 0, bud, j
                outs[i] = np.zeros(bud, np.int32)
            if not active.any():
                if qi >= len(order):
                    break
                step = max(step, float(arr[order[qi]]))  # idle: jump clock
                continue
            # emit the token produced by the previous model call; evict
            # slots that hit their per-request budget the same step
            for i in np.flatnonzero(active):
                outs[i][n_out[i]] = cur[i]
                n_out[i] += 1
                self.stats["tokens"] += 1
                if on_token:
                    on_token(int(ridx[i]), int(cur[i]))
                if n_out[i] >= budget[i]:
                    reqs[ridx[i]].out = outs[i][:n_out[i]].copy()
                    active[i] = False
                    done += 1
            if not active.any():
                continue
            with self._mesh_scope():
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(cur)[:, None],
                    jnp.asarray(pos), jnp.asarray(offs), jnp.asarray(valid))
            nxt = np.asarray(jnp.argmax(logits, -1))
            live = np.flatnonzero(active)
            cur[live] = nxt[live]
            pos[live] += 1
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += int(live.size)
            step += 1.0
        self.stats["occupancy"] = (
            self.stats["occupancy_sum"] / max(1, self.stats["decode_steps"]))
        return requests


def kv_block_bytes(cfg: ModelConfig, block_size: int, dtype=None) -> int:
    """HBM bytes one physical KV block costs across the whole model: K and V,
    every KV head, every attention layer (a page-table entry maps the same
    block id in every layer's pool — blocks are allocated per slot, not per
    layer)."""
    dtype = dtype or cfg.param_dtype
    n_attn = sum(1 for k in cfg.pattern if k.startswith("attn")) * cfg.n_groups
    return (2 * n_attn * cfg.n_kv_heads * block_size * cfg.head_dim
            * jnp.dtype(dtype).itemsize)


class BlockAllocator:
    """Refcounted free-list over ``n_blocks`` physical KV blocks.

    Block 0 is the *null* block: page tables default to it for unallocated
    logical blocks, it is never handed out and never written, so it stays
    all-zeros (non-causal/window gathers through it see exactly what a
    contiguous cache holds past its fill). Block 1 is the *scratch* block:
    inactive decode rows park their page table on it so their discarded
    writes never dirty the null block. Shared prefix blocks carry one ref
    per sharer plus one for the prefix cache itself; a block returns to the
    free list when its refcount drains to zero.
    """

    NULL = 0
    SCRATCH = 1
    RESERVED = 2

    def __init__(self, n_blocks: int):
        assert n_blocks > self.RESERVED, n_blocks
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, self.RESERVED - 1, -1))
        self._rc = np.zeros(n_blocks, np.int32)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_blocks - self.RESERVED - len(self._free)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        blk = self._free.pop()
        self._rc[blk] = 1
        return blk

    def ref(self, blk: int) -> int:
        assert self._rc[blk] > 0, blk
        self._rc[blk] += 1
        return blk

    def release(self, blk: int) -> bool:
        """Drop one ref; returns True when the block went back on the free
        list."""
        assert self._rc[blk] > 0, blk
        self._rc[blk] -= 1
        if self._rc[blk] == 0:
            self._free.append(blk)
            return True
        return False

    def refcount(self, blk: int) -> int:
        return int(self._rc[blk])


class PagedContinuousServeEngine:
    """Continuous batching over a block-paged KV cache with prefix reuse.

    The scheduler is :class:`ContinuousServeEngine`'s (per-slot cache
    positions, shared batched decode step, decode-step clock) but the cache
    is a global pool of ``block_size``-token physical blocks sized by an
    HBM budget instead of per-slot contiguous rows:

    * **Prefill** runs in block-aligned chunks (batch-1): every full
      ``block_size`` chunk is one jitted call writing exactly one pool
      block; the final partial chunk pads to a power-of-two bucket (its
      trailing pad KV lands in the tail block but is strictly
      causal-future of every real query, and each slot's decode overwrites
      one pad position per step — so it is masked ``LUT[0, .]`` mass at
      most, and deterministic, which the bitwise prefix-hit contract
      relies on). No left-padding exists, so no ``pos_offset``/``pad_mask``
      plumbing.
    * **Prefix cache**: full prompt blocks are keyed by a chained hash of
      their token contents; an admission walks the chain and *reuses* every
      leading hit (refcounted — no copy, no recompute), then replays only
      the chunks past the last hit. Replayed KV is bitwise what the cold
      run wrote (same jitted chunk calls on the same values), so a warm
      admission is bit-identical to a cold one from the first replayed
      chunk onward. A *full-prompt* entry additionally snapshots the tail
      block and the first sampled token: an exact repeat admits with zero
      prefill compute, copy-on-write duplicating the tail block before
      decode writes into it.
    * **Memory pressure**: a decode step or admission that cannot get a
      block first evicts LRU prefix-cache entries, then preempts the
      youngest running request — its emitted tokens are kept and it
      re-enters the queue with ``prompt + emitted`` (greedy decode is
      deterministic, so the continuation is the continuation), usually
      landing back on its own still-cached prefix blocks.

    ``stats`` adds ``prefill_chunks``, ``prefix_hit_blocks``,
    ``prefix_lookup_blocks``, ``full_prompt_hits``, ``cache_evictions``,
    ``preemptions``, ``rejected``, ``block_util`` (mean fraction of
    poolblocks in use per decode step) and ``peak_blocks``.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, block_size: int = 16, acfg=None,
                 mesh=None, hbm_budget: Optional[int] = None,
                 prefix_cache: bool = True):
        assert max_seq % block_size == 0, (max_seq, block_size)
        # power-of-two >= the bucket floor: the tail chunk's pow2 bucket
        # must never overflow its single block
        assert block_size >= 8 and block_size & (block_size - 1) == 0, \
            block_size
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.block_size = block_size
        self.acfg = acfg
        self.prefix_cache = prefix_cache
        self.n_logical = max_seq // block_size
        bbytes = kv_block_bytes(cfg, block_size)
        if hbm_budget is None:
            # default budget: what the contiguous engine would pin for the
            # same (slots, max_seq) — paged then wins by packing more rows
            # into the same bytes, not by quietly getting more memory
            hbm_budget = slots * self.n_logical * bbytes
        self.hbm_budget = hbm_budget
        self.n_blocks = max(BlockAllocator.RESERVED + self.n_logical,
                            hbm_budget // bbytes)
        self.stats: dict = {}
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill_chunk(params, cache, tokens, pos, pt):
            # full-block chunk: KV side effects only, logits discarded
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos,
                                        last_only=True, page_table=pt)
            return logits[:, -1], cache

        def prefill_tail(params, cache, tokens, pos, pt):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos,
                                        page_table=pt)
            return logits, cache

        def decode(params, cache, tokens, pos, pt):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos,
                                        decode=True, page_table=pt)
            return logits[:, -1], cache

        def copy_block(cache, src, dst):
            # one physical block, every layer's K and V pool (axis 2 of the
            # group-stacked (g, Hkv, P, bk, hd) leaves)
            return jax.tree.map(
                lambda pool: jax.lax.dynamic_update_index_in_dim(
                    pool, jax.lax.dynamic_index_in_dim(
                        pool, src, axis=2, keepdims=False), dst, axis=2),
                cache)

        self._prefill_chunk = jax.jit(prefill_chunk, donate_argnums=(1,))
        self._prefill_tail = jax.jit(prefill_tail, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._copy_block = jax.jit(copy_block, donate_argnums=(0,))

    # -- prefix cache -------------------------------------------------------

    @staticmethod
    def _chain_hashes(prompt: np.ndarray, n: int, bk: int) -> list[str]:
        """Chained content hashes of the first ``n`` full blocks: block i's
        key commits to every token before it, so equal keys mean equal
        prefixes (not merely equal blocks)."""
        hs, h = [], "root"
        for c in range(n):
            h = hashlib.sha1(
                (h + "|" + prompt[c * bk:(c + 1) * bk].tobytes().hex())
                .encode()).hexdigest()
            hs.append(h)
        return hs

    def _evict_lru_entry(self) -> bool:
        """Drop the least-recently-used prefix-cache entry (either kind),
        releasing its block refs. Returns False when both caches are empty."""
        cands = [(use, "blk", k) for k, (_, use) in self._prefix.items()]
        cands += [(use, "full", k)
                  for k, (_, _, _, use) in self._full.items()]
        if not cands:
            return False
        _, kind, key = min(cands)
        if kind == "blk":
            phys, _ = self._prefix.pop(key)
            self._alloc_release(phys)
        else:
            shared, tail, _, _ = self._full.pop(key)
            for phys in shared:
                self._alloc_release(phys)
            if tail is not None:
                self._alloc_release(tail)
        self.stats["cache_evictions"] += 1
        return True

    def _alloc_release(self, blk: int) -> None:
        self.alloc.release(blk)

    def _get_block(self) -> Optional[int]:
        """Allocate a block, evicting LRU prefix-cache entries under
        pressure; None when the pool is truly exhausted."""
        while True:
            blk = self.alloc.alloc()
            if blk is not None:
                return blk
            if not self._evict_lru_entry():
                return None

    # -- admission ----------------------------------------------------------

    def _admit(self, req: Request, slot: int, cache, resume: np.ndarray):
        """Chunked block-aligned prefill of one request into ``slot``,
        reusing cached prefix blocks. Returns (cache, first_token, plen,
        budget) or (cache, None, 0, 0) when the pool cannot host the
        prompt right now (caller requeues)."""
        bk = self.block_size
        prompt = np.concatenate([np.asarray(req.prompt, np.int32), resume])
        plen = len(prompt)
        n_full = plen // bk
        t_real = plen - n_full * bk
        # the last chunk — partial, or the last full block when the prompt
        # is block-aligned — is always replayed privately: it produces the
        # admission's logits and is where decode will write
        n_shared = n_full - (1 if t_real == 0 and n_full > 0 else 0)
        tail_lo = n_shared * bk
        tl = plen - tail_lo                     # in (0, bk]
        hashes = self._chain_hashes(prompt, n_shared, bk)
        full_key = ((hashes[-1] if n_shared else "root")
                    + "|" + prompt[tail_lo:].tobytes().hex())
        table = self._tables[slot]
        table[:] = BlockAllocator.NULL
        taken: list[int] = []                   # refs to roll back on abort

        def abort():
            for phys in taken:
                self._alloc_release(phys)
            table[:] = BlockAllocator.SCRATCH
            return cache, None, 0, 0

        self._lru += 1
        full_ent = self._full.get(full_key) if self.prefix_cache else None
        if full_ent is not None:
            shared, tail_snap, first_tok, _ = full_ent
            self._full[full_key] = (shared, tail_snap, first_tok, self._lru)
            for c, phys in enumerate(shared):
                table[c] = self.alloc.ref(phys)
                taken.append(phys)
            # copy-on-write: decode writes into the tail block, so the
            # cached snapshot is duplicated into a private block first
            dst = self._get_block()
            if dst is None:
                return abort()
            taken.append(dst)
            table[n_shared] = dst
            with self._mesh_scope():
                cache = self._copy_block(cache, jnp.asarray(tail_snap),
                                         jnp.asarray(dst))
            self.stats["full_prompt_hits"] += 1
            self.stats["prefix_hit_blocks"] += n_shared + 1
            self.stats["prefix_lookup_blocks"] += n_shared + 1
            tok = first_tok
        else:
            m = 0
            while self.prefix_cache and m < n_shared \
                    and hashes[m] in self._prefix:
                phys, _ = self._prefix[hashes[m]]
                self._prefix[hashes[m]] = (phys, self._lru)
                table[m] = self.alloc.ref(phys)
                taken.append(phys)
                m += 1
            self.stats["prefix_hit_blocks"] += m
            if self.prefix_cache:
                self.stats["prefix_lookup_blocks"] += n_shared
            for c in range(m, n_shared + 1):
                blk = self._get_block()
                if blk is None:
                    return abort()
                taken.append(blk)
                table[c] = blk
            pt = jnp.asarray(table[None])
            with self._mesh_scope():
                for c in range(m, n_shared):
                    toks = jnp.asarray(prompt[None, c * bk:(c + 1) * bk])
                    _, cache = self._prefill_chunk(
                        self.params, cache, toks,
                        jnp.asarray(c * bk, jnp.int32), pt)
                    self.stats["prefill_chunks"] += 1
                tb = _bucket(tl)
                padded = np.zeros((1, tb), np.int32)
                padded[0, :tl] = prompt[tail_lo:]
                logits, cache = self._prefill_tail(
                    self.params, cache, jnp.asarray(padded),
                    jnp.asarray(tail_lo, jnp.int32), pt)
                self.stats["prefill_chunks"] += 1
            self.stats["prefills"] += 1
            tok = int(np.asarray(jnp.argmax(logits[0, tl - 1])))
            if self.prefix_cache:
                # publish the freshly computed full blocks, and snapshot
                # (tail block, first token) for exact-repeat admissions
                for c in range(m, n_shared):
                    self._prefix[hashes[c]] = (self.alloc.ref(table[c]),
                                               self._lru)
                if full_key not in self._full:
                    snap = self.alloc.alloc()   # best effort: no eviction
                    if snap is not None:
                        with self._mesh_scope():
                            cache = self._copy_block(
                                cache, jnp.asarray(int(table[n_shared])),
                                jnp.asarray(snap))
                        shared = tuple(self.alloc.ref(int(table[c]))
                                       for c in range(n_shared))
                        self._full[full_key] = (shared, snap, tok, self._lru)
        budget = max(0, min(req.max_new_tokens - len(resume),
                            self.max_seq - plen))
        return cache, tok, plen, budget

    def _release_slot(self, slot: int) -> None:
        table = self._tables[slot]
        for phys in table[table >= BlockAllocator.RESERVED]:
            self._alloc_release(int(phys))
        table[:] = BlockAllocator.SCRATCH

    # -- main loop ----------------------------------------------------------

    def run(self, requests: list[Request], arrivals=None,
            on_token: Optional[Callable[[int, int], None]] = None
            ) -> list[Request]:
        reqs = list(requests)
        n = len(reqs)
        arr = (np.zeros(n) if arrivals is None
               else np.asarray(arrivals, np.float64))
        assert len(arr) == n
        order = sorted(range(n), key=lambda j: (arr[j], j))
        qi = 0
        ready: list[int] = []                  # admission queue (indices)
        resume: dict[int, np.ndarray] = {}     # preempted: emitted-so-far
        slots = self.slots
        active = np.zeros(slots, bool)
        pos = np.zeros(slots, np.int32)
        cur = np.zeros(slots, np.int32)
        n_out = np.zeros(slots, np.int64)
        budget = np.zeros(slots, np.int64)
        ridx = np.full(slots, -1, np.int64)
        admit_seq = np.zeros(slots, np.int64)  # preemption picks the max
        outs: list[Optional[np.ndarray]] = [None] * slots
        self.alloc = BlockAllocator(self.n_blocks)
        self._tables = np.full((slots, self.n_logical),
                               BlockAllocator.SCRATCH, np.int32)
        self._prefix: dict[str, tuple[int, int]] = {}
        self._full: dict[str, tuple[tuple, Optional[int], int, int]] = {}
        self._lru = 0
        cache = init_paged_cache(self.cfg, self.n_blocks, self.block_size)
        self.stats = {"prefills": 0, "prefill_chunks": 0, "decode_steps": 0,
                      "tokens": 0, "occupancy_sum": 0, "rejected": 0,
                      "prefix_hit_blocks": 0, "prefix_lookup_blocks": 0,
                      "full_prompt_hits": 0, "cache_evictions": 0,
                      "preemptions": 0, "block_util_sum": 0.0,
                      "peak_blocks": 0}
        usable = self.n_blocks - BlockAllocator.RESERVED
        step = 0.0
        done = 0
        seq = 0

        def preempt_youngest() -> bool:
            live = np.flatnonzero(active)
            if not live.size:
                return False
            i = int(live[np.argmax(admit_seq[live])])
            j = int(ridx[i])
            resume[j] = np.asarray(outs[i][:n_out[i]], np.int32).copy()
            self._release_slot(i)
            active[i] = False
            pos[i] = 0
            ready.insert(0, j)
            self.stats["preemptions"] += 1
            return True

        while done < n:
            while qi < len(order) and arr[order[qi]] <= step:
                ready.append(order[qi])
                qi += 1
            # admit from the queue into free slots (chunked prefill each)
            while ready:
                free = np.flatnonzero(~active)
                if not free.size:
                    break
                i, j = int(free[0]), ready[0]
                res = resume.get(j, np.zeros(0, np.int32))
                plen_total = len(reqs[j].prompt) + len(res)
                if plen_total > self.max_seq:
                    # over-length (or preempted past the horizon): reject /
                    # finish with what was already emitted
                    ready.pop(0)
                    reqs[j].out = res
                    if not res.size:
                        self.stats["rejected"] += 1
                    resume.pop(j, None)
                    done += 1
                    continue
                cache, tok, p0, bud = self._admit(reqs[j], i, cache, res)
                if tok is None:
                    # pool exhausted: leave at queue head, back-pressure
                    break
                ready.pop(0)
                if bud <= 0:
                    reqs[j].out = res
                    resume.pop(j, None)
                    self._release_slot(i)
                    done += 1
                    continue
                seq += 1
                active[i] = True
                pos[i], cur[i] = p0, tok
                n_out[i], budget[i], ridx[i] = 0, bud, j
                admit_seq[i] = seq
                base = res
                outs[i] = np.concatenate(
                    [base, np.zeros(bud, np.int32)])
                n_out[i] = len(base)
                budget[i] = len(base) + bud
            if not active.any():
                if not ready and qi >= len(order):
                    break
                if not ready:
                    step = max(step, float(arr[order[qi]]))
                    continue
                raise RuntimeError(
                    f"KV pool ({usable} blocks) cannot host request "
                    f"{ready[0]} even with every slot idle")
            # emit the token from the previous model call; free finished
            for i in np.flatnonzero(active):
                outs[i][n_out[i]] = cur[i]
                n_out[i] += 1
                self.stats["tokens"] += 1
                if on_token:
                    on_token(int(ridx[i]), int(cur[i]))
                if n_out[i] >= budget[i]:
                    reqs[ridx[i]].out = outs[i][:n_out[i]].copy()
                    resume.pop(int(ridx[i]), None)
                    self._release_slot(i)
                    active[i] = False
                    done += 1
            if not active.any():
                continue
            # every live row needs its write-target block mapped before the
            # decode step touches position pos[i]
            for i in np.flatnonzero(active):
                bi = int(pos[i]) // self.block_size
                while self._tables[i, bi] < BlockAllocator.RESERVED:
                    blk = self._get_block()
                    if blk is not None:
                        self._tables[i, bi] = blk
                        break
                    if not preempt_youngest():
                        raise RuntimeError("KV pool exhausted mid-decode "
                                           "with nothing left to preempt")
                    if not active[i]:
                        break               # preempted ourselves
            live = np.flatnonzero(active)
            if not live.size:
                continue
            with self._mesh_scope():
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(cur)[:, None],
                    jnp.asarray(pos), jnp.asarray(self._tables))
            nxt = np.asarray(jnp.argmax(logits, -1))
            cur[live] = nxt[live]
            pos[live] += 1
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += int(live.size)
            self.stats["block_util_sum"] += self.alloc.n_used / usable
            self.stats["peak_blocks"] = max(self.stats["peak_blocks"],
                                            self.alloc.n_used)
            step += 1.0
        self.stats["occupancy"] = (
            self.stats["occupancy_sum"] / max(1, self.stats["decode_steps"]))
        self.stats["block_util"] = (
            self.stats["block_util_sum"] / max(1, self.stats["decode_steps"]))
        self.stats["prefix_hit_rate"] = (
            self.stats["prefix_hit_blocks"]
            / max(1, self.stats["prefix_lookup_blocks"]))
        return requests


class VisionServeEngine:
    """Batched image-inference serving: fixed-size waves through one jitted
    forward, mesh-aware like :class:`ServeEngine`.

    ``forward_fn(params, images, acfg) -> logits`` is any vision model
    forward (``repro.models.vision.cnn_forward`` / ``resnet_forward`` / ...);
    every conv inside it resolves a :func:`~repro.core.acu.conv_plan`, so
    with a LUT-Pallas ``acfg`` the whole stack rides the fused
    patch-streaming conv kernels — including ImageNet-scale (224^2) inputs,
    which since PR 4 resolve to the spatially-tiled kernel instead of
    reporting the eager-im2col VMEM fallback (``plan_report`` shows the
    chosen banding) — and with ``mesh=...`` the waves run under the
    ``acu_conv`` partition (batch x output-row bands over
    ``("pod", "data")``, output channels over ``("model",)``) — bit-for-bit
    the single-device logits.
    """

    def __init__(self, params, forward_fn: Callable, *, slots: int = 8,
                 acfg=None, mesh=None):
        self.params = params
        self.slots = slots
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)
        self._infer = jax.jit(lambda p, imgs: forward_fn(p, imgs, acfg))

    def plan_report(self, image_shape, w_shape, acfg, **geom) -> dict:
        """The conv route one layer takes under this engine's mesh scope
        (see :func:`repro.core.approx_ops.conv_plan_report`)."""
        from repro.core.approx_ops import conv_plan_report
        with self._mesh_scope():
            return conv_plan_report(image_shape, w_shape, acfg, **geom)

    def run(self, images: np.ndarray) -> np.ndarray:
        """images: (B, C, H, W) -> logits (B, n_classes), served in waves of
        ``slots`` (the last wave zero-padded and sliced)."""
        b = images.shape[0]
        outs = []
        for i in range(0, b, self.slots):
            wave = np.asarray(images[i:i + self.slots], np.float32)
            pad = self.slots - wave.shape[0]
            if pad:
                wave = np.concatenate(
                    [wave, np.zeros((pad, *wave.shape[1:]), wave.dtype)])
            with self._mesh_scope():
                logits = self._infer(self.params, jnp.asarray(wave))
            outs.append(np.asarray(logits)[:self.slots - pad])
        return np.concatenate(outs, axis=0)
