"""Serving engines: batched LM prefill/decode and batched vision inference.

Two LM engines share the jitted ``apply_model`` steps:

* :class:`ServeEngine` — *waves*: up to ``slots`` prompts are padded to a
  common length, prefilled in one batched call, then decoded in lockstep
  (one jit'd decode step per token for the whole batch). Per-request early
  stop masks finished rows, but a finished slot idles until the whole wave
  drains, and arrivals queue behind the current wave.
* :class:`ContinuousServeEngine` — true continuous batching: every slot
  advances at its *own* cache position (``cache_pos`` is a (slots,) vector;
  the KV append is a vmap'd per-row ``dynamic_update_slice``), a finished
  slot is evicted and refilled immediately (batch-1 bucketed prefill +
  jitted row insertion into the batched cache), so the decode batch stays
  full under load. Sustained tokens/s under a Poisson arrival trace is the
  ``[serve]`` benchmark's headline number.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import apply_model, init_cache
from repro.parallel.sharding import MeshContext, use_mesh, use_mesh_context


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    """``mesh`` (a ``jax.sharding.Mesh`` or an existing
    :class:`~repro.parallel.sharding.MeshContext`) activates mesh-aware
    execution for both jits: prefill/decode trace under
    :func:`~repro.parallel.sharding.use_mesh`, so every ``matmul_plan``
    inside `apply_model` resolves to its sharded route (and the models'
    logical-axis ``shard()`` annotations become real constraints) instead of
    silently running replicated. ``mesh=None`` keeps the single-device
    behavior bit-for-bit."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, acfg=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.acfg = acfg
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            # verbatim: a context whose rules omit keys means "replicated
            # there" — re-entering via use_mesh would re-merge DEFAULT_RULES
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill(params, cache, tokens, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=0,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        def decode(params, cache, tokens, pos, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos, decode=True,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _wave(self, reqs: list[Request],
              on_token: Optional[Callable[[int, int], None]]) -> None:
        b = self.slots
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        offs = np.zeros(b, np.int32)           # per-request left-pad counts
        valid = np.zeros((b, self.max_seq), bool)
        for i, r in enumerate(reqs):
            off = plen - len(r.prompt)
            toks[i, off:] = r.prompt           # left-pad
            offs[i] = off
            valid[i, off:] = True              # pad slots masked for the wave
        cache = init_cache(self.cfg, b, self.max_seq)
        offs_j, valid_j = jnp.asarray(offs), jnp.asarray(valid)
        with self._mesh_scope():
            logits, cache = self._prefill(self.params, cache,
                                          jnp.asarray(toks), offs_j, valid_j)
        cur = np.asarray(jnp.argmax(logits, -1))
        max_new = max(r.max_new_tokens for r in reqs)
        budget = max(0, min(max_new, self.max_seq - plen))
        out = np.zeros((b, budget), np.int32)  # preallocated (was O(n^2)
        n_out = np.zeros(b, np.int32)          # np.append per token)
        alive = np.ones(b, bool)
        for t in range(budget):
            for i in np.flatnonzero(alive):
                out[i, t] = cur[i]
                n_out[i] += 1
                if on_token:
                    on_token(int(i), int(cur[i]))
                if n_out[i] >= reqs[i].max_new_tokens:
                    alive[i] = False
            # no decode once every slot is done, nor for the step whose
            # logits nothing would consume (the old loop ran one extra)
            if not alive.any() or t == budget - 1:
                break
            with self._mesh_scope():
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur)[:, None],
                                             plen + t, offs_j, valid_j)
            cur = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(reqs):
            r.out = out[i, :n_out[i]].copy()

    def run(self, requests: list[Request],
            on_token: Optional[Callable[[int, int], None]] = None) -> list[Request]:
        """Serve all requests (waves of ``slots``); returns them with .out."""
        reqs = list(requests)
        for i in range(0, len(reqs), self.slots):
            wave = reqs[i:i + self.slots]
            while len(wave) < self.slots:       # pad the wave with a dummy
                wave.append(Request(prompt=np.zeros(1, np.int32),
                                    max_new_tokens=1))
            self._wave(wave, on_token)
        return requests


def _bucket(n: int, lo: int = 8) -> int:
    """Next power-of-two >= n (>= lo): bounds prefill recompiles to log2
    distinct prompt shapes."""
    b = lo
    while b < n:
        b *= 2
    return b


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times for ``n`` requests, in
    decode-step units (``rate`` = mean arrivals per decode step)."""
    rng = np.random.RandomState(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


class ContinuousServeEngine:
    """Continuous-batching LM serving: slot-level admission and eviction.

    Each incoming request is prefilled alone (prompt left-padded to a
    power-of-two bucket, so at most log2(max_seq) prefill shapes compile),
    its batch-1 cache row is inserted into the live batched cache by a
    jitted ``dynamic_update_slice``, and from then on the slot decodes in
    the shared batched step at its own cache position — ``cache_pos`` is a
    (slots,) vector and every attention layer appends KV with a vmap'd
    per-row update. A slot that exhausts its ``max_new_tokens`` (honored
    exactly, per request) is evicted the same step and its slot refilled by
    the next queued arrival, so unlike the wave engine no row idles behind
    the longest request in its batch.

    ``run(requests, arrivals=None)``: ``arrivals`` are request arrival
    times in decode-step units (``None`` = all at t=0); the engine's clock
    is the decode-step counter, so a trace replays deterministically.
    ``self.stats`` afterwards holds ``decode_steps``, ``prefills``,
    ``tokens`` and mean slot ``occupancy`` per decode step.

    Same mesh contract as :class:`ServeEngine`; with a LUT-Pallas ``acfg``
    every attention layer rides the fused approximate flash kernel
    (per-row ``rowinfo`` built from the position vector and pad mask).
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 max_seq: int = 512, acfg=None, mesh=None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.acfg = acfg
        self.stats: dict = {}
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)

        def prefill(params, cache, tokens, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=0,
                                        pos_offset=pos_offset,
                                        pad_mask=pad_mask, last_only=True)
            return logits[:, -1], cache

        def insert(cache, row, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1), cache, row)

        def decode(params, cache, tokens, pos, pos_offset, pad_mask):
            logits, cache = apply_model(params, tokens, cfg, acfg=acfg,
                                        cache=cache, cache_pos=pos,
                                        decode=True, pos_offset=pos_offset,
                                        pad_mask=pad_mask)
            return logits[:, -1], cache

        self._prefill = jax.jit(prefill)
        # no donation on insert: a fresh init_cache aliases its k/v leaves
        # (the same zeros array twice), which donation rejects
        self._insert = jax.jit(insert)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    def _admit(self, req: Request, slot: int, cache):
        """Prefill one request and insert its cache row at ``slot``.
        Returns (cache, first_token, next_pos, pad_off, budget)."""
        plen = len(req.prompt)
        bucket = min(_bucket(plen), self.max_seq)
        assert plen <= bucket, (plen, self.max_seq)
        off = bucket - plen
        toks = np.zeros((1, bucket), np.int32)
        toks[0, off:] = req.prompt
        valid = np.zeros((1, self.max_seq), bool)
        valid[0, off:] = True
        row_cache = init_cache(self.cfg, 1, self.max_seq)
        with self._mesh_scope():
            logits, row_cache = self._prefill(
                self.params, row_cache, jnp.asarray(toks),
                jnp.asarray([off], jnp.int32), jnp.asarray(valid))
            cache = self._insert(cache, row_cache,
                                 jnp.asarray(slot, jnp.int32))
        self.stats["prefills"] += 1
        tok = int(np.asarray(jnp.argmax(logits[0])))
        budget = max(0, min(req.max_new_tokens, self.max_seq - bucket))
        return cache, tok, bucket, off, budget

    def run(self, requests: list[Request], arrivals=None,
            on_token: Optional[Callable[[int, int], None]] = None
            ) -> list[Request]:
        reqs = list(requests)
        n = len(reqs)
        arr = (np.zeros(n) if arrivals is None
               else np.asarray(arrivals, np.float64))
        assert len(arr) == n
        order = sorted(range(n), key=lambda j: (arr[j], j))
        qi = 0
        slots = self.slots
        active = np.zeros(slots, bool)
        pos = np.zeros(slots, np.int32)
        offs = np.zeros(slots, np.int32)
        valid = np.zeros((slots, self.max_seq), bool)
        cur = np.zeros(slots, np.int32)
        n_out = np.zeros(slots, np.int64)
        budget = np.zeros(slots, np.int64)
        ridx = np.full(slots, -1, np.int64)
        outs: list[Optional[np.ndarray]] = [None] * slots
        cache = init_cache(self.cfg, slots, self.max_seq)
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0,
                      "occupancy_sum": 0}
        step = 0.0  # decode-step clock
        done = 0
        while done < n:
            # admit queued arrivals into free slots (one prefill each)
            while qi < len(order) and arr[order[qi]] <= step:
                free = np.flatnonzero(~active)
                if not free.size:
                    break
                i, j = int(free[0]), order[qi]
                qi += 1
                cache, tok, p0, off, bud = self._admit(reqs[j], i, cache)
                if bud <= 0:       # prompt fills max_seq: nothing to emit
                    reqs[j].out = np.zeros(0, np.int32)
                    done += 1
                    continue
                active[i] = True
                pos[i], offs[i], cur[i] = p0, off, tok
                valid[i] = False
                valid[i, off:] = True
                n_out[i], budget[i], ridx[i] = 0, bud, j
                outs[i] = np.zeros(bud, np.int32)
            if not active.any():
                if qi >= len(order):
                    break
                step = max(step, float(arr[order[qi]]))  # idle: jump clock
                continue
            # emit the token produced by the previous model call; evict
            # slots that hit their per-request budget the same step
            for i in np.flatnonzero(active):
                outs[i][n_out[i]] = cur[i]
                n_out[i] += 1
                self.stats["tokens"] += 1
                if on_token:
                    on_token(int(ridx[i]), int(cur[i]))
                if n_out[i] >= budget[i]:
                    reqs[ridx[i]].out = outs[i][:n_out[i]].copy()
                    active[i] = False
                    done += 1
            if not active.any():
                continue
            with self._mesh_scope():
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(cur)[:, None],
                    jnp.asarray(pos), jnp.asarray(offs), jnp.asarray(valid))
            nxt = np.asarray(jnp.argmax(logits, -1))
            live = np.flatnonzero(active)
            cur[live] = nxt[live]
            pos[live] += 1
            self.stats["decode_steps"] += 1
            self.stats["occupancy_sum"] += int(live.size)
            step += 1.0
        self.stats["occupancy"] = (
            self.stats["occupancy_sum"] / max(1, self.stats["decode_steps"]))
        return requests


class VisionServeEngine:
    """Batched image-inference serving: fixed-size waves through one jitted
    forward, mesh-aware like :class:`ServeEngine`.

    ``forward_fn(params, images, acfg) -> logits`` is any vision model
    forward (``repro.models.vision.cnn_forward`` / ``resnet_forward`` / ...);
    every conv inside it resolves a :func:`~repro.core.acu.conv_plan`, so
    with a LUT-Pallas ``acfg`` the whole stack rides the fused
    patch-streaming conv kernels — including ImageNet-scale (224^2) inputs,
    which since PR 4 resolve to the spatially-tiled kernel instead of
    reporting the eager-im2col VMEM fallback (``plan_report`` shows the
    chosen banding) — and with ``mesh=...`` the waves run under the
    ``acu_conv`` partition (batch x output-row bands over
    ``("pod", "data")``, output channels over ``("model",)``) — bit-for-bit
    the single-device logits.
    """

    def __init__(self, params, forward_fn: Callable, *, slots: int = 8,
                 acfg=None, mesh=None):
        self.params = params
        self.slots = slots
        if mesh is None:
            self._mesh_scope = contextlib.nullcontext
        elif isinstance(mesh, MeshContext):
            self._mesh_scope = lambda: use_mesh_context(mesh)
        else:
            self._mesh_scope = lambda: use_mesh(mesh)
        self._infer = jax.jit(lambda p, imgs: forward_fn(p, imgs, acfg))

    def plan_report(self, image_shape, w_shape, acfg, **geom) -> dict:
        """The conv route one layer takes under this engine's mesh scope
        (see :func:`repro.core.approx_ops.conv_plan_report`)."""
        from repro.core.approx_ops import conv_plan_report
        with self._mesh_scope():
            return conv_plan_report(image_shape, w_shape, acfg, **geom)

    def run(self, images: np.ndarray) -> np.ndarray:
        """images: (B, C, H, W) -> logits (B, n_classes), served in waves of
        ``slots`` (the last wave zero-padded and sliced)."""
        b = images.shape[0]
        outs = []
        for i in range(0, b, self.slots):
            wave = np.asarray(images[i:i + self.slots], np.float32)
            pad = self.slots - wave.shape[0]
            if pad:
                wave = np.concatenate(
                    [wave, np.zeros((pad, *wave.shape[1:]), wave.dtype)])
            with self._mesh_scope():
                logits = self._infer(self.params, jnp.asarray(wave))
            outs.append(np.asarray(logits)[:self.slots - pad])
        return np.concatenate(outs, axis=0)
