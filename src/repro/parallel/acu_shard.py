"""Mesh-aware execution of ACU GEMM plans (the second level of dispatch).

``core/acu.py`` resolves *what* kernel runs (mode x fused); this module
resolves *where*: with an active :class:`~repro.parallel.sharding.MeshContext`
every plan is wrapped in a ``shard_map`` that

* replicates the (2^b, 2^b) product table (<= 256 KiB) to every device,
* shards activation/output rows over the ``acu_rows`` axes (``("pod",
  "data")`` by default), weight/output columns over ``acu_cols``
  (``("model",)``),
* optionally shards the contraction dim over ``acu_k`` and psum-reduces the
  int32 partial accumulators *before* dequant,
* pads M/N/K up to the axis products and slices the result back — padding
  rows/columns only produce discarded outputs, while the K shard-padding
  contributes ``M[0, 0]`` per padded k and is corrected **exactly once
  globally** (after the psum), not once per shard.

Everything stays bit-exact against the single-device kernels: each local
kernel sees the full contraction (or an exact K slice whose int32 partials
add associatively), so the int accumulators — and hence the dequantized
floats — are identical element-for-element.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .planner import (GemmPartition, acu_attn_partition, acu_conv_partition,
                      acu_gemm_partition, acu_grouped_partition)
from .sharding import MeshContext

Array = jnp.ndarray


def resolve_partition(ctx: MeshContext, *, float_accum: bool = False
                      ) -> Optional[GemmPartition]:
    """Partition for the active mesh, or None when every axis is trivial
    (1x1 host mesh: the wrap would be a no-op, so the plan stays local)."""
    part, _ = acu_gemm_partition(ctx, float_accum=float_accum)
    return part if part.total > 1 else None


def resolve_conv_partition(ctx: MeshContext, *, float_accum: bool = False
                           ) -> Optional[GemmPartition]:
    """The ``acu_conv`` partition for the active mesh (rows = batch x
    output pixels, cols = output channels, k = input channels), or None when
    every axis is trivial."""
    part, _ = acu_conv_partition(ctx, float_accum=float_accum)
    return part if part.total > 1 else None


def resolve_attn_partition(ctx: MeshContext, *, hq: int, hkv: int
                           ) -> Optional[GemmPartition]:
    """The ``acu_attn`` partition for the active mesh (rows = batch, cols =
    KV heads with whole GQA groups per shard), or None when every axis is
    trivial."""
    part, _ = acu_attn_partition(ctx, hq=hq, hkv=hkv)
    return part if part.total > 1 else None


def resolve_grouped_partition(ctx: MeshContext, *, n_experts: int,
                              n_blocks: int) -> Optional[GemmPartition]:
    """The ``acu_grouped`` partition for the active mesh (rows = dispatch
    blocks, cols = whole experts per shard, k = opt-in contraction), or None
    when every axis is trivial."""
    part, _ = acu_grouped_partition(ctx, n_experts=n_experts,
                                    n_blocks=n_blocks)
    return part if part.total > 1 else None


def _pad2(x: Array, pr: int, pc: int) -> Array:
    return jnp.pad(x, ((0, pr), (0, pc))) if (pr or pc) else x


def wrap_attn(attn_call: Callable[..., Array], ctx: MeshContext,
              part: GemmPartition, *, hq: int, hkv: int
              ) -> Callable[..., Array]:
    """Shard an approximate attention plan
    ``fn(q, k, v, qs, ks, vs, rowinfo) -> (B, Hq, Sq, D) f32``.

    ``q``: (B, Hq, Sq, D) float; ``k``/``v``: (B, Hkv, Sk, D);
    ``rowinfo``: (B, 3) int32 ``[q_base, kv_start, kv_len]`` rows (one per
    batch row — heads of a sequence share its cache geometry). Batch rows
    shard over ``part.rows``, KV heads over ``part.cols`` — each shard gets
    whole GQA groups (``rep`` query heads per KV head), runs the full fused
    kernel on its (B_loc * Hq_loc) fold, and there are no collectives: the
    kernel grid is embarrassingly parallel over (batch*head, q_block), so
    the wrap is bit-exact by construction. Scales are computed by the
    caller on the FULL tensors and replicated — every shard sees identical
    quantization. Padded batch rows carry rowinfo ``[0, 0, 0]``: every key
    masked, finite garbage output, sliced off here.
    """
    mesh = ctx.mesh
    assert hq % hkv == 0 and hkv % part.n_cols == 0, (hq, hkv, part.n_cols)

    def fn(q: Array, k: Array, v: Array, qs, ks, vs, rowinfo: Array) -> Array:
        b, _, sq, d = q.shape
        pb = (-b) % part.n_rows
        if pb:
            q = jnp.pad(q, ((0, pb), (0, 0), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, pb), (0, 0), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, pb), (0, 0), (0, 0), (0, 0)))
            rowinfo = jnp.pad(rowinfo, ((0, pb), (0, 0)))
        qs_a = jnp.asarray(qs, jnp.float32).reshape(1)
        ks_a = jnp.asarray(ks, jnp.float32).reshape(1)
        vs_a = jnp.asarray(vs, jnp.float32).reshape(1)

        rows = part._dim(part.rows)
        cols = part._dim(part.cols)

        def local(q_blk, k_blk, v_blk, qs_b, ks_b, vs_b, info_blk):
            bl, hql = q_blk.shape[0], q_blk.shape[1]
            info = jnp.repeat(info_blk, hql, axis=0)     # (bl*hql, 3)
            out = attn_call(
                q_blk.reshape(bl * hql, *q_blk.shape[2:]),
                k_blk.reshape(bl * k_blk.shape[1], *k_blk.shape[2:]),
                v_blk.reshape(bl * v_blk.shape[1], *v_blk.shape[2:]),
                qs_b, ks_b, vs_b, info)
            return out.reshape(bl, hql, *out.shape[1:])

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(rows, cols, None, None), P(rows, cols, None, None),
                      P(rows, cols, None, None), P(None), P(None), P(None),
                      P(rows, None)),
            out_specs=P(rows, cols, None, None), check_rep=False,
        )(q, k, v, qs_a, ks_a, vs_a, rowinfo)
        return out[:b]

    return fn


def wrap_attn_paged(attn_call: Callable[..., Array], ctx: MeshContext,
                    part: GemmPartition, *, hq: int, hkv: int
                    ) -> Callable[..., Array]:
    """Shard a paged approximate attention plan
    ``fn(q, k_pool, v_pool, qs, ks, vs, rowinfo, page_table) ->
    (B, Hq, Sq, D) f32``.

    Same geometry as :func:`wrap_attn` — batch rows over ``part.rows``,
    KV heads over ``part.cols`` in whole GQA groups, no collectives — with
    the paged twists: the ``(Hkv, P, bk, D)`` physical pools shard over
    ``part.cols`` on their head axis and REPLICATE over the row axes (every
    batch shard reads the same pool), while the ``(B, n_logical)`` page
    table shards with the batch rows like ``rowinfo`` and replicates over
    the head axis — the table is head-independent by construction (one
    pool row per KV head, same block ids). The local fold keeps the global
    ``rep``: with ``hql = hq/n_cols`` local query heads and
    ``hkv_loc = hkv/n_cols`` local pool rows, the kernel's
    ``(b // rep) % hkv_loc`` lands each local query head on its own KV
    head for every batch index. Padded batch rows carry rowinfo
    ``[0, 0, 0]`` and an all-zeros page table (physical block 0 — the
    engine's permanently-zero null block): every key masked, finite
    garbage, sliced off here.
    """
    mesh = ctx.mesh
    assert hq % hkv == 0 and hkv % part.n_cols == 0, (hq, hkv, part.n_cols)

    def fn(q: Array, k_pool: Array, v_pool: Array, qs, ks, vs,
           rowinfo: Array, page_table: Array) -> Array:
        b = q.shape[0]
        pb = (-b) % part.n_rows
        if pb:
            q = jnp.pad(q, ((0, pb), (0, 0), (0, 0), (0, 0)))
            rowinfo = jnp.pad(rowinfo, ((0, pb), (0, 0)))
            page_table = jnp.pad(page_table, ((0, pb), (0, 0)))
        qs_a = jnp.asarray(qs, jnp.float32).reshape(1)
        ks_a = jnp.asarray(ks, jnp.float32).reshape(1)
        vs_a = jnp.asarray(vs, jnp.float32).reshape(1)

        rows = part._dim(part.rows)
        cols = part._dim(part.cols)

        def local(q_blk, kp_blk, vp_blk, qs_b, ks_b, vs_b, info_blk, pt_blk):
            bl, hql = q_blk.shape[0], q_blk.shape[1]
            info = jnp.repeat(info_blk, hql, axis=0)     # (bl*hql, 3)
            pt = jnp.repeat(pt_blk, hql, axis=0)         # (bl*hql, n_log)
            out = attn_call(
                q_blk.reshape(bl * hql, *q_blk.shape[2:]),
                kp_blk, vp_blk, qs_b, ks_b, vs_b, info, pt)
            return out.reshape(bl, hql, *out.shape[1:])

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(rows, cols, None, None),
                      P(cols, None, None, None), P(cols, None, None, None),
                      P(None), P(None), P(None),
                      P(rows, None), P(rows, None)),
            out_specs=P(rows, cols, None, None), check_rep=False,
        )(q, k_pool, v_pool, qs_a, ks_a, vs_a, rowinfo, page_table)
        return out[:b]

    return fn


def wrap_unfused(base_fn: Callable[[Array, Array], Array], ctx: MeshContext,
                 part: GemmPartition, m00: int) -> Callable[[Array, Array], Array]:
    """Shard an unfused integer-operand GEMM ``fn(a, w) -> acc``.

    ``m00`` is the multiplier's product at shifted code (0, 0) — what every
    K shard-pad entry contributes to the accumulator.
    """
    mesh = ctx.mesh

    def fn(a: Array, w: Array) -> Array:
        M, K = a.shape
        N = w.shape[1]
        pm, pk, pn = (-M) % part.n_rows, (-K) % part.n_k, (-N) % part.n_cols
        a_p = _pad2(a, pm, pk)          # code 0 == shifted zero-point
        w_p = _pad2(w, pk, pn)

        def local(a_blk, w_blk):
            acc = base_fn(a_blk, w_blk)
            if part.k:
                acc = jax.lax.psum(acc, part.k)
            return acc

        out = shard_map(local, mesh=mesh,
                        in_specs=(part.a_spec(), part.w_spec()),
                        out_specs=part.out_spec(), check_rep=False)(a_p, w_p)
        if pk and m00:
            # global K shard-padding correction: applied once, after the
            # psum — each pad entry contributed m00 to exactly one k shard
            out = out - jnp.asarray(pk * m00, out.dtype)
        return out[:M, :N]

    return fn


def wrap_fused(fused_call: Callable[..., Array],
               acc_call: Callable[..., Array], ctx: MeshContext,
               part: GemmPartition, m00: int) -> Callable[..., Array]:
    """Shard a fused quantize->LUT-GEMM->dequant plan
    ``fn(x, wq, xs, xz, ws) -> f32``.

    Without K sharding each shard runs the full fused kernel (dequant stays
    in-kernel). With K sharding the kernel emits the raw int32 accumulator
    (``acc_call``), partials psum in integer space, the global K-pad
    correction lands once, and the dequant — the same ``acc * xs * ws``
    expression the kernel uses — runs on the reduced accumulator.
    """
    mesh = ctx.mesh

    def fn(x: Array, wq: Array, xs, xz, ws) -> Array:
        M, K = x.shape
        N = wq.shape[1]
        pm, pk, pn = (-M) % part.n_rows, (-K) % part.n_k, (-N) % part.n_cols
        x_p = _pad2(x, pm, pk)          # 0.0 quantizes to the zero-point
        wq_p = _pad2(wq, pk, pn)        # shifted code 0
        ws_row = jnp.broadcast_to(
            jnp.asarray(ws, jnp.float32).reshape(1, -1), (1, N))
        ws_p = _pad2(ws_row, 0, pn)
        xs_a = jnp.asarray(xs, jnp.float32).reshape(1)
        xz_a = jnp.asarray(xz, jnp.float32).reshape(1)

        if not part.k:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk):
                return fused_call(x_blk, wq_blk, xs_b, xz_b, ws_blk[0])
        else:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk):
                acc = acc_call(x_blk, wq_blk, xs_b, xz_b, ws_blk[0])
                acc = jax.lax.psum(acc, part.k)
                if pk and m00:
                    acc = acc - jnp.asarray(pk * m00, acc.dtype)
                # same single combined-scale multiply as the kernel's in-VMEM
                # dequant — bit-exact vs the single-device output
                return acc.astype(jnp.float32) * (xs_b[0] * ws_blk)

        out = shard_map(
            local, mesh=mesh,
            in_specs=(part.a_spec(), part.w_spec(), P(None), P(None),
                      P(None, part._dim(part.cols))),
            out_specs=part.out_spec(), check_rep=False,
        )(x_p, wq_p, xs_a, xz_a, ws_p)
        return out[:M, :N]

    return fn


def wrap_fused_bwd(bwd_call: Callable[..., Array],
                   acc_call: Callable[..., Array], ctx: MeshContext,
                   part: GemmPartition, m00: int) -> Callable[..., Array]:
    """Shard a fused approximate-backward GEMM
    ``fn(a, b, sa, sb) -> f32 (M, N)``.

    Both operands are float residuals quantized *inside* the kernel with
    per-tensor symmetric scales computed by the caller on the full tensors
    (outside this wrap — every shard must see the same scale). ``part`` is a
    permuted forward partition (:func:`~repro.parallel.planner.
    bwd_gemm_partitions`), so the contraction axes here are the forward's
    rows or cols axes. Without contraction sharding each shard runs the full
    fused kernel; with it the kernel emits raw int32 partials (``acc_call``),
    they psum in integer space, the K shard-padding correction — zero pads
    quantize to code 0, contributing ``M[0, 0]`` each — lands exactly once
    after the collective, and the single combined-scale dequant runs on the
    reduced accumulator. Bit-exact vs the single-device kernel.
    """
    mesh = ctx.mesh

    def fn(a: Array, b: Array, sa, sb) -> Array:
        M, K = a.shape
        N = b.shape[1]
        pm, pk, pn = (-M) % part.n_rows, (-K) % part.n_k, (-N) % part.n_cols
        a_p = _pad2(a, pm, pk)      # 0.0 quantizes to code 0 (symmetric)
        b_p = _pad2(b, pk, pn)
        sa_a = jnp.asarray(sa, jnp.float32).reshape(1)
        sb_a = jnp.asarray(sb, jnp.float32).reshape(1)

        if not part.k:
            def local(a_blk, b_blk, sa_b, sb_b):
                return bwd_call(a_blk, b_blk, sa_b, sb_b)
        else:
            def local(a_blk, b_blk, sa_b, sb_b):
                acc = acc_call(a_blk, b_blk, sa_b, sb_b)
                acc = jax.lax.psum(acc, part.k)
                if pk and m00:
                    acc = acc - jnp.asarray(pk * m00, acc.dtype)
                # same single combined-scale multiply as the kernel's
                # in-VMEM dequant, with the scale product pinned to one f32
                # rounding: both factors are scalars here, and the jitted
                # SPMD program otherwise reassociates acc * sa * sb
                from repro.core.quantization import pin_rounding
                return acc.astype(jnp.float32) * pin_rounding(sa_b[0] * sb_b[0])

        out = shard_map(
            local, mesh=mesh,
            in_specs=(part.a_spec(), part.w_spec(), P(None), P(None)),
            out_specs=part.out_spec(), check_rep=False,
        )(a_p, b_p, sa_a, sb_a)
        return out[:M, :N]

    return fn


def wrap_fused_grouped(grouped_call: Callable[..., Array],
                       acc_call: Callable[..., Array], ctx: MeshContext,
                       part: GemmPartition, m00: int, *, n_experts: int
                       ) -> Callable[..., Array]:
    """Shard a fused grouped ragged GEMM plan
    ``fn(xe, wq, xs, xz, ws, counts) -> (G, C, N) f32``.

    ``xe``: (G, C, K) dispatched capacity buffers with ``G = nb * E`` groups
    laid out block-major — reshaped to (nb, E, C, K) here so dispatch blocks
    shard over ``part.rows`` and experts over ``part.cols`` (expert
    parallelism). Each shard keeps whole experts and whole dispatch blocks
    (the partition resolver drops non-dividing axes), so the local group ->
    expert mapping ``g % E_loc`` of the flattened (nb_loc * E_loc) slice is
    exactly the global mapping restricted to the shard, the LUT and the
    shared activation scale replicate, and the groupinfo counts ride with
    their groups. Without K sharding each shard runs the full fused kernel
    (dead-row masking stays in-kernel). With K sharding the kernel emits the
    masked int32 accumulator (``acc_call``), partials psum in integer space,
    the global K-pad correction lands once — which un-zeroes the dead rows,
    so the live-row mask is re-applied after the dequant. Bit-exact vs the
    single-device grouped kernel.
    """
    mesh = ctx.mesh

    def fn(xe: Array, wq: Array, xs, xz, ws, counts: Array) -> Array:
        G, C, K = xe.shape
        E, _, N = wq.shape
        assert E == n_experts and G % E == 0, (G, E, n_experts)
        nb = G // E
        assert nb % part.n_rows == 0 and E % part.n_cols == 0, (
            f"partition {part.n_rows}x{part.n_cols} does not divide "
            f"blocks={nb} experts={E} (resolver should have dropped axes)")
        pk = (-K) % part.n_k
        x4 = xe.reshape(nb, E, C, K)
        if pk:  # 0.0 quantizes to the zero-point -> shifted code 0
            x4 = jnp.pad(x4, ((0, 0), (0, 0), (0, 0), (0, pk)))
            wq = jnp.pad(wq, ((0, 0), (0, pk), (0, 0)))
        ws_e = jnp.broadcast_to(
            jnp.asarray(ws, jnp.float32).reshape(E, -1), (E, N))
        xs_a = jnp.asarray(xs, jnp.float32).reshape(1)
        xz_a = jnp.asarray(xz, jnp.float32).reshape(1)
        cnt = jnp.asarray(counts, jnp.int32).reshape(nb, E)

        rows = part._dim(part.rows)
        cols = part._dim(part.cols)
        kdim = part._dim(part.k)

        if not part.k:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk, cnt_blk):
                nbl, el = x_blk.shape[0], x_blk.shape[1]
                out = grouped_call(
                    x_blk.reshape(nbl * el, *x_blk.shape[2:]), wq_blk,
                    xs_b, xz_b, ws_blk, cnt_blk.reshape(-1))
                return out.reshape(nbl, el, *out.shape[1:])
        else:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk, cnt_blk):
                nbl, el = x_blk.shape[0], x_blk.shape[1]
                acc = acc_call(
                    x_blk.reshape(nbl * el, *x_blk.shape[2:]), wq_blk,
                    xs_b, xz_b, ws_blk, cnt_blk.reshape(-1))
                acc = jax.lax.psum(acc, part.k)
                if pk and m00:
                    acc = acc - jnp.asarray(pk * m00, acc.dtype)
                # same single combined-scale multiply as the kernel's in-VMEM
                # dequant; then re-mask — the uniform pad correction gave the
                # dead rows (zeroed in integer space per shard) -pk*m00
                deq = (acc.reshape(nbl, el, *acc.shape[1:]).astype(jnp.float32)
                       * (xs_b[0] * ws_blk)[None, :, None, :])
                live = (jnp.arange(deq.shape[2])[None, None, :]
                        < cnt_blk[:, :, None])
                return jnp.where(live[..., None], deq, 0.0)

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(rows, cols, None, kdim), P(cols, kdim, None),
                      P(None), P(None), P(cols, None), P(rows, cols)),
            out_specs=P(rows, cols, None, None), check_rep=False,
        )(x4, wq, xs_a, xz_a, ws_e, cnt)
        return out.reshape(G, C, N)

    return fn


def _conv_band_ways(n: int, ho: int, n_rows: int) -> int:
    """Output-row band ways for the conv rows partition: when the batch
    alone cannot fill the ``acu_conv_rows`` axes (N < n_rows with N | n_rows),
    each image's output rows split into ``n_rows // N`` halo'd bands so the
    spare devices compute spatial bands instead of padding images."""
    if n >= n_rows or n_rows % n != 0:
        return 1
    bw = n_rows // n
    return bw if ho >= bw else 1


def wrap_fused_conv(conv_call: Callable[..., Array],
                    acc_call: Callable[..., Array], ctx: MeshContext,
                    part: GemmPartition, m00: int, n_taps: int, *,
                    spec=None) -> Callable[..., Array]:
    """Shard a fused patch-streaming conv plan
    ``fn(x, wq, xs, xz, ws) -> (N, Ho, Wo, Cout) f32``.

    ``x``: (N, C, H, W) float; ``wq``: (Cout, C, kh, kw) shifted weight
    codes. The *batch x output-row-band* dim shards over ``part.rows`` (the
    output-pixel rows of the implicit im2col GEMM follow their image — and,
    when the batch alone cannot fill the rows axes, each image splits into
    halo'd output-row bands, each shard slicing its own slab inside the
    ``shard_map``, so e.g. a single 224^2 image still uses every rows-axis
    device). Output channels
    shard over ``part.cols``, and the LUT replicates — every shard runs the
    full fused kernel (whole-image or spatially tiled) on its
    (batch x band, Cout) tile, so there are no collectives and the wrap is
    bit-exact by construction: band slabs carry their own halo rows, and
    int32 tap accumulation is order-independent. With ``part.k`` the *input
    channels* split: each shard's kernel emits its raw int32 partial
    accumulator (``acc_call``), partials psum in integer space, and the
    global channel-shard-padding correction — ``pad_c * n_taps * M[0, 0]``,
    one ``M[0, 0]`` per padded channel per kernel tap — lands exactly once,
    after the collective, before the single combined-scale dequant.

    ``n_taps`` is ``kh * kw`` (each padded channel feeds every tap).
    ``spec`` is the plan's :class:`~repro.core.acu.ConvSpec`; band
    partitioning needs its static geometry and is skipped when absent.
    """
    mesh = ctx.mesh

    def fn(x: Array, wq: Array, xs, xz, ws) -> Array:
        n, c, h = x.shape[0], x.shape[1], x.shape[2]
        cout = wq.shape[0]
        band_ways = 1
        if spec is not None and part.rows:
            band_ways = _conv_band_ways(n, spec.out_spatial[0], part.n_rows)
        pk = (-c) % part.n_k
        pn = (-cout) % part.n_cols

        if band_ways > 1:
            # halo'd band sharding: conv row padding materializes here
            # (zeros), each shard dynamic-slices its own slab inside the
            # shard_map from its rows-axis index — slab extraction must not
            # go through an XLA concat feeding the shard_map (the SPMD
            # partitioner mis-reshards concat-of-slices), and on real
            # hardware this is where a halo exchange would go
            (ph0, _), (pw0, pw1) = spec.padding
            sh = spec.stride[0]
            kh = spec.w_shape[2]
            dh = spec.dilation[0]
            ho, _ = spec.out_spatial
            ho_band = -(-ho // band_ways)
            slab_rows = (ho_band - 1) * sh + (kh - 1) * dh + 1
            rows_needed = (band_ways - 1) * ho_band * sh + slab_rows
            x = jnp.pad(x, ((0, 0), (0, pk),
                            (ph0, max(0, rows_needed - h - ph0)), (0, 0)))
            x = x[:, :, :rows_needed]   # rows past the last slab: never read
            pb = 0
            call_kw = {"padding": ((0, 0), (pw0, pw1))}

            def extract(x_blk):
                r = 0
                for a in part.rows:     # linear index along the rows axes
                    r = r * mesh.shape[a] + jax.lax.axis_index(a)
                b_idx = r // band_ways
                band = r % band_ways
                return jax.lax.dynamic_slice(
                    x_blk, (b_idx, 0, band * ho_band * sh, 0),
                    (1, x_blk.shape[1], slab_rows, x_blk.shape[3]))
        else:
            pb = (-n) % part.n_rows
            if pb or pk:
                x = jnp.pad(x, ((0, pb), (0, pk), (0, 0), (0, 0)))
            call_kw = {}
            extract = lambda x_blk: x_blk

        if pn or pk:  # pad channels: shifted code 0; pad couts: discarded
            wq = jnp.pad(wq, ((0, pn), (0, pk), (0, 0), (0, 0)))
        ws_row = jnp.broadcast_to(
            jnp.asarray(ws, jnp.float32).reshape(1, -1), (1, cout))
        if pn:
            ws_row = jnp.pad(ws_row, ((0, 0), (0, pn)))
        xs_a = jnp.asarray(xs, jnp.float32).reshape(1)
        xz_a = jnp.asarray(xz, jnp.float32).reshape(1)

        rows = part._dim(part.rows)
        cols = part._dim(part.cols)
        kdim = part._dim(part.k)
        # banded: the image batch replicates over the rows axes (each shard
        # carves out its slab); otherwise the batch dim itself shards
        x_rows = None if band_ways > 1 else rows

        if not part.k:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk):
                return conv_call(extract(x_blk), wq_blk, xs_b, xz_b,
                                 ws_blk[0], **call_kw)
        else:
            def local(x_blk, wq_blk, xs_b, xz_b, ws_blk):
                acc = acc_call(extract(x_blk), wq_blk, xs_b, xz_b,
                               ws_blk[0], **call_kw)
                acc = jax.lax.psum(acc, part.k)
                if pk and m00:
                    # global channel-shard-padding correction: each padded
                    # channel contributed m00 through every tap, to exactly
                    # one channel shard — corrected once, after the psum
                    acc = acc - jnp.asarray(pk * n_taps * m00, acc.dtype)
                # same single combined-scale multiply as the in-kernel dequant
                return acc.astype(jnp.float32) * \
                    (xs_b[0] * ws_blk).reshape(1, 1, 1, -1)

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(x_rows, kdim, None, None), P(cols, kdim, None, None),
                      P(None), P(None), P(None, cols)),
            out_specs=P(rows, None, None, cols), check_rep=False,
        )(x, wq, xs_a, xz_a, ws_row)
        if band_ways > 1:
            ho, wo = spec.out_spatial
            out = out[:, :, :, :cout]
            out = out.reshape(n, band_ways * out.shape[1], wo, cout)
            return out[:, :ho]
        return out[:n, :, :, :cout]

    return fn


def wrap_conv_bwd_w(acc_call: Callable[..., Array], ctx: MeshContext,
                    part: GemmPartition, spec) -> Callable[..., Array]:
    """Shard the banded approximate conv weight-grad
    ``fn(xf, g, sx, sg) -> (kh*kw, Cin, Cout) int32``.

    The weight-grad contracts over output pixels — the *rows* of the conv
    partition — so the batch x output-row-band dim shards over ``part.rows``
    (halo'd band slabs, same machinery as the forward's
    :func:`wrap_fused_conv`) and the per-shard int32 partials **psum over
    the rows axes**. Output channels shard over ``part.cols`` and input
    channels over ``part.k`` — both are *output* dims of gw, so they carve
    the accumulator without collectives, staying sharded exactly as the
    forward left them. There is no pad-correction term at all: padded batch
    images and dead band-slab rows carry a zero ``rmask`` (the kernel masks
    them multiplicatively, because an invalid row contributes the
    non-constant ``M[x, 0]``), and padded cin/cout only produce discarded
    accumulator slices. ``acc_call(x, g, rmask, sx, sg, padding)`` is the
    single-device banded kernel wrapper; bit-exactness is by construction —
    int32 pixel partials add associatively across shards.
    """
    mesh = ctx.mesh

    def fn(xf: Array, g: Array, sx, sg) -> Array:
        n, c, h = xf.shape[0], xf.shape[1], xf.shape[2]
        cout = g.shape[3]
        kh = spec.w_shape[2]
        ho, wo = spec.out_spatial
        band_ways = 1
        if part.rows:
            band_ways = _conv_band_ways(n, ho, part.n_rows)
        pk = (-c) % part.n_k
        pn = (-cout) % part.n_cols
        sh = spec.stride[0]
        dh = spec.dilation[0]
        (ph0, _), (pw0, pw1) = spec.padding

        if band_ways > 1:
            # conv row padding materializes here (zeros); each shard
            # dynamic-slices its halo'd slab from its rows-axis index —
            # never an XLA concat feeding the shard_map
            ho_band = -(-ho // band_ways)
            slab_rows = (ho_band - 1) * sh + (kh - 1) * dh + 1
            rows_needed = (band_ways - 1) * ho_band * sh + slab_rows
            xf = jnp.pad(xf, ((0, 0), (0, pk),
                              (ph0, max(0, rows_needed - h - ph0)), (0, 0)))
            xf = xf[:, :, :rows_needed]
            g = jnp.pad(g, ((0, 0), (0, band_ways * ho_band - ho),
                            (0, 0), (0, pn)))
            pad_kw = {"padding": ((0, 0), (pw0, pw1))}
            x_rows = g_rows = None   # replicated; slabs carved per shard

            def extract(x_blk, g_blk, rm_blk):
                r = 0
                for a in part.rows:
                    r = r * mesh.shape[a] + jax.lax.axis_index(a)
                b_idx = r // band_ways
                band = r % band_ways
                x_sl = jax.lax.dynamic_slice(
                    x_blk, (b_idx, 0, band * ho_band * sh, 0),
                    (1, x_blk.shape[1], slab_rows, x_blk.shape[3]))
                g_sl = jax.lax.dynamic_slice(
                    g_blk, (b_idx, band * ho_band, 0, 0),
                    (1, ho_band, g_blk.shape[2], g_blk.shape[3]))
                # slab rows past Ho (last band of an uneven split) are dead
                rm = ((band * ho_band + jnp.arange(ho_band)) < ho
                      ).astype(jnp.int32).reshape(1, ho_band)
                return x_sl, g_sl, rm
        else:
            pb = (-n) % part.n_rows
            if pb or pk:
                xf = jnp.pad(xf, ((0, pb), (0, pk), (0, 0), (0, 0)))
            if pb or pn:
                g = jnp.pad(g, ((0, pb), (0, 0), (0, 0), (0, pn)))
            rmask = jnp.pad(jnp.ones((n, ho), jnp.int32),
                            ((0, pb), (0, 0)))   # padded images: dead rows
            pad_kw = {"padding": spec.padding}
            x_rows = g_rows = part._dim(part.rows)
            extract = lambda x_blk, g_blk, rm_blk: (x_blk, g_blk, rm_blk)

        sx_a = jnp.asarray(sx, jnp.float32).reshape(1)
        sg_a = jnp.asarray(sg, jnp.float32).reshape(1)
        cols = part._dim(part.cols)
        kdim = part._dim(part.k)

        def local(x_blk, g_blk, rm_blk, sx_b, sg_b):
            x_sl, g_sl, rm = extract(x_blk, g_blk, rm_blk)
            acc = acc_call(x_sl, g_sl, rm, sx_b, sg_b, **pad_kw)
            if part.rows:
                # the pixel contraction: int32 partials, one per band slab
                acc = jax.lax.psum(acc, part.rows)
            return acc

        rm_arg = rmask if band_ways == 1 else \
            jnp.zeros((1, 1), jnp.int32)   # unused; built inside extract
        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(x_rows, kdim, None, None),
                      P(g_rows, None, None, cols),
                      P(g_rows, None) if band_ways == 1 else P(None, None),
                      P(None), P(None)),
            out_specs=P(None, kdim, cols), check_rep=False,
        )(xf, g, rm_arg, sx_a, sg_a)
        return out[:, :c, :cout]

    return fn


def wrap_conv_gx_gemm(acc_call: Callable[..., Array], ctx: MeshContext,
                      part: GemmPartition, m00: int) -> Callable[..., Array]:
    """Shard one per-band input-grad GEMM ``fn(g2, wfmat, sg, sw) -> int32``.

    ``g2``: (band pixels, Cout) float gradient rows; ``wfmat``: (Cout,
    C*kh*kw) float residual weights. The contraction dim is Cout — the conv
    partition's *cols* axes — so the weight operand stays sharded exactly as
    the forward left it: each cols shard runs the fused backward kernel on
    its Cout slice (``acc_call`` = ``fused_lut_bwd`` with ``emit_acc``),
    the int32 partials psum over ``part.cols``, and the Cout shard-padding
    correction — zero pads quantize to code 0, contributing ``M[0, 0]``
    each — lands exactly once, after the collective. Rows and k axes are
    idle here (the band's pixel rows and the patch-feature columns stay
    whole); they compute replicated. The caller scatters the returned
    accumulator into the integer gradient canvas and dequants once.
    """
    mesh = ctx.mesh

    def fn(g2: Array, bmat: Array, sg, sw) -> Array:
        K = g2.shape[1]
        pk = (-K) % part.n_cols
        g2_p = _pad2(g2, 0, pk)     # 0.0 quantizes to code 0 (symmetric)
        b_p = _pad2(bmat, pk, 0)
        sg_a = jnp.asarray(sg, jnp.float32).reshape(1)
        sw_a = jnp.asarray(sw, jnp.float32).reshape(1)
        cols = part._dim(part.cols)

        def local(a_blk, b_blk, sa_b, sb_b):
            acc = acc_call(a_blk, b_blk, sa_b, sb_b)
            if part.cols:
                acc = jax.lax.psum(acc, part.cols)
            return acc

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(None, cols), P(cols, None), P(None), P(None)),
            out_specs=P(None, None), check_rep=False,
        )(g2_p, b_p, sg_a, sw_a)
        if pk and m00:
            # global Cout shard-padding correction: once, after the psum
            out = out - jnp.asarray(pk * m00, out.dtype)
        return out

    return fn


def bwd_gemms(ctx: MeshContext, part: GemmPartition
              ) -> tuple[Callable[[Array, Array], Array],
                         Callable[[Array, Array], Array]]:
    """The STE backward GEMMs with specs matching the forward partition:
    ``gx = g @ wf.T`` comes back row-sharded like the activations, ``gw =
    xf.T @ g`` column-sharded like the weights. Each local matmul contracts
    the *full* reduction dim (the counterpart operand is replicated), so
    gradients are bitwise identical to the unsharded backward.
    """
    mesh = ctx.mesh

    def gx_fn(g: Array, wf: Array) -> Array:
        M = g.shape[0]
        pm = (-M) % part.n_rows
        g_p = jnp.pad(g, ((0, pm), (0, 0))) if pm else g
        out = shard_map(lambda gb, wb: gb @ wb.T, mesh=mesh,
                        in_specs=(P(part._dim(part.rows), None), P(None, None)),
                        out_specs=P(part._dim(part.rows), None),
                        check_rep=False)(g_p, wf)
        return out[:M]

    def gw_fn(xf: Array, g: Array) -> Array:
        N = g.shape[1]
        pn = (-N) % part.n_cols
        g_p = jnp.pad(g, ((0, 0), (0, pn))) if pn else g
        out = shard_map(lambda xb, gb: xb.T @ gb, mesh=mesh,
                        in_specs=(P(None, None), P(None, part._dim(part.cols))),
                        out_specs=P(None, part._dim(part.cols)),
                        check_rep=False)(xf, g_p)
        return out[:, :N]

    return gx_fn, gw_fn
