"""Sharding planner: assigns PartitionSpecs to every param / optimizer-state /
cache / batch leaf, by leaf name + tensor role, with divisibility fallbacks.

Modes:
* ``train``  — FSDP(data) x TP(model): TP on the semantically-shardable dim
  (heads when H % axis == 0, d_ff, vocab, experts), FSDP on the other dim.
* ``serve``  — TP(model) only; params replicated over data (batch shards DP).
* ``long``   — serve + context parallelism: KV-cache/state sequence dim over
  ``data`` (batch=1 cannot use it).

Every decision that falls back (heads not divisible, experts not divisible)
is recorded in the returned ``report`` so DESIGN.md §6 claims are auditable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class Plan:
    mesh: Mesh
    specs: Any                 # pytree of PartitionSpec
    report: list[str]

    def shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), self.specs,
                            is_leaf=lambda x: isinstance(x, P))


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh: Mesh, axes: tuple[str, ...]) -> bool:
    n = int(np.prod([_axis(mesh, a) for a in axes])) if axes else 1
    return n > 1 and dim % n == 0


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, global_batch: int, extra_dims: int = 1) -> P:
    ba = batch_axes(mesh)
    if not _fits(global_batch, mesh, ba):
        ba = ba[1:] if len(ba) > 1 and _fits(global_batch, mesh, ba[1:]) else ()
    lead = ba if ba else None
    return P(lead, *([None] * extra_dims))


def param_specs(cfg: ModelConfig, params, mesh: Mesh, mode: str = "train") -> Plan:
    """Walk the param pytree; assign (TP, FSDP) per leaf by name."""
    report: list[str] = []
    fsdp = ("data",) if (mode == "train" and "data" in mesh.axis_names) else ()
    if mode == "serve" and "data" in mesh.axis_names:
        # TP-only replicates weights across the data axis; when that exceeds
        # the HBM budget (v5e 16 GiB minus activations), also shard weights
        # over data — ZeRO-inference (per-layer all-gather, memory-feasible).
        dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
        per_dev = cfg.n_params() * dtype_bytes / _axis(mesh, "model")
        if per_dev > 10e9:
            fsdp = ("data",)
            report.append(f"serve: params {per_dev/2**30:.1f} GiB/device under "
                          f"TP-only -> weight FSDP over data (ZeRO-inference)")
    heads_ok = _fits(cfg.n_heads, mesh, ("model",))
    kv_ok = _fits(cfg.n_kv_heads, mesh, ("model",))
    experts_ok = cfg.n_experts and _fits(cfg.n_experts, mesh, ("model",))
    if not heads_ok:
        report.append(f"heads {cfg.n_heads} %% model axis != 0 -> attention "
                      f"projections replicated on TP (TP lives on d_ff/vocab)")
    if cfg.n_experts and not experts_ok:
        report.append(f"experts {cfg.n_experts} %% model axis != 0 -> "
                      f"TP-in-expert (d_ff {cfg.d_ff})")

    def fs(dim_size: int) -> Optional[tuple]:
        return fsdp if fsdp and dim_size % _axis(mesh, "data") == 0 else None

    def mdl(dim_size: int, want: bool = True) -> Optional[tuple]:
        return ("model",) if want and _fits(dim_size, mesh, ("model",)) else None

    def leaf_spec(path: str, leaf) -> P:
        shp = leaf.shape
        nd = len(shp)
        name = path.split("'")[-2] if "'" in path else path  # last dict key

        def grouped(*dims):  # prepend None for the group-stack axis if present
            return P(*([None] * (nd - len(dims)) + list(dims)))

        # ---- embeddings / head -------------------------------------------
        if name == "embed":
            return P(mdl(shp[0]), fs(shp[1]))
        if name == "lm_head":
            return P(fs(shp[0]), mdl(shp[1]))
        if name == "dec_pos":
            return P(None, None)
        # ---- attention ----------------------------------------------------
        if name in ("wq", "wk", "wv"):
            n_h = cfg.n_heads if name == "wq" else cfg.n_kv_heads
            ok = heads_ok if name == "wq" else kv_ok
            return grouped(fs(shp[-2]), mdl(shp[-1], ok))
        if name == "wo":
            return grouped(mdl(shp[-2], heads_ok), fs(shp[-1]))
        if name in ("bq", "bk", "bv"):
            ok = heads_ok if name == "bq" else kv_ok
            return grouped(mdl(shp[-1], ok))
        if name == "bo":
            return grouped(None)
        # ---- dense MLP ------------------------------------------------------
        if name in ("w_gate", "w_up") and nd <= 3:
            return grouped(fs(shp[-2]), mdl(shp[-1]))
        if name == "w_down" and nd <= 3:
            return grouped(mdl(shp[-2]), fs(shp[-1]))
        if name in ("b_up",):
            return grouped(mdl(shp[-1]))
        # ---- MoE ------------------------------------------------------------
        if name in ("w_gate", "w_up") and nd == 4:   # (g, E, D, F)
            if experts_ok:
                return P(None, ("model",), fs(shp[2]), None)
            return P(None, None, fs(shp[2]), mdl(shp[3]))
        if name == "w_down" and nd == 4:             # (g, E, F, D)
            if experts_ok:
                return P(None, ("model",), None, fs(shp[3]))
            return P(None, None, mdl(shp[2]), fs(shp[3]))
        if name == "router":
            return grouped(None, None)
        # ---- mamba ----------------------------------------------------------
        if name == "in_proj":
            return grouped(fs(shp[-2]), mdl(shp[-1]))
        if name == "x_proj":
            return grouped(mdl(shp[-2]), None)
        if name == "dt_proj":
            return grouped(None, mdl(shp[-1]))
        if name in ("conv_w",):
            return grouped(None, mdl(shp[-1]))
        if name in ("conv_b", "dt_bias", "Dskip"):
            return grouped(mdl(shp[-1]))
        if name == "A_log":
            return grouped(mdl(shp[-2]), None)
        if name == "out_proj":
            return grouped(mdl(shp[-2]), fs(shp[-1]))
        # ---- rwkv -----------------------------------------------------------
        if name in ("Wr", "Wk", "Wv", "Wg", "Wo", "Wr_cm"):
            # wkv heads (40) don't divide the axis; keep head locality by
            # replicating time-mix projections, TP on channel-mix below
            return grouped(fs(shp[-2]), mdl(shp[-1], heads_ok))
        if name == "Wk_cm":
            return grouped(fs(shp[-2]), mdl(shp[-1]))
        if name == "Wv_cm":
            return grouped(mdl(shp[-2]), fs(shp[-1]))
        if name in ("Wdecay_A", "Wdecay_B", "lora_A") or name.startswith("lora_B"):
            return grouped(None, None)
        # ---- everything else (norms, scalars, mus) ------------------------
        return P(*([None] * nd))

    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = [leaf_spec(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return Plan(mesh=mesh, specs=tdef.unflatten(specs), report=report)


def cache_specs(cfg: ModelConfig, cache, mesh: Mesh, *, global_batch: int,
                long_context: bool = False) -> Plan:
    """KV/SSM cache sharding for serving.

    Default: batch -> (pod, data), kv-heads -> model (when divisible, else
    head_dim -> model, else seq -> model). long_context (batch=1): sequence
    dim -> data (context parallelism), heads/head_dim -> model.
    """
    report: list[str] = []
    ba = batch_axes(mesh)
    b_ok = _fits(global_batch, mesh, ba)
    if not b_ok and len(ba) > 1 and _fits(global_batch, mesh, ba[1:]):
        ba = ba[1:]
        b_ok = True
    if not b_ok:
        ba = ()
        report.append(f"batch {global_batch} not divisible -> replicated batch")

    def leaf_spec(path: str, leaf) -> P:
        shp = leaf.shape
        nd = len(shp)
        bspec = ba if ba else None
        if nd == 5 and "attn" in path:            # (g, B, S, Hkv, hd)
            seq = ("data",) if (long_context and "data" in mesh.axis_names
                                and shp[2] % _axis(mesh, "data") == 0) else None
            if _fits(shp[3], mesh, ("model",)):
                return P(None, bspec, seq, ("model",), None)
            # kv heads don't divide: split-KV decode — shard the sequence dim
            # over model (softmax denominators all-reduce; avoids the
            # involuntary-full-remat path that head_dim sharding triggers)
            if seq is None and _fits(shp[2], mesh, ("model",)):
                return P(None, bspec, ("model",), None, None)
            return P(None, bspec, seq, None, None)
        if "mamba" in path:
            if nd == 4 and "conv" in path:        # (g, B, dc-1, di)
                return P(None, bspec, None,
                         ("model",) if _fits(shp[3], mesh, ("model",)) else None)
            if nd == 4:                            # ssm (g, B, di, ds)
                return P(None, bspec,
                         ("model",) if _fits(shp[2], mesh, ("model",)) else None,
                         None)
        if "rwkv" in path:
            if nd == 5:                            # wkv (g, B, H, hd, hd)
                if _fits(shp[2], mesh, ("model",)):
                    return P(None, bspec, ("model",), None, None)
                if _fits(shp[3], mesh, ("model",)):
                    return P(None, bspec, None, ("model",), None)
                return P(None, bspec, None, None, None)
            if nd == 4:                            # shift (g, B, 1, D)
                return P(None, bspec, None,
                         ("model",) if _fits(shp[3], mesh, ("model",)) else None)
        # whisper self-attn cache: (L, B, S, H, hd)
        if nd == 5:
            return P(None, bspec, None,
                     ("model",) if _fits(shp[3], mesh, ("model",)) else None, None)
        return P(*([None] * nd))

    flat, tdef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [leaf_spec(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return Plan(mesh=mesh, specs=tdef.unflatten(specs), report=report)


# ---------------------------------------------------------------------------
# approximate-GEMM partitions (core/acu.py matmul_plan routes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmPartition:
    """Resolved mesh partition for one ACU GEMM: ``a (M, K) @ w (K, N)``.

    ``rows``/``cols``/``k`` are mesh-axis tuples (possibly empty). The product
    LUT is always replicated (``acu_lut`` rule; it is <= 256 KiB). A non-empty
    ``k`` means contraction sharding: both operands split on K and the int32
    partial accumulators are psum-reduced over ``k`` before dequant.
    ``report`` carries the audited fallback decisions that shaped this
    partition (inspectable on ``MatmulPlan.partition`` in the dispatch path).
    """

    rows: tuple[str, ...]
    cols: tuple[str, ...]
    k: tuple[str, ...]
    n_rows: int
    n_cols: int
    n_k: int
    report: tuple[str, ...] = ()

    @property
    def total(self) -> int:
        return self.n_rows * self.n_cols * self.n_k

    @staticmethod
    def _dim(axes: tuple[str, ...]):
        return None if not axes else (axes[0] if len(axes) == 1 else axes)

    def a_spec(self) -> P:
        return P(self._dim(self.rows), self._dim(self.k))

    def w_spec(self) -> P:
        return P(self._dim(self.k), self._dim(self.cols))

    def out_spec(self) -> P:
        return P(self._dim(self.rows), self._dim(self.cols))


def acu_gemm_partition(ctx, *, float_accum: bool = False
                       ) -> tuple[GemmPartition, list[str]]:
    """Resolve the ``acu_rows``/``acu_cols``/``acu_k`` logical rules of an
    active :class:`~repro.parallel.sharding.MeshContext` into a
    :class:`GemmPartition`, with the planner's usual audited fallbacks:

    * each mesh axis is claimed by at most one GEMM dim — ``k`` first (it is
      an explicit opt-in), then ``cols``, then ``rows``;
    * ``float_accum`` (LOWRANK: the SVD correction makes partial accumulators
      real-valued) drops ``k``: a float psum would not be bit-exact against
      the single-device oracle.
    """
    report: list[str] = []
    k = ctx.axes_for("acu_k")
    if k and float_accum:
        report.append("acu_k dropped: float accumulator (LOWRANK) cannot "
                      "psum bit-exactly; K replicated")
        k = ()
    used = set(k)
    cols = tuple(a for a in ctx.axes_for("acu_cols") if a not in used)
    if len(cols) != len(ctx.axes_for("acu_cols")):
        report.append("acu_cols overlaps acu_k -> shared axes dropped from "
                      "cols (contraction sharding wins)")
    used.update(cols)
    rows = tuple(a for a in ctx.axes_for("acu_rows") if a not in used)
    part = GemmPartition(rows=rows, cols=cols, k=k,
                         n_rows=ctx.axis_prod(rows),
                         n_cols=ctx.axis_prod(cols),
                         n_k=ctx.axis_prod(k),
                         report=tuple(report))
    return part, report


def bwd_gemm_partitions(part: GemmPartition
                        ) -> tuple[GemmPartition, GemmPartition]:
    """Permuted partitions for the *approximate* STE backward GEMMs.

    Each backward GEMM is a forward-shaped GEMM with the forward partition's
    roles permuted — no new mesh axes are claimed, so the residuals arrive
    already sharded the way the forward left them:

    * ``gx = g (M, N) @ wf.T (N, K)``: output rows stay on the forward's
      ``rows`` axes, output columns land on the forward's ``k`` axes, and the
      contraction runs over the forward's ``cols`` axes.
    * ``gw = xf.T (K, M) @ g (M, N)``: rows over the forward's ``k`` axes,
      columns over the forward's ``cols`` axes, contraction over the
      forward's ``rows`` axes.

    A non-empty contraction (``k``) dim means int32 partial accumulators
    psum before dequant with the shard-padding corrected exactly once —
    the same discipline as an ``acu_k``-sharded forward. Under the default
    rules (rows over ``("pod", "data")``, cols over ``("model",)``) both
    backward GEMMs are contraction-sharded even though the forward is not.
    """
    gx = GemmPartition(rows=part.rows, cols=part.k, k=part.cols,
                       n_rows=part.n_rows, n_cols=part.n_k, n_k=part.n_cols,
                       report=("bwd gx: forward partition, cols<->k swapped",))
    gw = GemmPartition(rows=part.k, cols=part.cols, k=part.rows,
                       n_rows=part.n_k, n_cols=part.n_cols, n_k=part.n_rows,
                       report=("bwd gw: forward partition, rows<->k swapped",))
    return gx, gw


def acu_conv_partition(ctx, *, float_accum: bool = False
                       ) -> tuple[GemmPartition, list[str]]:
    """The ``acu_conv`` partition rule: resolve ``acu_conv_rows`` /
    ``acu_conv_cols`` / ``acu_conv_k`` into a :class:`GemmPartition` for one
    approximate conv — ``rows`` shards the batch x output-pixel dim (the GEMM
    M of the implicit im2col; when the batch alone cannot fill the rows
    axes, ``acu_shard.wrap_fused_conv`` splits each image into halo'd
    output-row *bands* over the spare ways — batch x band partitioning),
    ``cols`` the output channels, ``k`` the input-channel contraction
    (opt-in; int32 psum before dequant). The product LUT is always
    replicated (``acu_lut``). Same audited-fallback discipline as
    :func:`acu_gemm_partition`: one mesh axis per conv dim, ``k`` claims
    first, and a float accumulator (LOWRANK) drops ``k``.
    """
    report: list[str] = []
    k = ctx.axes_for("acu_conv_k")
    if k and float_accum:
        report.append("acu_conv_k dropped: float accumulator (LOWRANK) "
                      "cannot psum bit-exactly; channels replicated")
        k = ()
    used = set(k)
    cols = tuple(a for a in ctx.axes_for("acu_conv_cols") if a not in used)
    if len(cols) != len(ctx.axes_for("acu_conv_cols")):
        report.append("acu_conv_cols overlaps acu_conv_k -> shared axes "
                      "dropped from cols (contraction sharding wins)")
    used.update(cols)
    rows = tuple(a for a in ctx.axes_for("acu_conv_rows") if a not in used)
    part = GemmPartition(rows=rows, cols=cols, k=k,
                         n_rows=ctx.axis_prod(rows),
                         n_cols=ctx.axis_prod(cols),
                         n_k=ctx.axis_prod(k),
                         report=tuple(report))
    return part, report


def acu_attn_partition(ctx, *, hq: int, hkv: int
                       ) -> tuple[GemmPartition, list[str]]:
    """Resolve the ``acu_attn_rows`` / ``acu_attn_heads`` logical rules for
    one approximate attention site: ``rows`` shards the batch dim (serving
    slots), ``cols`` the **KV** heads — each shard owns whole GQA groups
    (its ``rep = hq // hkv`` query heads per KV head ride along), so the
    kernel's ``b // rep`` index map stays local and there are no
    collectives. ``k`` is always empty: the online softmax is sequential
    over KV blocks and the float (m, l, acc) rescale cannot psum
    bit-exactly. Same audited-fallback discipline as the GEMM/conv
    partitions: head axes that do not divide ``hkv`` are dropped (reported)
    and the batch padding is handled by the wrap.
    """
    report: list[str] = []
    cols = ctx.axes_for("acu_attn_heads")
    while cols and hkv % ctx.axis_prod(cols) != 0:
        cols = cols[:-1]
    if len(cols) != len(ctx.axes_for("acu_attn_heads")):
        report.append(f"kv heads {hkv} %% acu_attn_heads axes != 0 -> heads "
                      f"{'partially sharded' if cols else 'replicated'} "
                      f"(GQA groups must stay whole per shard)")
    used = set(cols)
    rows = tuple(a for a in ctx.axes_for("acu_attn_rows") if a not in used)
    part = GemmPartition(rows=rows, cols=cols, k=(),
                         n_rows=ctx.axis_prod(rows),
                         n_cols=ctx.axis_prod(cols),
                         n_k=1,
                         report=tuple(report))
    return part, report


def acu_grouped_partition(ctx, *, n_experts: int, n_blocks: int
                          ) -> tuple[GemmPartition, list[str]]:
    """Resolve the ``acu_grouped_rows`` / ``acu_grouped_experts`` /
    ``acu_grouped_k`` logical rules for one MoE grouped ragged GEMM site:
    ``cols`` shards the expert dim (expert parallelism — each shard runs the
    grouped kernel over its expert slice with its slice of the groupinfo),
    ``rows`` the dispatch-block dim ``nb`` (token parallelism: dispatch
    blocks are independent capacity buffers), ``k`` the contraction (opt-in;
    the masked int32 partial accumulators psum before dequant). Same
    audited-fallback discipline as the attention partition: expert/block
    axes that do not divide their dim are dropped (reported) rather than
    padded — a fractional expert per shard would split a group's contiguous
    capacity strip.
    """
    report: list[str] = []
    k = ctx.axes_for("acu_grouped_k")
    used = set(k)
    cols = tuple(a for a in ctx.axes_for("acu_grouped_experts")
                 if a not in used)
    if len(cols) != len(ctx.axes_for("acu_grouped_experts")):
        report.append("acu_grouped_experts overlaps acu_grouped_k -> shared "
                      "axes dropped from experts (contraction sharding wins)")
    while cols and n_experts % ctx.axis_prod(cols) != 0:
        cols = cols[:-1]
        report.append(f"experts {n_experts} %% acu_grouped_experts axes != 0 "
                      f"-> experts {'partially sharded' if cols else 'replicated'} "
                      f"(each shard needs whole experts)")
    used.update(cols)
    rows = tuple(a for a in ctx.axes_for("acu_grouped_rows") if a not in used)
    while rows and n_blocks % ctx.axis_prod(rows) != 0:
        rows = rows[:-1]
        report.append(f"dispatch blocks {n_blocks} %% acu_grouped_rows axes "
                      f"!= 0 -> blocks "
                      f"{'partially sharded' if rows else 'replicated'}")
    part = GemmPartition(rows=rows, cols=cols, k=k,
                         n_rows=ctx.axis_prod(rows),
                         n_cols=ctx.axis_prod(cols),
                         n_k=ctx.axis_prod(k),
                         report=tuple(report))
    return part, report


def opt_state_specs(param_plan: Plan, opt_state) -> Any:
    """Optimizer moments shard exactly like their params; scalars replicate."""
    pspecs = param_plan.specs

    def match(leaf_spec):
        return leaf_spec

    # AdamWState(step, mu, nu) — mu/nu mirror params
    import repro.optim.adamw as O
    if isinstance(opt_state, O.AdamWState):
        return O.AdamWState(step=P(), mu=jax.tree.map(match, pspecs),
                            nu=jax.tree.map(match, pspecs))
    if isinstance(opt_state, O.SGDState):
        return O.SGDState(step=P(), momentum=jax.tree.map(match, pspecs))
    raise TypeError(type(opt_state))
