"""Logical-axis sharding: models annotate tensors with *logical* axes; an
active :class:`MeshContext` maps them to mesh axes with divisibility checks.

Model code stays mesh-agnostic: ``shard(x, "batch", None, "mlp")`` is an
identity when no mesh is active (unit tests, single device) and a
``with_sharding_constraint`` under a production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


# default logical-axis -> mesh-axes rules. "batch" spans pod+data so one rule
# set covers both single-pod and multi-pod meshes (missing axes are dropped).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                 # replicated by default; "seq_shard" opts in
    "seq_shard": ("data",),    # context parallelism (long-context KV/state)
    "embed": (),
    "embed_fsdp": ("data",),   # FSDP dim for params/optimizer state
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),     # expert parallelism
    "expert_blocks": ("pod", "data"),  # block-local MoE dispatch (token-parallel)
    "expert_cap": ("data",),   # MoE dispatch capacity dim (token-parallel)
    "expert_mlp": ("model",),  # TP-in-expert when EP doesn't divide
    "tokens": ("pod", "data"),  # flattened token rows (B*S order, batch-major)
    "conv_dim": ("model",),
    "state": (),
}


@dataclasses.dataclass
class MeshContext:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]]

    def spec(self, *logical: Optional[str], dim_sizes: Sequence[int] | None = None) -> P:
        """PartitionSpec for one tensor; rules that don't divide are dropped,
        and a mesh axis is used by at most one dim (first wins)."""
        parts = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            if name is None:
                parts.append(None)
                continue
            axes = [a for a in self.rules.get(name, ())
                    if a in self.mesh.axis_names and a not in used]
            if not axes:
                parts.append(None)
                continue
            if dim_sizes is not None:
                total = int(np.prod([self.mesh.shape[a] for a in axes]))
                if dim_sizes[i] % total != 0:
                    # try progressively smaller prefixes before replicating
                    while axes:
                        axes = axes[:-1]
                        total = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
                        if axes and dim_sizes[i] % total == 0:
                            break
                    if not axes:
                        parts.append(None)
                        continue
            used.update(axes)
            parts.append(tuple(axes) if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, *logical, dim_sizes=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical, dim_sizes=dim_sizes))


def current_mesh_context() -> Optional[MeshContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh=mesh, rules={**DEFAULT_RULES, **(rules or {})})
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op without an active mesh."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = ctx.spec(*logical, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
