"""Logical-axis sharding: models annotate tensors with *logical* axes; an
active :class:`MeshContext` maps them to mesh axes with divisibility checks.

Model code stays mesh-agnostic: ``shard(x, "batch", None, "mlp")`` is an
identity when no mesh is active (unit tests, single device) and a
``with_sharding_constraint`` under a production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


# default logical-axis -> mesh-axes rules. "batch" spans pod+data so one rule
# set covers both single-pod and multi-pod meshes (missing axes are dropped).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                 # replicated by default; "seq_shard" opts in
    "seq_shard": ("data",),    # context parallelism (long-context KV/state)
    "embed": (),
    "embed_fsdp": ("data",),   # FSDP dim for params/optimizer state
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),     # expert parallelism
    "expert_blocks": ("pod", "data"),  # block-local MoE dispatch (token-parallel)
    "expert_cap": ("data",),   # MoE dispatch capacity dim (token-parallel)
    "expert_mlp": ("model",),  # TP-in-expert when EP doesn't divide
    "tokens": ("pod", "data"),  # flattened token rows (B*S order, batch-major)
    "conv_dim": ("model",),
    "state": (),
    # ---- quantized ACU GEMM operands (core/acu.py matmul_plan routes) ----
    # The (2^b, 2^b) product table is <= 256 KiB and replicates to every
    # device; activation code rows shard like tokens, weight code columns
    # like any TP output dim. "acu_k" opts in to contraction sharding: the
    # K dim of both operands splits over the named axes and the int32
    # partial accumulators are psum-reduced before dequant.
    "acu_rows": ("pod", "data"),   # activation / output rows (M)
    "acu_cols": ("model",),        # weight / output columns (N)
    "acu_k": (),                   # contraction dim (K); empty = replicated
    "acu_lut": (),                 # product table: always replicated
    # ---- approximate conv (core/acu.py conv_plan routes): the "acu_conv"
    # partition rule family. Batch x output-pixel rows shard like tokens
    # (when the batch alone cannot fill the axes, images split into halo'd
    # output-row bands — batch x band, see acu_shard.wrap_fused_conv),
    # output channels like any TP output dim; "acu_conv_k" opts in to
    # input-channel contraction sharding (int32 psum before dequant).
    "acu_conv_rows": ("pod", "data"),  # batch x output-row-band rows
    "acu_conv_cols": ("model",),       # output channels (Cout)
    "acu_conv_k": (),                  # input channels (C); empty = replicated
    # ---- approximate attention (core/acu.py attn_plan routes): batch rows
    # (serving slots) shard like tokens, KV heads like any TP head dim —
    # whole GQA groups per shard, rowinfo rides with the batch, LUT
    # replicated. No contraction sharding: the online softmax is sequential
    # in KV and bit-exactness forbids re-associating the float rescale.
    "acu_attn_rows": ("pod", "data"),  # batch rows (B)
    "acu_attn_heads": ("model",),      # KV heads (GQA groups stay whole)
    # ---- grouped ragged MoE GEMM (core/acu.py grouped_plan routes): experts
    # shard over "model" (expert parallelism — each shard runs the grouped
    # kernel over its expert slice, groupinfo rides with the groups), dispatch
    # blocks over the token axes; "acu_grouped_k" opts in to contraction
    # sharding (int32 psum of the masked partial accumulators before dequant).
    "acu_grouped_rows": ("pod", "data"),  # dispatch blocks (nb)
    "acu_grouped_experts": ("model",),    # experts (E)
    "acu_grouped_k": (),                  # contraction dim; empty = replicated
}


@dataclasses.dataclass
class MeshContext:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]]

    def spec(self, *logical: Optional[str], dim_sizes: Sequence[int] | None = None) -> P:
        """PartitionSpec for one tensor; rules that don't divide are dropped,
        and a mesh axis is used by at most one dim (first wins)."""
        parts = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            if name is None:
                parts.append(None)
                continue
            axes = [a for a in self.rules.get(name, ())
                    if a in self.mesh.axis_names and a not in used]
            if not axes:
                parts.append(None)
                continue
            if dim_sizes is not None:
                total = int(np.prod([self.mesh.shape[a] for a in axes]))
                if dim_sizes[i] % total != 0:
                    # try progressively smaller prefixes before replicating
                    while axes:
                        axes = axes[:-1]
                        total = int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1
                        if axes and dim_sizes[i] % total == 0:
                            break
                    if not axes:
                        parts.append(None)
                        continue
            used.update(axes)
            parts.append(tuple(axes) if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, *logical, dim_sizes=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical, dim_sizes=dim_sizes))

    def axes_for(self, logical: str) -> tuple[str, ...]:
        """Mesh axes a logical rule resolves to on *this* mesh (missing mesh
        axes dropped, order preserved)."""
        return tuple(a for a in self.rules.get(logical, ())
                     if a in self.mesh.axis_names)

    def axis_prod(self, axes: Sequence[str]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    @property
    def size(self) -> int:
        return int(self.mesh.size)


def current_mesh_context() -> Optional[MeshContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh=mesh, rules={**DEFAULT_RULES, **(rules or {})})
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


@contextlib.contextmanager
def use_mesh_context(ctx: "MeshContext"):
    """Activate an existing :class:`MeshContext` verbatim — no DEFAULT_RULES
    re-merge, so a context whose ``rules`` dict deliberately omits keys (a
    missing rule means *replicated*) keeps exactly that meaning."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op without an active mesh."""
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = ctx.spec(*logical, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
