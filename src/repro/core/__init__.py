"""AdaPT-JAX core: the paper's contribution as composable JAX modules."""
from .acu import (Acu, AcuMode, ConvPlan, ConvSpec, GroupedPlan, GroupedSpec,
                  MatmulPlan, conv_plan, grouped_plan, make_acu, matmul_plan,
                  resolve_conv_padding)
from .approx_ops import (ApproxConfig, approx_dense, approx_grouped_dense,
                         approx_matmul, conv2d, conv_plan_report,
                         separable_conv2d)
from .calibration import HistogramObserver, calibrate_activation, calibrate_weight
from .lut import build_error_table, build_lut, factorize_error, rank_for_fidelity
from .multipliers import REGISTRY, Multiplier, error_stats, get_multiplier
from .quantization import (QParams, acu_operand, affine_qparams, dequantize,
                           fake_quantize, quantize, symmetric_qparams)

__all__ = [
    "Acu", "AcuMode", "ConvPlan", "ConvSpec", "GroupedPlan", "GroupedSpec",
    "MatmulPlan", "conv_plan", "grouped_plan", "make_acu", "matmul_plan",
    "resolve_conv_padding",
    "ApproxConfig", "approx_dense", "approx_grouped_dense", "approx_matmul",
    "conv_plan_report",
    "conv2d", "separable_conv2d", "HistogramObserver", "calibrate_activation",
    "calibrate_weight", "build_error_table", "build_lut", "factorize_error",
    "rank_for_fidelity", "REGISTRY", "Multiplier", "error_stats", "get_multiplier",
    "QParams", "acu_operand", "affine_qparams", "dequantize", "fake_quantize",
    "quantize", "symmetric_qparams",
]
