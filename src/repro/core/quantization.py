"""Affine quantization (paper §3.2).

``real = scale * (code - zero_point)`` — eq. (1) of the paper with
``A = scale``, ``B = -scale*zero_point``. Arbitrary bitwidth; per-tensor or
per-channel granularity (weights per-channel, activations per-tensor, per the
paper / Krishnamoorthi whitepaper).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class QParams:
    """Quantization parameters for one tensor.

    ``scale``/``zero_point`` are scalars (per-tensor) or vectors broadcast
    along ``axis`` (per-channel). ``zero_point`` lives in *code* space; the
    integer fed to the ACU is ``code - zero_point`` (paper eq. 2), so symmetric
    quantization has ``zero_point == 0``.
    """

    scale: Array
    zero_point: Array
    bits: int
    axis: Optional[int] = None  # channel axis for per-channel, None = per-tensor

    @property
    def lo(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def hi(self) -> int:
        return (1 << (self.bits - 1)) - 1

    def _expand(self, x: Array, v: Array) -> Array:
        if self.axis is None:
            return v
        shape = [1] * x.ndim
        shape[self.axis] = -1
        return jnp.reshape(v, shape)


def _register_barrier_batcher() -> None:
    """``optimization_barrier`` has no vmap rule in this jax version; it is
    an elementwise identity, so the batched rule is the barrier itself with
    unchanged batch dims (needed for the vmapped grouped-conv GEMM)."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
        if optimization_barrier_p not in batching.primitive_batchers:
            def _batcher(args, dims, **params):
                return optimization_barrier_p.bind(*args, **params), dims
            batching.primitive_batchers[optimization_barrier_p] = _batcher
    except (ImportError, AttributeError):
        # newer jax: the rule exists or the internals moved/were pruned —
        # degrade to the one feature needing it (vmapped grouped conv)
        # rather than failing the whole package at import time
        pass


_register_barrier_batcher()


_PIN_INT = {2: jnp.int16, 4: jnp.int32, 8: jnp.int64}


@jax.custom_jvp
def pin_rounding(x: Array) -> Array:
    """Identity that pins its input to one canonical set of float roundings.

    XLA fuses value-producing chains into consumers differently in
    differently-structured programs (flat jit vs shard_map-partitioned vs
    eager) — reassociating scale chains, contracting multiply+add into FMA —
    and those 1-ulp differences break bitwise reproducibility between the
    single-device and mesh-sharded ACU routes. Two layers of defense — an int
    bitcast round-trip plus ``optimization_barrier`` — because neither alone
    is load-bearing everywhere: the SPMD partitioner strips the barrier from
    sharded programs and the simplifier can fold the bitcast pair. Together
    they pin every GEMM+dequant route bitwise across eager/jit/mesh (see
    docs/sharding.md for the one residual caveat: bias-add FMA contraction
    in partitioned programs). Gradients pass straight through (custom_jvp —
    neither primitive differentiates in this jax version)."""
    i = _PIN_INT.get(jnp.dtype(x.dtype).itemsize)
    if i is not None and jnp.issubdtype(x.dtype, jnp.floating):
        x = jax.lax.bitcast_convert_type(
            jax.lax.bitcast_convert_type(x, i), x.dtype)
    return jax.lax.optimization_barrier(x)


@pin_rounding.defjvp
def _pin_rounding_jvp(primals, tangents):
    return pin_rounding(primals[0]), tangents[0]


def symmetric_qparams(calib_max: Array, bits: int, axis: Optional[int] = None) -> QParams:
    """Symmetric quantizer from a calibrated absolute max."""
    hi = (1 << (bits - 1)) - 1
    scale = pin_rounding(jnp.maximum(jnp.asarray(calib_max, jnp.float32), 1e-12) / hi)
    return QParams(scale=scale, zero_point=jnp.zeros_like(scale), bits=bits, axis=axis)


def inline_symmetric_scale(amax: Array, bits: int) -> Array:
    """Per-tensor symmetric scale for *in-graph* calibration.

    The approximate backward computes its operand amaxes inside the very
    program it differentiates, so the scale expression itself must compile
    identically in every context. :func:`symmetric_qparams` divides by
    ``hi``, and XLA's SPMD pipeline rewrites that constant division into a
    reciprocal multiply while eager / flat-jit modules keep the true divide
    — a 1-ulp context dependence that lands *upstream* of the pinned result,
    where ``pin_rounding`` cannot undo it. Writing the reciprocal multiply
    explicitly (the reciprocal folds to the same f32 constant everywhere)
    makes eager, flat jit, and SPMD-partitioned programs agree bitwise.
    Note the value may differ from ``symmetric_qparams(...).scale`` by 1 ulp
    — that is fine (any consistent scale is a valid quantizer); what matters
    is that every route sees the *same* one.
    """
    hi = (1 << (bits - 1)) - 1
    inv = jnp.float32(1.0) / jnp.float32(hi)   # folded at trace time
    return pin_rounding(
        jnp.maximum(jnp.asarray(amax, jnp.float32), 1e-12) * inv)


def affine_qparams(xmin: Array, xmax: Array, bits: int, axis: Optional[int] = None) -> QParams:
    """Affine quantizer from calibrated (min, max)."""
    lo = -(1 << (bits - 1))
    hi = (1 << (bits - 1)) - 1
    xmin = jnp.minimum(jnp.asarray(xmin, jnp.float32), 0.0)
    xmax = jnp.maximum(jnp.asarray(xmax, jnp.float32), 0.0)
    scale = pin_rounding(jnp.maximum((xmax - xmin) / (hi - lo), 1e-12))
    zp = jnp.clip(jnp.round(lo - xmin / scale), lo, hi)
    return QParams(scale=scale, zero_point=zp, bits=bits, axis=axis)


def quantize(x: Array, qp: QParams) -> Array:
    """real -> int code (int32 container, values within [lo, hi])."""
    s = qp._expand(x, qp.scale)
    z = qp._expand(x, qp.zero_point)
    q = jnp.round(x / s + z)
    return jnp.clip(q, qp.lo, qp.hi).astype(jnp.int32)


def dequantize(q: Array, qp: QParams) -> Array:
    s = qp._expand(q, qp.scale)
    z = qp._expand(q, qp.zero_point)
    return (q.astype(jnp.float32) - z) * s


def acu_operand(q: Array, qp: QParams) -> Array:
    """Integer operand the approximate hardware multiplier sees:
    ``code - zero_point`` (paper eq. 2)."""
    z = qp._expand(q, qp.zero_point)
    return (q - z.astype(jnp.int32)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# fake quantization with straight-through estimator (QAT, paper §3.2.1)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fake_quant(x: Array, scale: Array, zero_point: Array, lo: float, hi: float) -> Array:
    q = jnp.clip(jnp.round(x / scale + zero_point), lo, hi)
    return (q - zero_point) * scale


def _fq_fwd(x, scale, zero_point, lo, hi):
    y = fake_quant(x, scale, zero_point, lo, hi)
    in_range = (x / scale + zero_point >= lo) & (x / scale + zero_point <= hi)
    return y, in_range


def _fq_bwd(in_range, g):
    # STE: pass gradient through inside the clip range, zero outside.
    return (jnp.where(in_range, g, 0.0), None, None, None, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quantize(x: Array, qp: QParams) -> Array:
    """Fake-quantize with STE (differentiable); broadcast per-channel params."""
    s = qp._expand(x, qp.scale)
    z = qp._expand(x, qp.zero_point)
    return fake_quant(x, s, z, float(qp.lo), float(qp.hi))
