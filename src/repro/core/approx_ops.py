"""Approximate layer operations (paper §3.3): quantize -> ACU GEMM -> dequant.

This is the "graph re-transform" equivalent: model code calls
:func:`approx_dense` / :func:`approx_conv2d` at its matmul sites, and an
:class:`ApproxConfig` (threaded through the model, or None for exact fp)
decides whether and how approximation happens. Conv2D is lowered to GEMM by
im2col exactly as in the paper (§3.3.1, Fig. 3); separable conv is depthwise +
pointwise (§3.3.2); RNN cells reuse the approximate Linear (§3.3.4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .acu import Acu, AcuMode, GroupedSpec, grouped_plan, matmul_plan
from .quantization import (QParams, acu_operand, dequantize, fake_quantize,
                           pin_rounding, quantize)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """Per-model approximation configuration (the paper's "user sets the
    desired DNN model with the quantization parameters + approximate module")."""

    acu: Acu
    a_bits: int = 8
    w_bits: int = 8
    fake_quant_only: bool = False   # QAT fake-quant path (no integer GEMM)
    fused: Optional[bool] = None    # route the STE forward through the fused
                                    # quantize->LUT-GEMM->dequant Pallas kernel
                                    # (None = inherit acu.fused; only effective
                                    # for LUT mode with use_pallas=True)
    approx_bwd: bool = False        # run the STE backward GEMMs through the
                                    # ACU too (ApproxTrain regime): residuals
                                    # and the incoming gradient quantize
                                    # per-tensor symmetric and the grad GEMMs
                                    # go through the LUT (fused in-kernel when
                                    # the forward is fused). False keeps the
                                    # exact-f32 STE backward.

    def __post_init__(self):
        if max(self.a_bits, self.w_bits) > self.acu.bits:
            raise ValueError(
                f"quantization bits ({self.a_bits}/{self.w_bits}) exceed the "
                f"ACU's operand width ({self.acu.bits}-bit "
                f"{self.acu.multiplier.name}); codes would overflow")

    def replace(self, **kw) -> "ApproxConfig":
        return dataclasses.replace(self, **kw)


def _affine_matmul_dequant(acc: Array, xqp: QParams, wqp: QParams) -> Array:
    """Dequantize an integer GEMM accumulator (paper eq. 2).

    Operands were shifted codes (code - zp), so the accumulator is directly
    ``sum (q1-z1)(q2-z2)`` and the dequant is a pure scale product.
    Weight scale may be per-output-channel (axis 0 of w^T layout handled by
    caller passing wqp with axis=1 on the (K, N) matrix).

    The two scales combine into ONE multiply, ``acc * (s1 * s2)``, and the
    combined scale sits behind an optimization barrier: a ``acc * s1 * s2``
    chain gets reassociated by the XLA simplifier inside shard_map-partitioned
    programs, and letting inline scale *computations* (amax -> divide) fuse
    into the big multiply perturbs its rounding between compilation contexts.
    Bit-exactness across every fused/unfused/sharded route — jitted or eager —
    is the contract here, so the scale product is pinned to one f32 rounding.
    """
    s1 = xqp.scale  # per-tensor
    s2 = wqp.scale  # scalar or (N,)
    if wqp.axis is not None:
        s2 = jnp.reshape(s2, (1, -1))
    s = pin_rounding(jnp.asarray(s1, jnp.float32) * jnp.asarray(s2, jnp.float32))
    return acc.astype(jnp.float32) * s


_STE_CACHE: dict = {}


def _mesh_cache_key(ctx):
    """Hashable fingerprint of a MeshContext for the STE cache (meshes are
    hashable in jax; the acu_* rules are what the plan resolution reads)."""
    if ctx is None:
        return None
    rules = tuple(sorted((k, v) for k, v in ctx.rules.items()
                         if k.startswith("acu_")))
    return (ctx.mesh, rules)


def _get_ste_fn(acu: Acu, a_bits: int, w_bits: int, fused: bool = False,
                ctx=None, approx_bwd: bool = False):
    """Per-ACU custom_vjp GEMM: approximate forward, STE backward — exact
    f32 by default, or through the ACU itself with ``approx_bwd`` (the
    ApproxTrain regime: both grad GEMMs quantize their operands per-tensor
    symmetric and gather from the same LUT as the forward).

    The forward dispatches through :func:`matmul_plan`; a fused plan runs
    quantize -> LUT GEMM -> dequant as one Pallas kernel (weights are still
    quantized outside — their codes are produced once per layer, not per
    tile), an unfused plan keeps the three-stage pipeline. With an active
    mesh the plan runs sharded, and the backward GEMMs carry matching specs
    (exact: ``gx`` row-sharded like the activations, ``gw`` column-sharded
    like the weights, contractions device-local; approximate: the permuted
    forward partition with int32 psums over the contraction axes — see
    :func:`~repro.core.acu.matmul_bwd_plan`), so sharded QAT gradients are
    bitwise identical to single-device ones either way.
    """
    key = (id(acu), a_bits, w_bits, fused, approx_bwd, _mesh_cache_key(ctx))
    if key in _STE_CACHE:
        return _STE_CACHE[key]

    plan = matmul_plan(acu, a_bits=a_bits, fused=fused, mesh=ctx or False)
    if approx_bwd:
        from .acu import matmul_bwd_plan
        gx_bwd, gw_bwd = matmul_bwd_plan(acu, a_bits=a_bits, fused=fused,
                                         mesh=ctx or False)
    elif plan.partition is not None:
        from repro.parallel.acu_shard import bwd_gemms
        gx_gemm, gw_gemm = bwd_gemms(ctx, plan.partition)
    else:
        gx_gemm = lambda g, wf: g @ wf.T
        gw_gemm = lambda xf, g: xf.T @ g

    @jax.custom_vjp
    def ste_matmul(x, w, xs, xz, ws, wz):
        xqp = QParams(scale=xs, zero_point=xz, bits=a_bits)
        wqp = QParams(scale=ws, zero_point=wz, bits=w_bits, axis=1)
        wq = acu_operand(quantize(w, wqp), wqp)
        if plan.fused:
            return plan(x, wq, xs, xz, ws)
        xq = acu_operand(quantize(x, xqp), xqp)
        acc = plan(xq, wq)
        return _affine_matmul_dequant(acc, xqp, wqp)

    def fwd(x, w, xs, xz, ws, wz):
        y = ste_matmul(x, w, xs, xz, ws, wz)
        xqp = QParams(scale=xs, zero_point=xz, bits=a_bits)
        wqp = QParams(scale=ws, zero_point=wz, bits=w_bits, axis=1)
        xf = fake_quantize(x, xqp).astype(x.dtype)
        wf = fake_quantize(w, wqp).astype(w.dtype)
        return y, (xf, wf)

    if approx_bwd:
        from .quantization import inline_symmetric_scale

        def bwd(res, g):
            # approximate backward: per-tensor symmetric scales computed on
            # the FULL tensors (under a mesh every shard must see the same
            # scale — amax happens before the shard_map inside gx/gw_bwd);
            # inline_symmetric_scale because these amaxes live inside the
            # differentiated program, where the scale expression must
            # compile identically across eager/jit/SPMD contexts
            xf, wf = res
            g = g.astype(jnp.float32)
            sg = inline_symmetric_scale(jnp.max(jnp.abs(g)), a_bits)
            sx = inline_symmetric_scale(jnp.max(jnp.abs(xf)), a_bits)
            sw = inline_symmetric_scale(jnp.max(jnp.abs(wf)), a_bits)
            gx = gx_bwd(g, wf.astype(jnp.float32).T, sg, sw).astype(xf.dtype)
            gw = gw_bwd(xf.astype(jnp.float32).T, g, sx, sg).astype(wf.dtype)
            return (gx, gw, None, None, None, None)
    else:
        def bwd(res, g):
            xf, wf = res
            g = g.astype(jnp.float32)
            gx = gx_gemm(g, wf.astype(jnp.float32)).astype(xf.dtype)
            gw = gw_gemm(xf.astype(jnp.float32), g).astype(wf.dtype)
            return (gx, gw, None, None, None, None)

    ste_matmul.defvjp(fwd, bwd)
    _STE_CACHE[key] = ste_matmul
    return ste_matmul


def approx_matmul(x: Array, w: Array, cfg: ApproxConfig,
                  xqp: QParams, wqp: QParams) -> Array:
    """2-D approximate GEMM with STE backward. ``x``: (M, K) float,
    ``w``: (K, N) float; ``wqp.axis`` must be 1 (per-out-channel) or None.
    Mesh-aware: resolved against the active MeshContext at call time."""
    if cfg.fake_quant_only:
        return fake_quantize(x, xqp) @ fake_quantize(w, wqp)
    fused = cfg.acu.fused if cfg.fused is None else cfg.fused
    from repro.parallel.sharding import current_mesh_context
    fn = _get_ste_fn(cfg.acu, cfg.a_bits, cfg.w_bits, fused,
                     ctx=current_mesh_context(), approx_bwd=cfg.approx_bwd)
    return fn(x, w, xqp.scale, xqp.zero_point, wqp.scale, wqp.zero_point)


def approx_dense(x: Array, w: Array, b: Optional[Array], cfg: Optional[ApproxConfig],
                 xqp: Optional[QParams] = None, wqp: Optional[QParams] = None) -> Array:
    """Linear layer y = x @ w + b, optionally through the ACU.

    ``x``: (..., K), ``w``: (K, N). With ``cfg=None`` this is an exact matmul
    (the substrate path used by the LM stack unless emulation is enabled).
    """
    if cfg is None:
        y = x @ w
    else:
        lead = x.shape[:-1]
        K = x.shape[-1]
        x2 = x.reshape(-1, K)
        if xqp is None:
            amax = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-6)
            from .quantization import symmetric_qparams
            xqp = symmetric_qparams(amax, cfg.a_bits)
        if wqp is None:
            from .quantization import symmetric_qparams
            wqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-9),
                                    cfg.w_bits, axis=1)
        y = approx_matmul(x2, w, cfg, xqp, wqp).reshape(*lead, w.shape[1])
        y = y.astype(x.dtype)   # dequant is f32; keep the model's dtype
    if b is not None:
        if cfg is not None:
            # best-effort: keep dequant-multiply and bias-add as two separate
            # roundings so flat-jit and shard_map-partitioned programs agree;
            # the SPMD partitioner can still FMA-contract them (1-ulp, see
            # docs/sharding.md) — the GEMM+dequant itself is always bitwise
            y = pin_rounding(y)
        y = y + b
    return y


# ---------------------------------------------------------------------------
# Grouped ragged MoE GEMM: ONE pallas_call for all E expert GEMMs
# (kernels/fused_lut_grouped), routed by core/acu.grouped_plan. The resolved
# STE fn is cached per (acu, bits, spec, route, mesh) like the dense fns.
# ---------------------------------------------------------------------------

def _get_grouped_ste_fn(acu: Acu, a_bits: int, w_bits: int,
                        spec: GroupedSpec, ctx, route: Optional[str] = None):
    """Per-ACU custom_vjp grouped GEMM: approximate ragged forward, exact-f32
    STE backward.

    The forward dispatches through :func:`~repro.core.acu.grouped_plan` —
    the ``"fused_grouped"`` route runs every expert GEMM inside one ragged
    Pallas kernel (mesh-wrapped when a partition is active); the ``"vmap"``
    route keeps the per-expert vmapped composition (quantize -> per-expert
    GEMM -> dequant, fused or unfused per :func:`matmul_plan`), which doubles
    as the fused route's bit-exactness oracle since both consume the same
    pinned shared activation scale and mask dead capacity rows to exactly
    zero. The backward is the exact-f32 STE on the fake-quantized residuals
    with the incoming gradient masked to the live rows — dead capacity slots
    emit zero forward, so nothing may flow back through them.
    """
    key = ("grouped", id(acu), a_bits, w_bits, spec, route,
           _mesh_cache_key(ctx))
    if key in _STE_CACHE:
        return _STE_CACHE[key]

    plan = grouped_plan(acu, spec, a_bits=a_bits, mesh=ctx or False,
                        route=route)
    E, C, nb = spec.n_experts, spec.cap, spec.n_blocks
    if plan.route != "fused_grouped":
        # per-expert vmapped composition (single-device inner plan — the
        # audited fallback runs replicated, see plan.report)
        mplan = matmul_plan(acu, a_bits=a_bits, mesh=False)

    def _live(counts):
        return jnp.arange(C)[None, :] < jnp.clip(counts, 0, C)[:, None]

    @jax.custom_vjp
    def ste_grouped(xe, w, xs, xz, ws, counts):
        xqp = QParams(scale=xs, zero_point=xz, bits=a_bits)
        if plan.route == "fused_grouped":
            wqp = QParams(scale=ws.reshape(E, 1, -1),
                          zero_point=jnp.zeros((), jnp.float32), bits=w_bits)
            wq = acu_operand(quantize(w, wqp), wqp)
            return plan(xe, wq, xs, xz, ws, counts)

        def one(xg, wg, wsg):
            wqp_e = QParams(scale=wsg,
                            zero_point=jnp.zeros((), jnp.float32),
                            bits=w_bits, axis=1)
            wq_e = acu_operand(quantize(wg, wqp_e), wqp_e)
            if mplan.fused:
                return mplan(xg, wq_e, xs, xz, wsg)
            xq = acu_operand(quantize(xg, xqp), xqp)
            return _affine_matmul_dequant(mplan(xq, wq_e), xqp, wqp_e)

        per_e = jax.vmap(one, in_axes=(0, 0, 0))
        y = jax.vmap(per_e, in_axes=(0, None, None))(
            xe.reshape(nb, E, C, xe.shape[-1]), w, ws)
        y = y.reshape(nb * E, C, y.shape[-1])
        # masking, not slicing: dead capacity rows still produce
        # sum_k LUT[0, w] != 0 under biased-M00 multipliers
        return jnp.where(_live(counts)[..., None], y, 0.0)

    def fwd(xe, w, xs, xz, ws, counts):
        y = ste_grouped(xe, w, xs, xz, ws, counts)
        xqp = QParams(scale=xs, zero_point=xz, bits=a_bits)
        wqp = QParams(scale=ws.reshape(E, 1, -1),
                      zero_point=jnp.zeros((), jnp.float32), bits=w_bits)
        xf = fake_quantize(xe, xqp).astype(xe.dtype)
        wf = fake_quantize(w, wqp).astype(w.dtype)
        return y, (xf, wf, counts)

    def bwd(res, g):
        # exact-f32 STE on the fake-quantized residuals; the incoming
        # gradient is masked to the live rows (the forward emits exactly
        # zero past each group's count, so dead slots carry no gradient)
        xf, wf, counts = res
        g = jnp.where(_live(counts)[..., None], g.astype(jnp.float32), 0.0)
        g4 = g.reshape(nb, E, C, g.shape[-1])
        xf4 = xf.astype(jnp.float32).reshape(nb, E, C, xf.shape[-1])
        wff = wf.astype(jnp.float32)
        gx = jnp.einsum("becn,ekn->beck", g4, wff)
        gx = gx.reshape(xf.shape).astype(xf.dtype)
        gw = jnp.einsum("beck,becn->ekn", xf4, g4).astype(wf.dtype)
        return (gx, gw, None, None, None, None)

    ste_grouped.defvjp(fwd, bwd)
    _STE_CACHE[key] = ste_grouped
    return ste_grouped


def approx_grouped_dense(xe: Array, w: Array, cfg: ApproxConfig,
                         counts: Array, xqp: Optional[QParams] = None,
                         wqp: Optional[QParams] = None,
                         route: Optional[str] = None) -> Array:
    """Ragged grouped MoE GEMM through the ACU: all E expert GEMMs in one
    dispatch.

    ``xe``: (G, C, K) dispatched capacity buffers — ``G = nb * E`` groups
    (dispatch blocks x experts, block-major) of ``C`` capacity rows; group
    ``g`` multiplies expert ``g % E``. ``w``: (E, K, N) per-expert weights;
    ``counts``: (G,) live rows per group — output rows ``>= counts[g]`` are
    exactly 0.0 (dead capacity slots contribute nothing, even under
    biased-M00 multipliers).

    The activation quantizer is ONE per-tensor scale over the whole
    dispatched tensor (not per expert): that is what makes the grouped
    kernel and the per-expert vmapped composition bitwise identical, and it
    matches the dispatch semantics — the rows of every group came from the
    same layer activation tensor. Weight scales stay per-expert
    per-out-channel. ``route`` pins the plan route (``"fused_grouped"`` /
    ``"vmap"``); the default audited fallback applies.

    No ``fake_quant_only`` route: the grouped kernel runs the integer ACU
    GEMM, which contradicts the fake-quant contract — QAT MoE keeps the
    per-expert :func:`approx_dense` path.
    """
    G, C, K = xe.shape
    E, _, N = w.shape
    if G % E != 0:
        raise ValueError(f"groups {G} not a multiple of experts {E}")
    if cfg.fake_quant_only:
        raise ValueError("approx_grouped_dense has no fake-quant route; "
                         "keep the per-expert approx_dense path for QAT")
    # inline_symmetric_scale (multiply form), not symmetric_qparams: these
    # amaxes live inside the (possibly jitted) MoE layer, and the divide
    # form compiles to a reciprocal multiply under SPMD/jit — a 1-ulp scale
    # drift that lands upstream of pin_rounding (see quantization.py)
    from .quantization import inline_symmetric_scale
    if xqp is None:
        xqp = QParams(
            scale=inline_symmetric_scale(
                jnp.maximum(jnp.max(jnp.abs(xe)), 1e-6), cfg.a_bits),
            zero_point=jnp.zeros((), jnp.float32), bits=cfg.a_bits)
    if wqp is None:
        wqp = QParams(
            scale=inline_symmetric_scale(
                jnp.maximum(jnp.max(jnp.abs(w), axis=1), 1e-9), cfg.w_bits),
            zero_point=jnp.zeros((), jnp.float32), bits=cfg.w_bits)
    ws = jnp.broadcast_to(
        jnp.asarray(wqp.scale, jnp.float32).reshape(E, -1), (E, N))
    spec = GroupedSpec(n_experts=E, cap=C, d_in=K, d_out=N, n_blocks=G // E)
    from repro.parallel.sharding import current_mesh_context
    fn = _get_grouped_ste_fn(cfg.acu, cfg.a_bits, cfg.w_bits, spec,
                             ctx=current_mesh_context(), route=route)
    y = fn(xe, w, xqp.scale, xqp.zero_point, ws,
           jnp.asarray(counts, jnp.int32))
    return y.astype(xe.dtype)


# ---------------------------------------------------------------------------
# Approximate attention: quantize -> LUT-gather QK^T / PV inside the
# streaming-softmax kernel (kernels/flash_attention/approx.py), routed by
# core/acu.attn_plan. The resolved plan is cached per (acu, bits, spec, mesh)
# exactly like the STE GEMM fns.
# ---------------------------------------------------------------------------

def _get_attn_plan(acu: Acu, a_bits: int, spec, ctx):
    from .acu import attn_plan
    key = ("attn", id(acu), a_bits, spec, _mesh_cache_key(ctx))
    if key in _STE_CACHE:
        return _STE_CACHE[key]
    plan = attn_plan(acu, spec, a_bits=a_bits, mesh=ctx or False)
    _STE_CACHE[key] = plan
    return plan


def approx_attention(q: Array, k: Array, v: Array, cfg: ApproxConfig, *,
                     causal: bool = True, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     rowinfo: Optional[Array] = None) -> Optional[Array]:
    """Attention through the ACU, or ``None`` when the plan audits to the
    exact-substrate route (non-LUT mode, no Pallas, missing table) — the
    caller keeps its float attention, mirroring conv's im2col contract.

    ``q``: (B, Hq, Sq, D); ``k``/``v``: (B, Hkv, Sk, D). Per-tensor symmetric
    scales are calibrated here on the full tensors (under a mesh every shard
    must see the same scales — the amaxes happen before the plan's
    shard_map). Inference-only: no custom_vjp, decode/prefill forward path.
    """
    from .acu import AttnSpec
    from .quantization import inline_symmetric_scale
    from repro.parallel.sharding import current_mesh_context
    spec = AttnSpec(hq=q.shape[1], hkv=k.shape[1], causal=causal,
                    window=window, softcap=softcap)
    ctx = current_mesh_context()
    plan = _get_attn_plan(cfg.acu, cfg.a_bits, spec, ctx)
    if plan.route != "fused_attn":
        return None
    scales = [inline_symmetric_scale(jnp.maximum(jnp.max(jnp.abs(t)), 1e-6),
                                     cfg.a_bits) for t in (q, k, v)]
    return plan(q, k, v, *scales, rowinfo)


def approx_attention_paged(q: Array, k_pool: Array, v_pool: Array,
                           cfg: ApproxConfig, *, page_table: Array,
                           rowinfo: Array, causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None
                           ) -> Optional[Array]:
    """Attention through the ACU over block-paged KV, or ``None`` when the
    plan audits to the exact-substrate route (the caller then gathers the
    pool blocks back to a contiguous layout and keeps its float attention).

    ``q``: (B, Hq, Sq, D); ``k_pool``/``v_pool``: (Hkv, P, bk, D) physical
    block pools; ``page_table``: (B, n_logical) int32; ``rowinfo``: (B, 3)
    int32 — both REQUIRED. The K/V calibration amaxes run over the blocks
    the page tables actually reference (``pool[:, page_table]``), NOT the
    whole pool: a prefix-cache hit must see exactly the scales a cold run
    of the same request would compute, and the pool's unrelated residents
    (other requests, stale freed blocks) must never perturb them.
    """
    from .acu import AttnSpec
    from .quantization import inline_symmetric_scale
    from repro.parallel.sharding import current_mesh_context
    spec = AttnSpec(hq=q.shape[1], hkv=k_pool.shape[0], causal=causal,
                    window=window, softcap=softcap, bk=k_pool.shape[2],
                    kv_layout="paged")
    ctx = current_mesh_context()
    plan = _get_attn_plan(cfg.acu, cfg.a_bits, spec, ctx)
    if plan.route != "fused_attn_paged":
        return None
    pt = jnp.asarray(page_table, jnp.int32)
    amaxes = (jnp.maximum(jnp.max(jnp.abs(q)), 1e-6),) + tuple(
        jnp.maximum(jnp.max(jnp.abs(pool[:, pt])), 1e-6)
        for pool in (k_pool, v_pool))
    scales = [inline_symmetric_scale(a, cfg.a_bits) for a in amaxes]
    return plan(q, k_pool, v_pool, *scales, rowinfo, pt)


# ---------------------------------------------------------------------------
# Conv2D (paper §3.3.1) and separable conv (§3.3.2)
#
# Every approximate conv resolves a ConvPlan (core/acu.py): the fused route
# streams im2col patches inside one Pallas kernel (the patch tensor never
# reaches HBM); the eager im2col composition below is the audited fallback
# and the bit-exactness oracle.
# ---------------------------------------------------------------------------

def _im2col(x: Array, kh: int, kw: int, stride: Sequence[int],
            padding: str | Sequence[tuple[int, int]], dilation: Sequence[int]) -> Array:
    """Extract conv patches: (N, C, H, W) -> (N, Ho*Wo, C*kh*kw)."""
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), tuple(stride), padding,
        rhs_dilation=tuple(dilation),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*kh*kw, Ho, Wo)
    n, ckk, ho, wo = patches.shape
    return patches.reshape(n, ckk, ho * wo).transpose(0, 2, 1), (ho, wo)


def _conv_qparams(x: Array, w: Array, cfg: ApproxConfig,
                  xqp: Optional[QParams], wqp: Optional[QParams]
                  ) -> tuple[QParams, QParams]:
    """Shared quantizers for the groups=1 conv routes: per-tensor activation
    scale calibrated on the *input* (every patch entry is an input pixel or a
    0.0 pad, and 0.0 never raises an amax, so the input bound covers the
    patch tensor) and per-output-channel weight scales. Both the fused
    patch-streaming route and the eager im2col oracle use exactly these, so
    the two stay bitwise comparable end to end."""
    from .quantization import symmetric_qparams
    if xqp is None:
        xqp = symmetric_qparams(jnp.maximum(jnp.max(jnp.abs(x)), 1e-6),
                                cfg.a_bits)
    if wqp is None:
        wqp = symmetric_qparams(
            jnp.maximum(jnp.max(jnp.abs(w), axis=(1, 2, 3)), 1e-9),
            cfg.w_bits, axis=0)
    return xqp, wqp


def _conv_bwd_fns(acu: Acu, plan, a_bits: int, ctx):
    """The *approximate* conv STE backward pair for one resolved plan.

    Returns ``(gx_fn, gw_fn)``: ``gx_fn(g, wf, sg, sw) -> (N, Cin, H, W)``
    and ``gw_fn(xf, g, sx, sg) -> (Cout, Cin, kh, kw)``, both f32, operands
    float residuals with caller-computed per-tensor symmetric scales.

    ``plan.bwd_route == "banded"`` (LUT + Pallas + table): the weight-grad
    streams halo'd output-row bands through the
    ``fused_lut_conv_bwd_w`` kernel — contracting output pixels in-kernel,
    so the im2col patch tensor never exists in HBM — and the input-grad
    composes per-band ``fused_lut_bwd`` GEMMs whose int32 patch-gradient
    blocks scatter-add into an integer canvas (int adds are associative, so
    the band count is bitwise invisible) with ONE combined-scale dequant at
    the end. Under a mesh the weight-grad psums band-shard partials over the
    conv partition's rows axes and the per-band GEMM contraction shards over
    its cols axes (``acu_shard.wrap_conv_bwd_w`` / ``wrap_conv_gx_gemm``),
    bit-identical to single-device.

    Any other ``bwd_route`` falls back to materialized im2col + the dense
    approximate backward GEMMs (:func:`~repro.core.acu.matmul_bwd_plan`) —
    the audited fallback for degenerate geometry.
    """
    from .acu import AcuMode, matmul_bwd_plan
    from .quantization import pin_rounding as _pin
    spec = plan.spec
    n, cin, h, w_in = spec.x_shape
    cout, _, kh, kw = spec.w_shape
    ho, wo = spec.out_spatial
    sh, sw_ = spec.stride
    dh, dw = spec.dilation
    (ph0, ph1), (pw0, pw1) = spec.padding

    banded = (plan.bwd_route == "banded" and acu.mode == AcuMode.LUT
              and acu.use_pallas and acu.lut is not None)
    if not banded:
        gx_d, gw_d = matmul_bwd_plan(acu, a_bits=a_bits, fused=plan.fused,
                                     mesh=ctx or False)

        def gx_fn(g, wf, sg, sw):
            g2 = g.reshape(-1, cout).astype(jnp.float32)
            wfmat = wf.reshape(cout, -1).astype(jnp.float32)
            _, col_vjp = jax.vjp(
                lambda t: _im2col(t, kh, kw, spec.stride, spec.padding,
                                  spec.dilation)[0],
                jnp.zeros(spec.x_shape, jnp.float32))   # im2col is linear
            gcols = gx_d(g2, wfmat, sg, sw)             # (N*P, C*kh*kw) f32
            (gx,) = col_vjp(gcols.reshape(n, ho * wo, -1))
            return gx

        def gw_fn(xf, g, sx, sg):
            cols, _ = _im2col(xf.astype(jnp.float32), kh, kw, spec.stride,
                              spec.padding, spec.dilation)
            g2 = g.reshape(-1, cout).astype(jnp.float32)
            gw = gw_d(cols.reshape(-1, cols.shape[-1]).T, g2, sx, sg)
            return gw.T.reshape(cout, cin, kh, kw)

        return gx_fn, gw_fn

    from repro.kernels.fused_lut_conv import ops as cops
    from repro.kernels.fused_lut_dense import ops as fops
    bh_t, bn_t, mc_t, _ = plan.bwd_tiling
    part = plan.partition

    def gw_acc(x, g, rm, sx, sg, padding):
        # jnp.asarray stays inside: plans/STE fns are cached across traces
        return cops.fused_lut_conv_bwd_w(
            x, g, jnp.asarray(acu.lut), acu.offset, sx, sg,
            ksize=(kh, kw), stride=spec.stride, padding=padding,
            dilation=spec.dilation, bits=a_bits, bh=bh_t, bn=bn_t, mc=mc_t,
            interpret=acu.interpret, rmask=rm)

    if part is not None:
        from repro.parallel import acu_shard
        gw_call = acu_shard.wrap_conv_bwd_w(gw_acc, ctx, part, spec)
    else:
        gw_call = lambda xf, g, sx, sg: gw_acc(xf, g, None, sx, sg,
                                               spec.padding)

    def gw_fn(xf, g, sx, sg):
        acc = gw_call(xf.astype(jnp.float32), g, sx, sg)  # (kh*kw, Cin, Cout)
        s = _pin(jnp.asarray(sx, jnp.float32) * jnp.asarray(sg, jnp.float32))
        gw = acc.astype(jnp.float32) * s
        return gw.transpose(2, 1, 0).reshape(cout, cin, kh, kw)

    def gx_acc(a, b, sa, sb):
        return fops.fused_lut_bwd(a, b, jnp.asarray(acu.lut), acu.offset,
                                  sa, sb, bits=a_bits,
                                  interpret=acu.interpret, emit_acc=True)

    band_gemm = gx_acc
    if part is not None:
        from repro.parallel import acu_shard
        band_gemm = acu_shard.wrap_conv_gx_gemm(gx_acc, ctx, part, acu.m00())

    ckk = cin * kh * kw
    # band height for the input-grad: bound the per-band int32 patch-gradient
    # block — the only patch-shaped intermediate — to a slice of the budget
    from repro.kernels.fused_lut_conv.ops import CONV_VMEM_BUDGET
    bh_gx = max(1, min(ho, (CONV_VMEM_BUDGET // 4)
                       // max(1, 4 * n * wo * ckk)))
    hp_c = h + ph0 + ph1
    wp_c = w_in + pw0 + pw1

    def gx_fn(g, wf, sg, sw):
        wfmat = wf.reshape(cout, -1).astype(jnp.float32)    # (Cout, ckk)
        canvas = jnp.zeros((n, cin, hp_c, wp_c), jnp.int32)
        for s0 in range(0, ho, bh_gx):
            bhb = min(bh_gx, ho - s0)
            g_band = g[:, s0:s0 + bhb].reshape(-1, cout).astype(jnp.float32)
            acc = band_gemm(g_band, wfmat, sg, sw)   # (n*bhb*wo, ckk) int32
            acc = acc.reshape(n, bhb, wo, cin, kh, kw)
            for u in range(kh):
                r0 = s0 * sh + u * dh
                for v in range(kw):
                    c0 = v * dw
                    canvas = canvas.at[
                        :, :, r0:r0 + (bhb - 1) * sh + 1:sh,
                        c0:c0 + (wo - 1) * sw_ + 1:sw_,
                    ].add(acc[:, :, :, :, u, v].transpose(0, 3, 1, 2))
        canvas = canvas[:, :, ph0:ph0 + h, pw0:pw0 + w_in]
        s = _pin(jnp.asarray(sg, jnp.float32) * jnp.asarray(sw, jnp.float32))
        return canvas.astype(jnp.float32) * s

    return gx_fn, gw_fn


def _get_conv_ste_fn(acu: Acu, a_bits: int, w_bits: int, plan, ctx=None,
                     approx_bwd: bool = False):
    """Per-(ACU, geometry) custom_vjp conv: fused patch-streaming forward,
    STE backward — exact f32 by default, or through the ACU with
    ``approx_bwd`` (the ApproxTrain regime, see :func:`_conv_bwd_fns`).

    ``plan`` is the caller's already-resolved fused-conv
    :class:`~repro.core.acu.ConvPlan` (the route dispatches through it;
    under an active mesh it runs sharded per the ``acu_conv`` partition).
    The exact backward keeps explicit im2col — the weight-grad GEMM needs
    the patch matrix — but its two GEMMs route through the same spec-matched
    sharded wrappers as the dense STE (``gcols`` row-sharded like the output
    pixels, ``gw`` column-sharded like the output channels). The approximate
    backward follows ``plan.bwd_route`` instead — banded kernels that never
    materialize the patch tensor. Either way sharded QAT gradients stay
    bitwise identical to single-device ones.
    """
    assert plan.route in ("fused_conv", "tiled"), plan.route
    spec = plan.spec
    key = ("conv", plan.route, id(acu), a_bits, w_bits, spec, approx_bwd,
           plan.bwd_route if approx_bwd else None, _mesh_cache_key(ctx))
    if key in _STE_CACHE:
        return _STE_CACHE[key]

    cout, _, kh, kw = spec.w_shape
    if approx_bwd:
        gx_bwd, gw_bwd = _conv_bwd_fns(acu, plan, a_bits, ctx)
    elif plan.partition is not None:
        from repro.parallel.acu_shard import bwd_gemms
        gx_gemm, gw_gemm = bwd_gemms(ctx, plan.partition)
    else:
        gx_gemm = lambda g, wf: g @ wf.T
        gw_gemm = lambda xf, g: xf.T @ g

    @jax.custom_vjp
    def ste_conv(x, w, xs, xz, ws, wz):
        wqp = QParams(scale=ws, zero_point=wz, bits=w_bits, axis=0)
        wq = acu_operand(quantize(w, wqp), wqp)
        return plan(x, wq, xs, xz, ws)          # (N, Ho, Wo, Cout) f32

    def fwd(x, w, xs, xz, ws, wz):
        y = ste_conv(x, w, xs, xz, ws, wz)
        xqp = QParams(scale=xs, zero_point=xz, bits=a_bits)
        wqp = QParams(scale=ws, zero_point=wz, bits=w_bits, axis=0)
        xf = fake_quantize(x, xqp).astype(x.dtype)
        wf = fake_quantize(w, wqp).astype(w.dtype)
        return y, (xf, wf)

    if approx_bwd:
        from .quantization import inline_symmetric_scale

        def bwd(res, g):
            # scales on the FULL tensors (every mesh shard must see the same
            # ones), with the in-graph scale expression that compiles
            # identically across eager/jit/SPMD contexts
            xf, wf = res
            g = g.astype(jnp.float32)           # (N, Ho, Wo, Cout)
            sg = inline_symmetric_scale(jnp.max(jnp.abs(g)), a_bits)
            sx = inline_symmetric_scale(jnp.max(jnp.abs(xf)), a_bits)
            sw = inline_symmetric_scale(jnp.max(jnp.abs(wf)), a_bits)
            gx = gx_bwd(g, wf.astype(jnp.float32), sg, sw).astype(xf.dtype)
            gw = gw_bwd(xf, g, sx, sg).astype(wf.dtype)
            return (gx, gw, None, None, None, None)
    else:
        def bwd(res, g):
            xf, wf = res
            g2 = g.reshape(-1, cout).astype(jnp.float32)        # (N*P, Cout)
            wfmat = wf.reshape(cout, -1).T.astype(jnp.float32)  # (C*kh*kw, Cout)
            colsf, col_vjp = jax.vjp(
                lambda t: _im2col(t, kh, kw, spec.stride, spec.padding,
                                  spec.dilation)[0],
                xf.astype(jnp.float32))
            gcols = gx_gemm(g2, wfmat)                          # (N*P, C*kh*kw)
            gw = gw_gemm(colsf.reshape(-1, colsf.shape[-1]), g2)
            (gx,) = col_vjp(gcols.reshape(colsf.shape))
            return (gx.astype(xf.dtype),
                    gw.T.reshape(wf.shape).astype(wf.dtype),
                    None, None, None, None)

    ste_conv.defvjp(fwd, bwd)
    _STE_CACHE[key] = ste_conv
    return ste_conv


def conv_plan_report(x_shape: Sequence[int], w_shape: Sequence[int],
                     cfg: ApproxConfig, *, stride: Sequence[int] = (1, 1),
                     padding="SAME", dilation: Sequence[int] = (1, 1),
                     groups: int = 1) -> dict:
    """Resolve (without running) the conv route one layer would take under
    the current mesh context — route, fusion, partition spec, and every
    audited fallback. What ``examples/quickstart.py`` prints."""
    from .acu import ConvSpec, conv_plan, resolve_conv_padding
    stride, dilation = tuple(stride), tuple(dilation)
    pad = resolve_conv_padding(padding, tuple(x_shape), tuple(w_shape),
                               stride, dilation)
    spec = ConvSpec(x_shape=tuple(x_shape), w_shape=tuple(w_shape),
                    stride=stride, padding=pad, dilation=dilation,
                    groups=groups)
    fused = cfg.acu.fused if cfg.fused is None else cfg.fused
    return conv_plan(cfg.acu, spec, a_bits=cfg.a_bits,
                     fused=fused).describe()


def conv2d(x: Array, w: Array, b: Optional[Array] = None, *,
           stride: Sequence[int] = (1, 1), padding="SAME",
           dilation: Sequence[int] = (1, 1), groups: int = 1,
           cfg: Optional[ApproxConfig] = None, route: Optional[str] = None,
           xqp: Optional[QParams] = None, wqp: Optional[QParams] = None) -> Array:
    """2-D convolution with the full vanilla-PyTorch parameter surface
    (stride/padding/dilation/groups).

    ``x``: (N, Cin, H, W); ``w``: (Cout, Cin/groups, kh, kw). With an
    ``ApproxConfig`` the execution route is resolved by
    :func:`~repro.core.acu.conv_plan`: LUT-mode Pallas ACUs stream im2col
    patches inside one fused quantize->LUT-GEMM->dequant kernel — the
    whole-image variant when the image fits the VMEM budget, the
    spatially-tiled halo variant above it (ImageNet-scale feature maps) —
    everything else lowers to eager im2col + (approx) GEMM exactly as in
    the paper (§3.3.1, Fig. 3). ``route="im2col"`` pins the eager path
    (benchmark baseline / test oracle); ``route="tiled"`` pins the tiled
    kernel. ``xqp``/``wqp`` override the groups=1 quantizers (``wqp``
    per-output-channel, axis=0).
    """
    n, cin, _, _ = x.shape
    cout, cin_g, kh, kw = w.shape
    assert cin == cin_g * groups, (cin, cin_g, groups)

    if cfg is None:
        # exact substrate path: native conv (XLA picks the fast algorithm)
        pad = padding if isinstance(padding, str) else tuple(padding)
        y = jax.lax.conv_general_dilated(
            x, w, tuple(stride), pad, rhs_dilation=tuple(dilation),
            feature_group_count=groups,
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        if b is not None:
            y = y + b.reshape(1, -1, 1, 1)
        return y

    from .acu import ConvSpec, conv_plan, resolve_conv_padding
    stride, dilation = tuple(stride), tuple(dilation)
    pad = resolve_conv_padding(padding, x.shape, w.shape, stride, dilation)
    spec = ConvSpec(x_shape=tuple(x.shape), w_shape=tuple(w.shape),
                    stride=stride, padding=pad, dilation=dilation,
                    groups=groups)
    if cfg.fake_quant_only:
        # the fake-quant QAT path runs through approx_dense — the integer
        # LUT kernel would silently break the fake_quantize(x)@fake_quantize(w)
        # contract, so a pinned fused route is a caller error
        if route in ("fused_conv", "tiled"):
            raise ValueError(f"route={route!r} contradicts "
                             f"cfg.fake_quant_only (the fused kernel runs "
                             f"the integer ACU GEMM, not fake-quant)")
        route = "im2col"
    fused = cfg.acu.fused if cfg.fused is None else cfg.fused
    from repro.parallel.sharding import current_mesh_context
    ctx = current_mesh_context()
    plan = conv_plan(cfg.acu, spec, a_bits=cfg.a_bits, fused=fused,
                     mesh=ctx or False, route=route)

    if plan.route in ("fused_conv", "tiled"):
        xqp, wqp = _conv_qparams(x, w, cfg, xqp, wqp)
        fn = _get_conv_ste_fn(cfg.acu, cfg.a_bits, cfg.w_bits, plan, ctx=ctx,
                              approx_bwd=cfg.approx_bwd)
        y = fn(x, w, xqp.scale, xqp.zero_point, wqp.scale, wqp.zero_point)
        y = y.transpose(0, 3, 1, 2).astype(x.dtype)
    elif plan.route == "im2col":
        xqp, wqp = _conv_qparams(x, w, cfg, xqp, wqp)
        cols, (ho, wo) = _im2col(x, kh, kw, stride, pad, dilation)
        wmat = w.reshape(cout, -1).T                       # (C*kh*kw, Cout)
        m = cols.reshape(-1, cols.shape[-1])               # (N*Ho*Wo, C*kh*kw)
        wqp_mat = QParams(scale=wqp.scale, zero_point=wqp.zero_point,
                          bits=wqp.bits, axis=1)
        y = approx_dense(m, wmat, None, cfg, xqp=xqp, wqp=wqp_mat)
        y = y.reshape(n, ho, wo, cout).transpose(0, 3, 1, 2)
    elif plan.route == "im2col_depthwise":
        # depthwise through the ACU: single GEMM against a block-diagonal
        # weight. M[0, x] == 0 for every multiplier family here, so the
        # structural zeros are exact through the ACU.
        cols, (ho, wo) = _im2col(x, kh, kw, stride, pad, dilation)
        m = cols.reshape(-1, cols.shape[-1])               # (N*P, C*kh*kw)
        kk = kh * kw
        wblk = jnp.zeros((cin * kk, cout), x.dtype)
        ch = jnp.repeat(jnp.arange(cin), kk)
        rows = jnp.arange(cin * kk)
        mult = cout // cin
        wflat = w.reshape(cout, kk)  # channel c output o uses its own kernel
        for o_in_c in range(mult):
            cols_idx = ch * mult + o_in_c
            wblk = wblk.at[rows, cols_idx].set(
                wflat[ch * mult + o_in_c, jnp.tile(jnp.arange(kk), cin)])
        y = approx_dense(m, wblk, None, cfg)
        y = y.reshape(n, ho, wo, cout).transpose(0, 3, 1, 2)
    else:
        # grouped conv as ONE vmapped GEMM over the group axis: patch
        # features from a single im2col are channel-major, so each group's
        # block is a contiguous (cpg_in*kh*kw) slice. Traces O(1)
        # approx_dense calls instead of O(groups), and the per-group
        # activation qparams (amax inside the vmapped call) match the old
        # per-group loop bitwise.
        cpg_in, cpg_out = cin // groups, cout // groups
        cols, (ho, wo) = _im2col(x, kh, kw, stride, pad, dilation)
        kk = kh * kw
        m = cols.reshape(n, ho * wo, groups, cpg_in * kk)
        m = m.transpose(2, 0, 1, 3).reshape(groups, n * ho * wo, cpg_in * kk)
        wg = w.reshape(groups, cpg_out, cpg_in * kk).transpose(0, 2, 1)
        yg = jax.vmap(lambda mg, wgg: approx_dense(mg, wgg, None, cfg))(m, wg)
        y = yg.reshape(groups, n, ho * wo, cpg_out).transpose(1, 2, 0, 3)
        y = y.reshape(n, ho, wo, cout).transpose(0, 3, 1, 2)
    if b is not None:
        # same best-effort as approx_dense: keep dequant-multiply and
        # bias-add as two separate roundings across compilation contexts
        # (residual 1-ulp FMA caveat under jitted mesh programs —
        # docs/sharding.md)
        y = pin_rounding(y) + b.reshape(1, -1, 1, 1)
    return y


def separable_conv2d(x: Array, w_dw: Array, w_pw: Array,
                     b: Optional[Array] = None, *, stride=(1, 1), padding="SAME",
                     cfg: Optional[ApproxConfig] = None) -> Array:
    """Depthwise (groups=Cin) + pointwise (1x1) conv — paper eq. (3)."""
    cin = x.shape[1]
    y = conv2d(x, w_dw, None, stride=stride, padding=padding, groups=cin, cfg=cfg)
    return conv2d(y, w_pw, b, stride=(1, 1), padding="VALID", cfg=cfg)
