"""Approximate multiplier zoo.

Bit-level closed-form models of approximate hardware multipliers, any bitwidth.
Each multiplier is a vectorized integer function ``fn(a, w) -> int32/int64``
over signed operands in ``[-2^(b-1), 2^(b-1)-1]``.

The EvoApprox netlists used by the paper are not available offline; these
families cover the same design space (see DESIGN.md §9):

* ``exact``          — reference multiplier.
* ``trunc(t)``       — operand truncation: low ``t`` bits of both operands gated
                       to zero (classic fixed-width truncation).
* ``bam(k)``         — broken-array multiplier: partial products on diagonals
                       ``i + j < k`` perforated (sign-magnitude core).
* ``mitchell``       — Mitchell logarithmic multiplier (piecewise-linear log).
* ``drum(k)``        — DRUM-style dynamic-range multiplier: top-``k``-bit
                       windows with LSB set for unbiasedness.

``mul8s_1L2H`` / ``mul12s_2KM`` name the paper's two evaluation roles
("lossy, low-power 8-bit" / "near-exact 12-bit"); measured MAE/MRE are
reported by :func:`error_stats` and in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Multiplier:
    """A b-bit x b-bit signed approximate multiplier model."""

    name: str
    bits: int
    fn: Callable[[Array, Array], Array]
    description: str = ""

    def __call__(self, a: Array, w: Array) -> Array:
        return self.fn(jnp.asarray(a), jnp.asarray(w))

    @property
    def lo(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def hi(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def n_codes(self) -> int:
        return 1 << self.bits


def _acc_dtype(bits: int):
    # 2*bits + log2(K) accumulation headroom; int32 is fine through 12-bit
    # operands (24-bit products), int64 beyond.
    return jnp.int32 if bits <= 12 else jnp.int64


# ---------------------------------------------------------------------------
# multiplier families
# ---------------------------------------------------------------------------

def exact_fn(a: Array, w: Array) -> Array:
    return a.astype(jnp.int32) * w.astype(jnp.int32)


def make_exact(bits: int) -> Multiplier:
    return Multiplier(f"mul{bits}s_exact", bits, exact_fn, "exact reference")


def make_trunc(bits: int, t: int) -> Multiplier:
    """Gate the low ``t`` bits of both operands to zero, then multiply exactly.

    Two's-complement masking (``a & ~mask``) models a hardware multiplier whose
    low partial-product columns driven by operand LSBs are tied off.
    """
    mask = ~((1 << t) - 1)

    def fn(a: Array, w: Array) -> Array:
        a = a.astype(jnp.int32) & mask
        w = w.astype(jnp.int32) & mask
        return a * w

    return Multiplier(f"mul{bits}s_trunc{t}", bits, fn,
                      f"operand truncation, {t} LSBs gated")


def make_bam(bits: int, k: int) -> Multiplier:
    """Broken-array multiplier: drop partial-product diagonals ``i+j < k``.

    Sign-magnitude core: ``p = sign(a)*sign(w) * sum_{i+j>=k} a_i w_j 2^(i+j)``.
    """

    def fn(a: Array, w: Array) -> Array:
        a = a.astype(jnp.int32)
        w = w.astype(jnp.int32)
        sgn = jnp.sign(a) * jnp.sign(w)
        ma = jnp.abs(a)
        mw = jnp.abs(w)
        acc = jnp.zeros(jnp.broadcast_shapes(a.shape, w.shape), jnp.int32)
        for i in range(bits):  # unrolled at trace time; bits is small
            bit_i = (ma >> i) & 1
            jmin = max(0, k - i)
            if jmin >= bits:
                continue
            w_kept = mw & ~((1 << jmin) - 1)
            acc = acc + (bit_i * w_kept << i)
        return sgn * acc

    return Multiplier(f"mul{bits}s_bam{k}", bits, fn,
                      f"broken-array, diagonals < {k} perforated")


def make_mitchell(bits: int) -> Multiplier:
    """Mitchell logarithmic multiplier (sign-magnitude).

    ``m = 2^k (1+x)`` with ``x in [0,1)``; ``m1*m2 ~= 2^(k1+k2) (1+x1+x2)`` when
    ``x1+x2 < 1`` else ``2^(k1+k2+1) (x1+x2)``. Integer-exact fixed-point
    evaluation (Q(bits) fraction), zero-safe.
    """
    fb = 15  # Q(fb) fraction for x1+x2; keeps all intermediates inside int32

    def fn(a: Array, w: Array) -> Array:
        a = a.astype(jnp.int32)
        w = w.astype(jnp.int32)
        sgn = jnp.sign(a) * jnp.sign(w)
        ma = jnp.abs(a)
        mw = jnp.abs(w)
        safe_ma = jnp.maximum(ma, 1)
        safe_mw = jnp.maximum(mw, 1)
        # exact floor(log2 m) for m < 2^24 via float32 log2
        k1 = jnp.floor(jnp.log2(safe_ma.astype(jnp.float32))).astype(jnp.int32)
        k2 = jnp.floor(jnp.log2(safe_mw.astype(jnp.float32))).astype(jnp.int32)
        # x in Q(fb): x = (m - 2^k) / 2^k  (exact: m < 2^bits, fb+bits < 31)
        x1 = ((safe_ma - (1 << k1)) << fb) // jnp.maximum(1 << k1, 1)
        x2 = ((safe_mw - (1 << k2)) << fb) // jnp.maximum(1 << k2, 1)
        s = x1 + x2
        one = jnp.int32(1) << fb
        ksum = k1 + k2

        def shift_to(v: Array, sh: Array) -> Array:
            # v * 2^sh with truncation, overflow-safe split shifts
            left = v << jnp.clip(sh, 0, 30)
            right = v >> jnp.clip(-sh, 0, 30)
            return jnp.where(sh >= 0, left, right)

        p_nc = shift_to(one + s, ksum - fb)          # (1+x1+x2) * 2^ksum
        p_c = shift_to(s, ksum + 1 - fb)             # (x1+x2) * 2^(ksum+1)
        p = jnp.where(s < one, p_nc, p_c)
        p = jnp.where((ma == 0) | (mw == 0), 0, p)
        return sgn * p

    return Multiplier(f"mul{bits}s_mitchell", bits, fn, "Mitchell log multiplier")


def make_drum(bits: int, k: int) -> Multiplier:
    """DRUM-style: multiply the leading-``k``-bit windows, LSB set (unbiased)."""

    def fn(a: Array, w: Array) -> Array:
        a = a.astype(jnp.int32)
        w = w.astype(jnp.int32)
        sgn = jnp.sign(a) * jnp.sign(w)
        ma = jnp.abs(a)
        mw = jnp.abs(w)

        def window(m):
            safe = jnp.maximum(m, 1)
            t = jnp.floor(jnp.log2(safe.astype(jnp.float32))).astype(jnp.int32)
            shift = jnp.maximum(t - (k - 1), 0)
            wnd = ((m >> shift) | jnp.where(shift > 0, 1, 0)) << shift
            return jnp.where(m == 0, 0, wnd)

        return sgn * (window(ma) * window(mw))

    return Multiplier(f"mul{bits}s_drum{k}", bits, fn,
                      f"DRUM dynamic-range, {k}-bit windows")


# ---------------------------------------------------------------------------
# registry + named roles from the paper
# ---------------------------------------------------------------------------

def _registry() -> dict[str, Multiplier]:
    muls = [
        make_exact(8), make_exact(12),
        make_trunc(8, 2), make_trunc(8, 3), make_trunc(8, 4),
        make_trunc(12, 2), make_trunc(12, 3),
        make_bam(8, 6), make_bam(8, 8), make_bam(8, 10),
        make_bam(12, 8),
        make_mitchell(8), make_mitchell(12),
        make_drum(8, 4), make_drum(8, 6), make_drum(12, 6),
    ]
    reg = {m.name: m for m in muls}
    # Paper evaluation roles (measured MAE/MRE reported in EXPERIMENTS.md):
    #   mul8s_1L2H : paper MAE 0.081%, MRE 4.41%  -> bam(8,5): 0.049%, 3.75%
    #   mul12s_2KM : paper MAE 1.2e-6%, MRE 4.7e-4% -> drum(12,11): 6e-6%, 4.8e-5%
    reg["mul8s_1L2H"] = dataclasses.replace(make_bam(8, 5), name="mul8s_1L2H")
    reg["mul12s_2KM"] = dataclasses.replace(make_drum(12, 11), name="mul12s_2KM")
    return reg


REGISTRY = _registry()


def get_multiplier(name: str) -> Multiplier:
    if name not in REGISTRY:
        raise KeyError(f"unknown multiplier {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def error_stats(mult: Multiplier) -> dict[str, float]:
    """Exhaustive MAE / MRE over the full operand grid (EvoApprox convention:
    MAE normalized by the max product magnitude 2^(2b); MRE over nonzero
    exact products)."""
    n = mult.n_codes
    vals = np.arange(mult.lo, mult.hi + 1, dtype=np.int64)
    a = vals[:, None]
    w = vals[None, :]
    exact = a * w
    approx = np.asarray(mult(jnp.asarray(a, jnp.int32), jnp.asarray(w, jnp.int32)),
                        dtype=np.int64)
    err = np.abs(approx - exact)
    mae = float(err.mean() / float(1 << (2 * mult.bits)) * 100.0)
    nz = exact != 0
    mre = float((err[nz] / np.abs(exact[nz])).mean() * 100.0)
    wce = float(err.max())
    return {"mae_pct": mae, "mre_pct": mre, "worst_case_err": wce,
            "n_codes": n, "bits": mult.bits}
