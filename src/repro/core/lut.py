"""LUT generation, error tables, and low-rank error factorization.

The paper's LUT generator tabulates the ACU once (``2^b x 2^b``) so every
multiply becomes a gather (paper §3.4, Fig. 3/4). On TPU we keep the table in
VMEM (``kernels/lut_matmul``). The beyond-paper path factorizes the *error*
table ``E = LUT - a*w`` with an SVD so the gather-bound emulation becomes
MXU matmuls (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .multipliers import Multiplier


def build_lut(mult: Multiplier) -> np.ndarray:
    """Full (2^b, 2^b) int32 product table, indexed by shifted codes
    ``lut[a - lo, w - lo]``."""
    vals = np.arange(mult.lo, mult.hi + 1, dtype=np.int32)
    a = jnp.asarray(vals[:, None])
    w = jnp.asarray(vals[None, :])
    return np.asarray(mult(a, w), dtype=np.int32)


def build_error_table(mult: Multiplier, lut: np.ndarray | None = None) -> np.ndarray:
    """E[a,w] = M[a,w] - a*w (int64 to be safe)."""
    if lut is None:
        lut = build_lut(mult)
    vals = np.arange(mult.lo, mult.hi + 1, dtype=np.int64)
    return lut.astype(np.int64) - vals[:, None] * vals[None, :]


@dataclasses.dataclass(frozen=True)
class LowRankError:
    """Rank-r factorization ``E[a,w] ~= f[a,:] @ g[w,:].T``.

    ``f``: (n_codes, r) float32, ``g``: (n_codes, r) float32, both indexed by
    shifted code. ``fidelity`` quantifies how faithful the factorized emulation
    is to the bit-exact LUT (per scalar multiply).
    """

    rank: int
    f: np.ndarray
    g: np.ndarray
    max_abs_err: float       # max |E - fg| over the grid
    mean_abs_err: float
    exact_frac: float        # fraction of grid entries with |E - fg| < 0.5
    energy: float            # captured singular-value energy fraction


def factorize_error(mult: Multiplier, rank: int,
                    lut: np.ndarray | None = None) -> LowRankError:
    """SVD factorization of the error table, truncated at ``rank``.

    For <=10-bit tables this is a dense SVD; for larger bitwidths a randomized
    range-finder keeps it tractable (the paper's functional fallback regime).
    """
    E = build_error_table(mult, lut).astype(np.float64)
    n = E.shape[0]
    if n <= 1024:
        U, s, Vt = np.linalg.svd(E, full_matrices=False)
    else:
        # randomized SVD: oversampled Gaussian range finder
        rng = np.random.default_rng(0)
        p = min(n, rank + 16)
        Y = E @ rng.standard_normal((n, p))
        Q, _ = np.linalg.qr(Y)
        B = Q.T @ E
        Ub, s, Vt = np.linalg.svd(B, full_matrices=False)
        U = Q @ Ub
    r = min(rank, len(s))
    sq = np.sqrt(s[:r])
    f = (U[:, :r] * sq[None, :]).astype(np.float32)
    g = (Vt[:r, :].T * sq[None, :]).astype(np.float32)
    recon = f.astype(np.float64) @ g.astype(np.float64).T
    d = np.abs(E - recon)
    tot = float((s ** 2).sum()) or 1.0
    return LowRankError(
        rank=r, f=f, g=g,
        max_abs_err=float(d.max()),
        mean_abs_err=float(d.mean()),
        exact_frac=float((d < 0.5).mean()),
        energy=float((s[:r] ** 2).sum() / tot),
    )


def rank_for_fidelity(mult: Multiplier, max_rank: int = 64,
                      target_exact_frac: float = 1.0) -> LowRankError:
    """Smallest rank whose rounded reconstruction reaches the target exact
    fraction (doubling search, then the best found)."""
    lut = build_lut(mult)
    best = None
    r = 1
    while r <= max_rank:
        lr = factorize_error(mult, r, lut)
        best = lr
        if lr.exact_frac >= target_exact_frac:
            return lr
        r *= 2
    return best


def trunc_masks(mult: Multiplier) -> int | None:
    """If ``mult`` is from the truncation family, return its LSB mask so the
    FACTORED (algebraically exact) path can be used: M[a,w] = (a&m)*(w&m)."""
    if "_trunc" in mult.name:
        t = int(mult.name.rsplit("trunc", 1)[-1])
        return ~((1 << t) - 1)
    return None
