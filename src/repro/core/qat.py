"""Quantization flow orchestration (paper Fig. 1 / Fig. 2).

``calibrate_model``: run a representative data subset through the model,
collecting activation histograms at every approx site -> QParams per site.
``qat_finetune`` is implemented by the trainer (train/trainer.py) using the
approximate forward / exact STE backward GEMM from approx_ops; this module
holds the site registry utilities shared by both.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from .calibration import HistogramObserver, calibrate_activation, calibrate_weight
from .quantization import QParams

Array = jnp.ndarray


@dataclasses.dataclass
class SiteStats:
    """Calibration state for one approximate GEMM call site."""

    observer: HistogramObserver = dataclasses.field(default_factory=HistogramObserver)
    qparams: Optional[QParams] = None


class CalibrationRegistry:
    """Collects activation statistics per named call site.

    Models call ``registry.observe(name, x)`` inside their forward pass when
    running in calibration mode (eager, not jitted); afterwards
    ``finalize(bits, method)`` turns every site's histogram into QParams.
    """

    def __init__(self) -> None:
        self.sites: Dict[str, SiteStats] = {}

    def observe(self, name: str, x: Array) -> Array:
        self.sites.setdefault(name, SiteStats()).observer.update(x)
        return x

    def finalize(self, bits: int, method: str = "percentile",
                 affine: bool = True, pct: float = 99.9) -> Dict[str, QParams]:
        out = {}
        for name, st in self.sites.items():
            st.qparams = calibrate_activation(st.observer, bits, method=method,
                                              affine=affine, pct=pct)
            out[name] = st.qparams
        return out


def calibrate_weights_tree(params, bits: int, axis: int = -1):
    """Per-channel symmetric QParams for every 2-D weight leaf; returns a
    parallel dict keyed by flattened path."""
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = {}
    for path, leaf in flat:
        if hasattr(leaf, "ndim") and leaf.ndim == 2:
            key = "/".join(str(p) for p in path)
            out[key] = calibrate_weight(leaf, bits, axis=leaf.ndim - 1 if axis == -1 else axis)
    return out
