"""Approximate Compute Units (paper §3.3 / §3.4).

An :class:`Acu` packages one approximate multiplier with an emulation *mode*:

* ``FUNCTIONAL`` — evaluate the multiplier's closed form per scalar product and
  reduce. This is the paper's *unoptimized baseline* regime (the 76.5-min
  ResNet50 row): it materializes (or streams) the full (M, K, N) product
  tensor. Kept as the oracle and the speedup denominator.
* ``LUT`` — the paper's optimized engine, adapted to TPU: the (2^b, 2^b)
  product table lives in VMEM; each GEMM tile does vectorized gathers
  (``kernels/lut_matmul``). Bit-exact.
* ``LOWRANK`` — beyond-paper: exact int MXU matmul + rank-r SVD error
  correction (DESIGN.md §3). Near-exact, with fidelity measured offline.
* ``FACTORED`` — algebraically exact fast path for the truncation family:
  ``M[a,w] = (a & m)(w & m)`` is a single masked int matmul.
* ``EXACT`` — no approximation (quantization-only reference).

All modes consume *shifted-code* integer operands (``code - zero_point``).

Dispatch is two-level: :func:`matmul_plan` (dense GEMMs) and
:func:`conv_plan` (conv2d sites, mirroring it at static geometry) first
resolve (mode, bits, use_pallas, fused) to a kernel — the conv fused routes
are the patch-streaming ``kernels/fused_lut_conv`` kernels (whole-image
inside the VMEM budget, spatially tiled over halo'd output-row bands above
it), which never materialize the im2col patch tensor — then, when a
:class:`~repro.parallel.sharding.MeshContext` is active, wrap it in a
``shard_map`` over the production mesh (``parallel/acu_shard.py``): LUT
replicated, rows over ``("pod", "data")``, columns over ``("model",)``,
optional contraction sharding with an int32 psum before dequant. Every
route stays bit-exact against the single-device jnp oracle.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# the per-core VMEM budget for the fused conv kernels lives with the VMEM
# model in kernels/fused_lut_conv/ops.py (single source of truth);
# re-exported here as the planning-layer API. Images whose whole-image
# working set exceeds it resolve to the spatially-tiled kernel; geometries
# where even a one-row band exceeds it fall back to eager im2col.
from repro.kernels.fused_lut_conv.ops import CONV_VMEM_BUDGET

from .lut import LowRankError, build_lut, factorize_error, trunc_masks
from .multipliers import Multiplier, get_multiplier

Array = jnp.ndarray


class AcuMode(enum.Enum):
    FUNCTIONAL = "functional"
    LUT = "lut"
    LOWRANK = "lowrank"
    FACTORED = "factored"
    EXACT = "exact"


@dataclasses.dataclass(frozen=True)
class Acu:
    multiplier: Multiplier
    mode: AcuMode
    lut: Optional[np.ndarray] = None          # (2^b, 2^b) int32
    lowrank: Optional[LowRankError] = None
    mask: Optional[int] = None                # FACTORED path
    use_pallas: bool = False                  # route GEMMs through Pallas kernels
    interpret: bool | None = None             # None: repro.kernels.runtime default
    lut_chunk: int = 256                      # K-chunk for LUT gathers; 0 = the
                                              # paper's unoptimized baseline
                                              # (full (M,K,N) materialization)
    fused: bool = False                       # default routing for approx_ops:
                                              # single-kernel quantize->LUT
                                              # GEMM->dequant (LUT+Pallas only)

    @property
    def bits(self) -> int:
        return self.multiplier.bits

    @property
    def offset(self) -> int:
        return -self.multiplier.lo  # code shift into table index space

    def m00(self) -> int:
        """The multiplier's product at shifted code (0, 0) — the integer every
        padded-K entry contributes to an accumulator (0 for exact-at-zero
        families; the synthetic biased multipliers exercise the general case)."""
        if self.mode == AcuMode.LUT and self.lut is not None:
            return int(np.asarray(self.lut)[self.offset, self.offset])
        if self.mode in (AcuMode.EXACT, AcuMode.FACTORED, AcuMode.LOWRANK):
            return 0
        return int(self.multiplier(np.zeros((), np.int32),
                                   np.zeros((), np.int32)))

    # ------------------------------------------------------------------
    # elementwise multiply (used by tests and conv inner loops)
    # ------------------------------------------------------------------
    def mul(self, a: Array, w: Array) -> Array:
        if self.mode == AcuMode.EXACT:
            return a.astype(jnp.int32) * w.astype(jnp.int32)
        if self.mode == AcuMode.FACTORED:
            return (a & self.mask) * (w & self.mask)
        if self.mode == AcuMode.LUT:
            tab = jnp.asarray(self.lut)
            return tab[a + self.offset, w + self.offset]
        if self.mode == AcuMode.LOWRANK:
            exact = a.astype(jnp.float32) * w.astype(jnp.float32)
            f = jnp.asarray(self.lowrank.f)[a + self.offset]
            g = jnp.asarray(self.lowrank.g)[w + self.offset]
            return exact + (f * g).sum(-1)
        return self.multiplier(a, w)

    # ------------------------------------------------------------------
    # GEMM: out[m, n] = sum_k M[a[m, k], w[k, n]]
    # ------------------------------------------------------------------
    def matmul(self, a: Array, w: Array) -> Array:
        """Approximate GEMM on integer operands. Returns int32 (exact modes)
        or float32 (LOWRANK — the SVD correction is real-valued).

        Thin wrapper over :func:`matmul_plan` (the explicit dispatch layer);
        always the unfused integer-operand form. Mesh-aware: under an active
        :func:`~repro.parallel.sharding.use_mesh` the GEMM runs sharded.
        """
        return matmul_plan(self, fused=False)(a, w)

    # -- pure-jnp implementations (portable; Pallas kernels mirror these) --

    def _lut_matmul_jnp(self, a: Array, w: Array, k_chunk: int = 256) -> Array:
        tab = jnp.asarray(self.lut).reshape(-1)
        n_codes = self.multiplier.n_codes
        M, K = a.shape
        _, N = w.shape
        ai = (a + self.offset).astype(jnp.int32)
        wi = (w + self.offset).astype(jnp.int32)
        k_chunk = min(k_chunk, K)
        pad = (-K) % k_chunk
        if pad:
            ai = jnp.pad(ai, ((0, 0), (0, pad)), constant_values=self.offset)
            wi = jnp.pad(wi, ((0, pad), (0, 0)), constant_values=self.offset)
        nk = ai.shape[1] // k_chunk
        ai = ai.reshape(M, nk, k_chunk)
        wi = wi.reshape(nk, k_chunk, N)

        def body(acc, inputs):
            ac, wc = inputs  # (M, kc), (kc, N)
            idx = ac[:, :, None] * n_codes + wc[None, :, :]
            acc = acc + jnp.take(tab, idx.reshape(-1)).reshape(M, k_chunk, N).sum(axis=1)
            return acc, None

        init = jnp.zeros((M, N), jnp.int32)
        acc, _ = jax.lax.scan(body, init, (ai.transpose(1, 0, 2), wi))
        if pad:  # padded entries contribute LUT[off, off] = M[0, 0]
            zz = jnp.asarray(self.lut)[self.offset, self.offset].astype(jnp.int32)
            acc = acc - pad * zz
        return acc

    def _lowrank_matmul_jnp(self, a: Array, w: Array) -> Array:
        r = self.lowrank.rank
        K = a.shape[-1]
        exact = jax.lax.dot(
            a.astype(jnp.int8 if self.bits <= 8 else jnp.bfloat16),
            w.astype(jnp.int8 if self.bits <= 8 else jnp.bfloat16),
            preferred_element_type=jnp.int32 if self.bits <= 8 else jnp.float32,
        ).astype(jnp.float32)
        f = jnp.take(jnp.asarray(self.lowrank.f), a + self.offset, axis=0)  # (M,K,r)
        g = jnp.take(jnp.asarray(self.lowrank.g), w + self.offset, axis=0)  # (K,N,r)
        M = a.shape[0]
        N = w.shape[1]
        corr = f.reshape(M, K * r) @ g.transpose(0, 2, 1).reshape(K * r, N)
        return exact + corr

    def _functional_matmul_jnp(self, a: Array, w: Array, k_chunk: int = 32) -> Array:
        M, K = a.shape
        _, N = w.shape
        k_chunk = min(k_chunk, K)
        pad = (-K) % k_chunk
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)))
            w = jnp.pad(w, ((0, pad), (0, 0)))
        nk = a.shape[1] // k_chunk
        ar = a.reshape(M, nk, k_chunk).transpose(1, 0, 2)
        wr = w.reshape(nk, k_chunk, N)

        def body(acc, inputs):
            ac, wc = inputs
            prods = self.multiplier(ac[:, :, None], wc[None, :, :])
            return acc + prods.sum(axis=1).astype(jnp.int64), None

        acc, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.int64), (ar, wr))
        if pad:
            z0 = self.multiplier(jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
            acc = acc - pad * z0.astype(jnp.int64)
        return acc.astype(jnp.int32)


# ---------------------------------------------------------------------------
# explicit dispatch layer: (mode, bits, use_pallas, fused) -> callable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    """A resolved GEMM route for one ACU.

    ``fused=False`` plans consume shifted integer operands and return the raw
    accumulator: ``plan(a, w) -> int32`` (float32 for LOWRANK). ``fused=True``
    plans run the whole quantize -> LUT GEMM -> dequant pipeline in one Pallas
    kernel: ``plan(x, wq, x_scale, x_zp, w_scale) -> float32`` where ``x`` is
    the float activation matrix and ``wq`` the shifted weight codes.

    ``partition`` records the mesh partition the plan executes under
    (``None`` = single-device); the wrapped ``fn`` already contains the
    ``shard_map`` — callers never change.
    """

    mode: AcuMode
    bits: int
    use_pallas: bool
    fused: bool
    fn: Callable[..., Array]
    partition: Optional[object] = None   # parallel.planner.GemmPartition

    def __call__(self, *args) -> Array:
        return self.fn(*args)


def _resolve_unfused(acu: Acu) -> Callable[[Array, Array], Array]:
    """The unfused integer-operand GEMM for ``acu`` (pure-jnp oracles or the
    per-mode Pallas kernels)."""
    if acu.mode == AcuMode.EXACT:
        def fn(a, w):
            if acu.bits <= 8:
                return jax.lax.dot(a.astype(jnp.int8), w.astype(jnp.int8),
                                   preferred_element_type=jnp.int32)
            return a.astype(jnp.int32) @ w.astype(jnp.int32)
        return fn
    if acu.mode == AcuMode.FACTORED:
        def fn(a, w):
            return (a & acu.mask).astype(jnp.int32) @ \
                   (w & acu.mask).astype(jnp.int32)
        return fn
    if acu.mode == AcuMode.LUT:
        if acu.use_pallas:
            from repro.kernels.lut_matmul import ops as lops
            return lambda a, w: lops.lut_matmul(
                a, w, jnp.asarray(acu.lut), acu.offset, interpret=acu.interpret)
        if acu.lut_chunk == 0:
            # paper's "baseline approximate": LUTs without the
            # vectorization/chunking optimizations — one (M, K, N) gather
            from repro.kernels.lut_matmul.ref import lut_matmul_ref
            return lambda a, w: lut_matmul_ref(
                a, w, jnp.asarray(acu.lut).reshape(-1), acu.offset,
                acu.multiplier.n_codes)
        return lambda a, w: acu._lut_matmul_jnp(a, w, k_chunk=acu.lut_chunk)
    if acu.mode == AcuMode.LOWRANK:
        if acu.use_pallas:
            from repro.kernels.err_matmul import ops as eops
            return lambda a, w: eops.err_matmul(
                a, w, jnp.asarray(acu.lowrank.f), jnp.asarray(acu.lowrank.g),
                acu.offset, interpret=acu.interpret)
        return acu._lowrank_matmul_jnp
    # FUNCTIONAL: stream over K chunks to bound the (M, Kc, N) intermediate
    return acu._functional_matmul_jnp


def _resolve_mesh(mesh):
    """``mesh`` arg -> active MeshContext or None. ``None`` auto-detects the
    ambient :func:`~repro.parallel.sharding.use_mesh` context; ``False``
    forces single-device resolution."""
    if mesh is False:
        return None
    if mesh is None:
        from repro.parallel.sharding import current_mesh_context
        return current_mesh_context()
    return mesh


def matmul_plan(acu: Acu, *, a_bits: Optional[int] = None,
                fused: Optional[bool] = None, mesh=None) -> MatmulPlan:
    """Resolve (mode, bits, use_pallas, fused) x mesh into a concrete GEMM
    callable.

    ``a_bits`` is the activation code width a fused plan quantizes/clips to
    (defaults to the ACU operand width). A fused request that cannot be
    served — non-LUT mode, no Pallas routing, or no table — silently falls
    back to the unfused plan, so callers can request fusion unconditionally
    and keep the pure-jnp implementations as bit-exact oracles.

    ``mesh``: ``None`` auto-detects the active
    :class:`~repro.parallel.sharding.MeshContext` (plans resolved under
    :func:`~repro.parallel.sharding.use_mesh` run sharded — LUT replicated,
    rows over the ``acu_rows`` axes, columns over ``acu_cols``, optional
    ``acu_k`` contraction sharding with an int32 psum before dequant); a
    :class:`MeshContext` pins one explicitly; ``False`` forces the
    single-device route. Sharded plans stay bit-exact vs their single-device
    counterparts — the wrap only changes where tiles execute.
    """
    fused = acu.fused if fused is None else fused
    a_bits = acu.bits if a_bits is None else a_bits
    ctx = _resolve_mesh(mesh)
    partition = None
    if ctx is not None:
        from repro.parallel import acu_shard
        partition = acu_shard.resolve_partition(
            ctx, float_accum=acu.mode == AcuMode.LOWRANK)

    if fused and acu.mode == AcuMode.LUT and acu.use_pallas \
            and acu.lut is not None:
        from repro.kernels.fused_lut_dense import ops as fops

        def fused_call(x, wq, x_scale, x_zp, w_scale, *, emit_acc=False):
            # jnp.asarray stays inside fn: plans are cached across jit traces
            # and a device constant created during one trace must not leak
            # into another
            return fops.fused_lut_dense(x, wq, jnp.asarray(acu.lut),
                                        acu.offset, x_scale, x_zp, w_scale,
                                        bits=a_bits, interpret=acu.interpret,
                                        emit_acc=emit_acc)
        fn = fused_call
        if partition is not None:
            fn = acu_shard.wrap_fused(
                fused_call,
                lambda *args: fused_call(*args, emit_acc=True),
                ctx, partition, acu.m00())
        return MatmulPlan(mode=acu.mode, bits=acu.bits, use_pallas=True,
                          fused=True, fn=fn, partition=partition)

    fn = _resolve_unfused(acu)
    if partition is not None:
        fn = acu_shard.wrap_unfused(fn, ctx, partition, acu.m00())
    return MatmulPlan(mode=acu.mode, bits=acu.bits, use_pallas=acu.use_pallas,
                      fused=False, fn=fn, partition=partition)


def matmul_bwd_plan(acu: Acu, *, a_bits: Optional[int] = None,
                    fused: Optional[bool] = None, mesh=None
                    ) -> tuple[Callable[..., Array], Callable[..., Array]]:
    """Resolve the *approximate* STE backward GEMM pair for one ACU.

    Returns ``(gx_fn, gw_fn)``; each is ``fn(a, b, sa, sb) -> f32 (M, N)``
    computing the approximate GEMM of two **float** operands quantized
    per-tensor symmetric (zero-point 0 — gradients are zero-centred) with a
    single combined-scale dequant ``acc * (sa * sb)``. The caller computes
    ``sa``/``sb`` on the full tensors (``symmetric_qparams(amax, a_bits)``)
    so every mesh shard sees identical scales. The two callables differ only
    in their mesh partition: each backward GEMM is the forward GEMM with
    permuted roles (``gx = g @ wf.T`` contracts the forward's cols,
    ``gw = xf.T @ g`` contracts the forward's rows), so the permuted
    partitions from :func:`~repro.parallel.planner.bwd_gemm_partitions`
    keep the residuals sharded exactly as the forward left them and psum
    the int32 partials over the contraction axes before dequant.

    Fused (LUT + Pallas + table) resolves to the in-kernel-quantizing
    ``fused_lut_bwd`` kernel; everything else quantizes outside and runs
    the mode's unfused integer GEMM — the two are bit-identical for LUT
    mode, making the unfused composition the test oracle. LOWRANK
    (float accumulator) computes replicated under a mesh: its partials
    cannot psum bit-exactly.
    """
    fused = acu.fused if fused is None else fused
    a_bits = acu.bits if a_bits is None else a_bits
    ctx = _resolve_mesh(mesh)
    gx_part = gw_part = None
    if ctx is not None and acu.mode != AcuMode.LOWRANK:
        from repro.parallel import acu_shard
        fwd_part = acu_shard.resolve_partition(ctx)
        if fwd_part is not None:
            from repro.parallel.planner import bwd_gemm_partitions
            gx_part, gw_part = bwd_gemm_partitions(fwd_part)

    if fused and acu.mode == AcuMode.LUT and acu.use_pallas \
            and acu.lut is not None:
        from repro.kernels.fused_lut_dense import ops as fops

        def bwd_call(a, b, sa, sb, *, emit_acc=False):
            # jnp.asarray stays inside fn: see fused_call in matmul_plan
            return fops.fused_lut_bwd(a, b, jnp.asarray(acu.lut), acu.offset,
                                      sa, sb, bits=a_bits,
                                      interpret=acu.interpret,
                                      emit_acc=emit_acc)

        def route(part):
            if part is None:
                return lambda a, b, sa, sb: bwd_call(a, b, sa, sb)
            from repro.parallel import acu_shard
            return acu_shard.wrap_fused_bwd(
                bwd_call, lambda *args: bwd_call(*args, emit_acc=True),
                ctx, part, acu.m00())

        return route(gx_part), route(gw_part)

    # unfused: quantize outside (full tensors, global scales), run the
    # mode's integer GEMM — sharded via the permuted partition when a mesh
    # is active — dequant once. Bit-identical to the fused kernel for LUT
    # mode (same quantizer expression, same int32 sums, same combined-scale
    # rounding), so this composition doubles as the bit-exactness oracle.
    base = _resolve_unfused(acu)
    lo = -(1 << (a_bits - 1))
    hi = (1 << (a_bits - 1)) - 1

    def route(part):
        gemm = base
        if part is not None:
            from repro.parallel import acu_shard
            gemm = acu_shard.wrap_unfused(base, ctx, part, acu.m00())

        def fn(a, b, sa, sb):
            from .quantization import pin_rounding
            sa_ = jnp.asarray(sa, jnp.float32)
            sb_ = jnp.asarray(sb, jnp.float32)
            qa = jnp.clip(jnp.round(a.astype(jnp.float32) / sa_), lo, hi
                          ).astype(jnp.int32)
            qb = jnp.clip(jnp.round(b.astype(jnp.float32) / sb_), lo, hi
                          ).astype(jnp.int32)
            acc = gemm(qa, qb)
            return acc.astype(jnp.float32) * pin_rounding(sa_ * sb_)

        return fn

    return route(gx_part), route(gw_part)


# ---------------------------------------------------------------------------
# conv planning layer: geometry x (mode, bits, use_pallas, fused) x mesh
# ---------------------------------------------------------------------------

def resolve_conv_padding(padding, x_shape, w_shape, stride, dilation
                         ) -> tuple[tuple[int, int], tuple[int, int]]:
    """Normalize SAME/VALID/explicit conv padding to per-edge pairs, with
    XLA's SAME split (lo = total // 2) so every route — fused kernel, eager
    im2col, exact lax.conv — sees identical geometry."""
    if not isinstance(padding, str):
        (p0, p1) = tuple(padding)
        return (tuple(p0), tuple(p1))
    if padding.upper() == "VALID":
        return ((0, 0), (0, 0))
    if padding.upper() != "SAME":
        raise ValueError(f"unsupported padding {padding!r}")
    pads = []
    for d in range(2):
        size = x_shape[2 + d]
        eff_k = (w_shape[2 + d] - 1) * dilation[d] + 1
        out = -(-size // stride[d])
        total = max((out - 1) * stride[d] + eff_k - size, 0)
        pads.append((total // 2, total - total // 2))
    return (pads[0], pads[1])


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static geometry of one conv2d site (hashable: plan / STE cache key).

    ``x_shape``: (N, Cin, H, W); ``w_shape``: (Cout, Cin/groups, kh, kw);
    ``padding``: explicit ((ph_lo, ph_hi), (pw_lo, pw_hi)) — use
    :func:`resolve_conv_padding` to normalize SAME/VALID first.
    """

    x_shape: tuple[int, int, int, int]
    w_shape: tuple[int, int, int, int]
    stride: tuple[int, int] = (1, 1)
    padding: tuple[tuple[int, int], tuple[int, int]] = ((0, 0), (0, 0))
    dilation: tuple[int, int] = (1, 1)
    groups: int = 1

    @property
    def out_spatial(self) -> tuple[int, int]:
        from repro.kernels.fused_lut_conv.ops import conv_out_size
        return (conv_out_size(self.x_shape[2], self.w_shape[2],
                              self.stride[0], self.dilation[0],
                              self.padding[0]),
                conv_out_size(self.x_shape[3], self.w_shape[3],
                              self.stride[1], self.dilation[1],
                              self.padding[1]))

    @property
    def gemm_shape(self) -> tuple[int, int, int]:
        """(M, K, N) of the implicit im2col GEMM."""
        ho, wo = self.out_spatial
        cout, cg, kh, kw = self.w_shape
        return (self.x_shape[0] * ho * wo, cg * kh * kw, cout)


def _conv_geometry_args(spec: ConvSpec) -> tuple:
    _, c, h, w = spec.x_shape
    cout, _, kh, kw = spec.w_shape
    return (c, h, w, cout, kh, kw, spec.stride[0], spec.stride[1],
            spec.dilation[0], spec.dilation[1], spec.padding)


def _conv_vmem_estimate(spec: ConvSpec, n_codes: int) -> int:
    """Working-set bytes of the whole-image fused conv kernel at this
    geometry, from the kernel's own tile picks and exact padded extents
    (``conv_vmem_bytes`` — one source of truth, including the
    ``(kh-1)*dilation`` halo rows the pre-PR 4 stride-only estimate
    omitted)."""
    from repro.kernels.fused_lut_conv.ops import conv_vmem_bytes
    return conv_vmem_bytes(*_conv_geometry_args(spec), n_codes)


def _fmt_vmem(nbytes: int) -> str:
    """Byte counts in audited report strings: MiB at image scale, KiB below
    (tests resolve tiled plans against shrunken budgets)."""
    if nbytes >= (1 << 20):
        return f"{nbytes >> 20} MiB"
    return f"{nbytes >> 10} KiB"


def _conv_spatial_tiling(spec: ConvSpec, n_codes: int, budget: int
                         ) -> Optional[tuple[int, int, int, int]]:
    """(inner, bh, bn, n_copies) for the spatially-tiled kernel, or None
    when the geometry is degenerate (even a one-row band exceeds the
    budget)."""
    from repro.kernels.fused_lut_conv.ops import pick_conv_spatial_tiling
    return pick_conv_spatial_tiling(*_conv_geometry_args(spec), n_codes,
                                    budget=budget)


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """A resolved conv2d route for one ACU at one static geometry.

    ``route`` is one of

    * ``"fused_conv"`` — the whole-image patch-streaming Pallas kernel
      (``kernels/fused_lut_conv``): im2col, quantize, LUT-GEMM and dequant in
      one pass, the patch tensor never materialized. ``fn(x, wq, xs, xz, ws)
      -> (N, Ho, Wo, Cout) f32`` with ``x`` the float NCHW activations and
      ``wq`` the (Cout, Cin, kh, kw) shifted weight codes; mesh-wrapped when
      a partition is active (callers never change).
    * ``"tiled"`` — the spatially-tiled variant of the same kernel: grid
      over output-row bands, only the halo'd input rows of one band
      VMEM-resident per step. Same ``fn`` signature and bit-identical
      output; chosen when the whole-image working set exceeds the VMEM
      budget (ImageNet-scale feature maps), with the picked tiling recorded
      in ``tiling`` and named in the report.
    * ``"im2col"`` — eager patch extraction + the dense ``matmul_plan`` route
      (which itself resolves fused/unfused x mesh). The audited fallback for
      non-LUT modes, non-Pallas ACUs, and truly degenerate geometry (even a
      one-row band over budget); also the oracle the fused kernels are
      tested against. ``fn`` is None: the caller composes quantize -> GEMM
      -> dequant as before.
    * ``"im2col_depthwise"`` / ``"im2col_grouped"`` — the block-diagonal and
      single-vmapped-GEMM group routes (PR 2 semantics, bitwise preserved).
      ``fn`` is None.

    ``partition`` is the ``acu_conv`` partition for the fused routes (batch
    x output-pixel rows over ``acu_conv_rows`` — with bands over the same
    axes when the batch alone cannot fill them, see
    ``acu_shard.wrap_fused_conv`` — output channels over ``acu_conv_cols``,
    opt-in input-channel contraction over ``acu_conv_k``), or the dense GEMM
    partition the im2col routes will resolve. ``report`` carries every
    audited fallback decision. ``tiling`` is the resolved
    ``(inner, bh, bn, n_copies)`` spatial tiling for the tiled route.

    ``bwd_route`` resolves where the *approximate* STE backward runs when a
    consumer enables it (``ApproxConfig.approx_bwd``): ``"banded"`` — the
    weight-grad streams halo'd output-row bands through
    ``kernels/fused_lut_conv.fused_lut_conv_bwd_w`` and the input-grad
    composes per-band ``fused_lut_bwd`` GEMMs with an integer scatter, so
    the im2col patch tensor never materializes in the backward either;
    ``"im2col"`` — the audited fallback (degenerate geometry under the same
    VMEM budget) that materializes patches and runs the dense approximate
    backward GEMMs. ``None`` for plans whose forward is not fused (their
    backward composes through the dense STE as before).
    ``bwd_tiling`` is the resolved ``(bh, bn, mc, n_copies)`` banding.
    """

    mode: AcuMode
    bits: int
    use_pallas: bool
    fused: bool
    route: str
    spec: ConvSpec
    fn: Optional[Callable[..., Array]] = None
    partition: Optional[object] = None
    report: tuple[str, ...] = ()
    tiling: Optional[tuple[int, int, int, int]] = None
    bwd_route: Optional[str] = None
    bwd_tiling: Optional[tuple[int, int, int, int]] = None

    def __call__(self, *args) -> Array:
        assert self.fn is not None, f"route {self.route} has no direct kernel"
        return self.fn(*args)

    def describe(self) -> dict:
        """Human-readable resolution report (examples/quickstart.py prints
        this so users can see which path their model took)."""
        part = self.partition
        m, k, n = self.spec.gemm_shape
        tiling = None
        if self.tiling is not None:
            inner, bh, bn, n_copies = self.tiling
            ho, _ = self.spec.out_spatial
            tiling = (f"bands of {bh} output rows ({-(-ho // bh)} bands, "
                      f"{n_copies} halo blocks/band, inner={inner} bn={bn})")
        return {
            "route": self.route,
            "bwd_route": self.bwd_route,
            "mode": self.mode.value,
            "fused": self.fused,
            "gemm": f"M={m} K={k} N={n}",
            "tiling": tiling,
            "partition": None if part is None else
                f"rows{part.rows}x cols{part.cols}x k{part.k} "
                f"({part.n_rows}x{part.n_cols}x{part.n_k} way)",
            "report": list(self.report) + (list(part.report) if part else []),
        }


def conv_plan(acu: Acu, spec: ConvSpec, *, a_bits: Optional[int] = None,
              fused: Optional[bool] = None, mesh=None,
              route: Optional[str] = None,
              vmem_budget: Optional[int] = None) -> ConvPlan:
    """Resolve one conv2d site: geometry x (mode, bits, use_pallas, fused) x
    mesh -> a concrete route. Mirrors :func:`matmul_plan`, with the same
    silent-but-audited fallback contract: a fused request that cannot be
    served by the whole-image kernel (groups, non-LUT mode, no Pallas, no
    table) resolves to the eager im2col route; one that only exceeds the
    VMEM budget resolves to the spatially-tiled kernel (``route="tiled"``,
    the chosen banding named in ``plan.report``); eager im2col remains only
    for truly degenerate geometry where even a one-row band is over budget.

    ``route`` pins a route explicitly (``"im2col"`` forces the eager path —
    the benchmark baseline and test oracle; ``"fused_conv"`` / ``"tiled"``
    raise if that kernel cannot serve the request instead of falling back).
    ``vmem_budget`` overrides :data:`CONV_VMEM_BUDGET` (tests exercise the
    tiled resolution on small geometry with a shrunken budget).
    """
    fused = acu.fused if fused is None else fused
    a_bits = acu.bits if a_bits is None else a_bits
    budget = CONV_VMEM_BUDGET if vmem_budget is None else vmem_budget
    ctx = _resolve_mesh(mesh)
    report: list[str] = []

    cout, cin_g, kh, kw = spec.w_shape
    cin = spec.x_shape[1]
    if route not in (None, "fused_conv", "tiled", "im2col"):
        raise ValueError(f"unknown conv route {route!r}")
    want_fused = fused or route in ("fused_conv", "tiled")
    can_fuse = True
    if spec.groups != 1:
        can_fuse = False
        if want_fused:
            report.append(f"groups={spec.groups}: fused conv serves groups=1 "
                          f"only; grouped route keeps the single-vmapped-GEMM "
                          f"semantics")
    if not (acu.mode == AcuMode.LUT and acu.use_pallas
            and acu.lut is not None):
        can_fuse = False
        if want_fused and spec.groups == 1:
            report.append(f"fused conv needs LUT mode + use_pallas + a built "
                          f"table (have mode={acu.mode.value}, "
                          f"use_pallas={acu.use_pallas})")

    if route == "im2col":
        # pinned before the budget resolution: an im2col-pinned plan must
        # not run (or report) a tiling it will never use
        can_fuse = False
        report.append("route pinned to eager im2col by caller")

    whole_ok = False
    tiling = None
    if can_fuse and want_fused:
        est = _conv_vmem_estimate(spec, acu.multiplier.n_codes)
        whole_ok = est <= budget
        if route == "tiled" or not whole_ok:
            tiling = _conv_spatial_tiling(spec, acu.multiplier.n_codes,
                                          budget)
        if not whole_ok:
            if tiling is not None:
                inner, bh, bn, n_copies = tiling
                ho, _ = spec.out_spatial
                report.append(
                    f"image working set ~{_fmt_vmem(est)} exceeds the "
                    f"{_fmt_vmem(budget)} VMEM budget; spatially tiled over "
                    f"output-row bands (bands of {bh} output rows, "
                    f"{-(-ho // bh)} bands, {n_copies} halo blocks/band)")
            else:
                report.append(
                    f"image working set ~{_fmt_vmem(est)} exceeds the "
                    f"{_fmt_vmem(budget)} VMEM budget and even a one-row "
                    f"band does not fit (degenerate geometry); falling "
                    f"back to eager im2col")
        elif route == "tiled":
            report.append("route pinned to spatially-tiled kernel by caller")

    if route == "fused_conv" and not (can_fuse and whole_ok):
        raise ValueError(f"fused_conv route unavailable: {report}")
    if route == "tiled" and not (can_fuse and tiling is not None):
        raise ValueError(f"tiled route unavailable: {report}")

    serve_tiled = can_fuse and want_fused and tiling is not None \
        and (route == "tiled" or not whole_ok)
    serve_whole = can_fuse and want_fused and whole_ok and route != "tiled"

    if serve_whole or serve_tiled:
        from repro.kernels.fused_lut_conv import ops as cops
        from repro.parallel import acu_shard
        partition = None
        if ctx is not None:
            partition = acu_shard.resolve_conv_partition(
                ctx, float_accum=acu.mode == AcuMode.LOWRANK)
        geom = dict(stride=spec.stride, dilation=spec.dilation)
        if serve_tiled:
            inner, bh, bn, _ = tiling
            kernel_fn = functools.partial(cops.fused_lut_conv_tiled,
                                          inner=inner, bh=bh, bn=bn)
        else:
            kernel_fn = cops.fused_lut_conv

        def fused_call(x, wq, xs, xz, ws, *, emit_acc=False, padding=None):
            # jnp.asarray stays inside: plans are cached across jit traces
            # and a device constant created during one trace must not leak
            # into another. ``padding`` override: the banded mesh wrap
            # pre-pads its halo'd row slabs and calls back with zero row
            # padding (acu_shard.wrap_fused_conv).
            return kernel_fn(
                x, wq, jnp.asarray(acu.lut), acu.offset, xs, xz, ws,
                bits=a_bits, interpret=acu.interpret, emit_acc=emit_acc,
                padding=spec.padding if padding is None else padding, **geom)

        fn = fused_call
        if partition is not None:
            fn = acu_shard.wrap_fused_conv(
                fused_call,
                lambda *args, **kw: fused_call(*args, emit_acc=True, **kw),
                ctx, partition, acu.m00(), kh * kw, spec=spec)

        # resolve where the approximate backward would run, under the same
        # budget: the banded weight-grad kernel when its band model fits,
        # the audited materialized-im2col fallback otherwise. Resolved for
        # every fused plan (it is pure geometry) — only approx_bwd
        # consumers act on it.
        from repro.kernels.fused_lut_conv.ops import pick_conv_bwd_tiling
        bwd_tiling = pick_conv_bwd_tiling(*_conv_geometry_args(spec),
                                          acu.multiplier.n_codes,
                                          budget=budget)
        if bwd_tiling is None:
            report.append("approx backward: even a one-row band exceeds the "
                          "VMEM budget; weight-grad falls back to "
                          "materialized im2col")
        return ConvPlan(mode=acu.mode, bits=acu.bits, use_pallas=True,
                        fused=True,
                        route="tiled" if serve_tiled else "fused_conv",
                        spec=spec, fn=fn, partition=partition,
                        report=tuple(report),
                        tiling=tiling if serve_tiled else None,
                        bwd_route="banded" if bwd_tiling is not None
                        else "im2col",
                        bwd_tiling=bwd_tiling)

    if spec.groups == 1:
        r = "im2col"
    elif spec.groups == cin and cin_g == 1:
        r = "im2col_depthwise"
    else:
        r = "im2col_grouped"
    partition = None
    if ctx is not None:
        from repro.parallel import acu_shard
        partition = acu_shard.resolve_partition(
            ctx, float_accum=acu.mode == AcuMode.LOWRANK)
    return ConvPlan(mode=acu.mode, bits=acu.bits, use_pallas=acu.use_pallas,
                    fused=fused, route=r, spec=spec, partition=partition,
                    report=tuple(report))


# ---------------------------------------------------------------------------
# attention planning layer: GQA geometry x (mode, bits, use_pallas) x mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static geometry of one attention site (hashable: plan cache key).

    ``hq``/``hkv``: query / KV head counts (``hq % hkv == 0``, GQA);
    ``causal``/``window``/``softcap``: the mask/logit statics;
    ``bq``/``bk``: kernel tile sizes (shrunk automatically for short
    sequences by the kernel wrapper). Sequence lengths are deliberately NOT
    part of the spec — the kernel geometry adapts per call, so one plan
    serves prefill and decode.

    ``kv_layout`` selects how the kernel reads KV:

    * ``"contiguous"`` — K/V arrive as per-row ``(B, Hkv, Sk, D)`` tensors.
    * ``"paged"`` — K/V live in a shared physical block pool
      ``(Hkv, P, bk, D)`` and each row reads through an int32 page table;
      ``bk`` is then also the paged block size (the pool's block extent
      must equal it). The serve engine's block allocator owns the pool.
    """

    hq: int
    hkv: int
    causal: bool = True
    window: Optional[int] = None
    softcap: Optional[float] = None
    bq: int = 128
    bk: int = 128
    kv_layout: str = "contiguous"


@dataclasses.dataclass(frozen=True)
class AttnPlan:
    """A resolved attention route for one ACU at one static geometry.

    ``route`` is one of

    * ``"fused_attn"`` — approximate flash attention
      (``kernels/flash_attention.approx``): per-tensor quantize of Q/K/V
      in-kernel, QK^T and PV as int32 LUT-gather GEMMs inside the streaming
      softmax, pad corrections in integer space, dequant folded into the
      running rescale. ``fn(q, k, v, q_scale, k_scale, v_scale, rowinfo)
      -> (B, Hq, Sq, D) f32`` with ``q`` (B, Hq, Sq, D) float, ``k``/``v``
      (B, Hkv, Sk, D), per-tensor scales computed by the caller on the FULL
      tensors (``inline_symmetric_scale`` — mesh shards must see identical
      scales), and ``rowinfo`` (B, 3) int32 ``[q_base, kv_start, kv_len]``
      rows (``None`` = the end-aligned full-sequence default). Mesh-wrapped
      when a partition is active — batch over ``acu_attn_rows``, KV heads
      over ``acu_attn_heads``, no collectives, bit-exact by construction.
    * ``"fused_attn_paged"`` — the same approximate flash attention reading
      KV through a per-row page table
      (``spec.kv_layout == "paged"``): ``fn(q, k_pool, v_pool, q_scale,
      k_scale, v_scale, rowinfo, page_table) -> (B, Hq, Sq, D) f32`` with
      ``k_pool``/``v_pool`` ``(Hkv, P, spec.bk, D)`` physical block pools
      shared by all rows, ``page_table`` ``(B, n_logical)`` int32 logical →
      physical block ids (repeated per query head internally), and
      ``rowinfo`` REQUIRED (there is no sensible full-pool default).
      Bitwise-identical to the contiguous route when the gathered blocks
      hold the same values. Mesh-wrapped like the contiguous route with the
      pool sharded over KV heads and the page table replicated per row
      shard.
    * ``"dense"`` — the audited fallback for non-LUT modes, non-Pallas ACUs
      and missing tables: ``fn`` is None and the caller keeps its exact
      float attention path (models/layers.py) — attention runs exact, only
      the projections/MLP run approximately, mirroring the conv plan's
      eager-im2col contract. Under ``kv_layout == "paged"`` the caller
      additionally gathers pool blocks back to a contiguous layout first
      (exact math is layout-independent, so the gather is just indexing).
    """

    mode: AcuMode
    bits: int
    use_pallas: bool
    route: str
    spec: AttnSpec
    fn: Optional[Callable[..., Array]] = None
    partition: Optional[object] = None
    report: tuple[str, ...] = ()

    def __call__(self, *args) -> Array:
        assert self.fn is not None, f"route {self.route} has no direct kernel"
        return self.fn(*args)

    def describe(self) -> dict:
        part = self.partition
        return {
            "route": self.route,
            "mode": self.mode.value,
            "heads": f"hq={self.spec.hq} hkv={self.spec.hkv} "
                     f"(rep={self.spec.hq // self.spec.hkv})",
            "kv_layout": self.spec.kv_layout
                + (f" (block={self.spec.bk})"
                   if self.spec.kv_layout == "paged" else ""),
            "mask": f"causal={self.spec.causal} window={self.spec.window} "
                    f"softcap={self.spec.softcap}",
            "partition": None if part is None else
                f"rows{part.rows}x heads{part.cols} "
                f"({part.n_rows}x{part.n_cols} way)",
            "report": list(self.report) + (list(part.report) if part else []),
        }


def attn_plan(acu: Acu, spec: AttnSpec, *, a_bits: Optional[int] = None,
              mesh=None, route: Optional[str] = None) -> AttnPlan:
    """Resolve one attention site: GQA geometry x (mode, bits, use_pallas) x
    mesh -> a concrete route. Mirrors :func:`conv_plan`'s silent-but-audited
    fallback contract: an ACU that cannot serve the fused approximate kernel
    (non-LUT mode, no Pallas routing, no table) resolves to ``"dense"`` —
    the caller keeps its exact float attention. ``route`` pins one
    explicitly (``"fused_attn"`` raises if unavailable; ``"dense"`` forces
    the exact path).

    There is no unfused approximate attention route on purpose: the unfused
    composition (``approx_attention_ref``) exists as the bit-exactness
    oracle, not a serving path.
    """
    a_bits = acu.bits if a_bits is None else a_bits
    ctx = _resolve_mesh(mesh)
    report: list[str] = []
    if spec.hq % spec.hkv != 0:
        raise ValueError(f"hq={spec.hq} not a multiple of hkv={spec.hkv}")
    if spec.kv_layout not in ("contiguous", "paged"):
        raise ValueError(f"unknown kv_layout {spec.kv_layout!r}")
    paged = spec.kv_layout == "paged"
    fused_route = "fused_attn_paged" if paged else "fused_attn"
    if route not in (None, "fused_attn", "fused_attn_paged", "dense"):
        raise ValueError(f"unknown attn route {route!r}")
    if route is not None and route.startswith("fused") and route != fused_route:
        raise ValueError(f"route pin {route!r} does not match "
                         f"kv_layout={spec.kv_layout!r} (fused route here "
                         f"is {fused_route!r})")

    can_fuse = acu.mode == AcuMode.LUT and acu.use_pallas \
        and acu.lut is not None
    if not can_fuse and route != "dense":
        report.append(f"fused attention needs LUT mode + use_pallas + a "
                      f"built table (have mode={acu.mode.value}, "
                      f"use_pallas={acu.use_pallas}); attention stays exact")
        if paged:
            report.append("paged KV on the dense route: caller gathers pool "
                          "blocks to a contiguous layout (exact math is "
                          "layout-independent)")
    if route == fused_route and not can_fuse:
        raise ValueError(f"{fused_route} route unavailable: {report}")
    if route == "dense" or not can_fuse:
        if route == "dense":
            report.append("route pinned to exact dense attention by caller")
        return AttnPlan(mode=acu.mode, bits=acu.bits,
                        use_pallas=acu.use_pallas, route="dense", spec=spec,
                        report=tuple(report))

    from repro.kernels.flash_attention.approx import (
        approx_flash_attention, approx_flash_attention_paged)

    rep = spec.hq // spec.hkv

    def attn_call(qf, kf, vf, qs, ks, vs, rowinfo):
        # folded (B*H, S, D) operands; jnp.asarray stays inside fn: plans
        # are cached across jit traces and a device constant created during
        # one trace must not leak into another
        return approx_flash_attention(
            qf, kf, vf, jnp.asarray(acu.lut), acu.offset, qs, ks, vs,
            bits=a_bits, causal=spec.causal, window=spec.window,
            softcap=spec.softcap, rowinfo=rowinfo, bq=spec.bq, bk=spec.bk,
            interpret=acu.interpret)

    def attn_call_paged(qf, k_pool, v_pool, qs, ks, vs, rowinfo, pt):
        return approx_flash_attention_paged(
            qf, k_pool, v_pool, jnp.asarray(acu.lut), acu.offset, qs, ks, vs,
            bits=a_bits, causal=spec.causal, window=spec.window,
            softcap=spec.softcap, rowinfo=rowinfo, page_table=pt, rep=rep,
            bq=spec.bq, interpret=acu.interpret)

    partition = None
    if ctx is not None:
        from repro.parallel import acu_shard
        partition = acu_shard.resolve_attn_partition(ctx, hq=spec.hq,
                                                     hkv=spec.hkv)

    def _default_rowinfo(q, k, rowinfo):
        if rowinfo is None:
            b, sq, sk = q.shape[0], q.shape[2], k.shape[2]
            rowinfo = jnp.broadcast_to(
                jnp.array([sk - sq, 0, sk], jnp.int32), (b, 3))
        return jnp.asarray(rowinfo, jnp.int32)

    if paged:
        if partition is not None:
            from repro.parallel import acu_shard
            sharded = acu_shard.wrap_attn_paged(
                attn_call_paged, ctx, partition, hq=spec.hq, hkv=spec.hkv)

            def fn(q, k_pool, v_pool, qs, ks, vs, rowinfo, page_table):
                return sharded(q, k_pool, v_pool, qs, ks, vs,
                               jnp.asarray(rowinfo, jnp.int32),
                               jnp.asarray(page_table, jnp.int32))
        else:
            def fn(q, k_pool, v_pool, qs, ks, vs, rowinfo, page_table):
                b, hq, sq, d = q.shape
                info = jnp.repeat(jnp.asarray(rowinfo, jnp.int32), hq,
                                  axis=0)
                pt = jnp.repeat(jnp.asarray(page_table, jnp.int32), hq,
                                axis=0)
                out = attn_call_paged(q.reshape(b * hq, sq, d), k_pool,
                                      v_pool, qs, ks, vs, info, pt)
                return out.reshape(b, hq, sq, d)
    elif partition is not None:
        from repro.parallel import acu_shard
        sharded = acu_shard.wrap_attn(attn_call, ctx, partition, hq=spec.hq,
                                      hkv=spec.hkv)

        def fn(q, k, v, qs, ks, vs, rowinfo=None):
            return sharded(q, k, v, qs, ks, vs,
                           _default_rowinfo(q, k, rowinfo))
    else:
        def fn(q, k, v, qs, ks, vs, rowinfo=None):
            b, hq, sq, d = q.shape
            hkv, sk = k.shape[1], k.shape[2]
            info = jnp.repeat(_default_rowinfo(q, k, rowinfo), hq, axis=0)
            out = attn_call(q.reshape(b * hq, sq, d),
                            k.reshape(b * hkv, sk, d),
                            v.reshape(b * hkv, sk, d), qs, ks, vs, info)
            return out.reshape(b, hq, sq, d)

    return AttnPlan(mode=acu.mode, bits=acu.bits, use_pallas=True,
                    route=fused_route, spec=spec, fn=fn,
                    partition=partition, report=tuple(report))


# ---------------------------------------------------------------------------
# grouped ragged GEMM plan (MoE expert dispatch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupedSpec:
    """Static geometry of one MoE grouped-GEMM site (hashable: plan cache
    key).

    ``n_experts``: expert count E; ``cap``: capacity rows per (dispatch
    block, expert) group; ``d_in``/``d_out``: the GEMM contraction / output
    widths; ``n_blocks``: the dispatch block count ``nb`` the MoE router
    resolved (``models/moe._dispatch_blocks`` — its silent power-of-2
    fallback is surfaced here so ``describe()`` reports the block layout the
    kernel actually runs). The grouped operand has ``G = n_blocks *
    n_experts`` groups; group ``g`` multiplies expert ``g % n_experts``.
    """

    n_experts: int
    cap: int
    d_in: int
    d_out: int
    n_blocks: int = 1


@dataclasses.dataclass(frozen=True)
class GroupedPlan:
    """A resolved grouped ragged GEMM route for one ACU at one MoE geometry.

    ``route`` is one of

    * ``"fused_grouped"`` — ONE ``pallas_call`` for all E expert GEMMs
      (``kernels/fused_lut_grouped``): the grid walks groups x row-blocks
      and a per-group ``groupinfo = [row_base, row_count]`` operand skips
      row-blocks past each group's live token count; in-kernel per-tensor
      activation quantize, shifted-code LUT gathers, int32 accumulate with
      integer-space K-pad correction, ONE combined-scale dequant.
      ``fn(xe, wq, xs, xz, ws, counts) -> (G, cap, d_out) f32`` with ``xe``
      (G, cap, d_in) float dispatched activations, ``wq`` (E, d_in, d_out)
      shifted int weight codes, ``xs``/``xz`` per-tensor activation qparams
      SHARED across groups (the caller pins ONE scale over the whole
      dispatched tensor so grouped == per-expert-vmap bitwise), ``ws``
      (E, d_out) per-expert weight scales, ``counts`` (G,) int32 live rows.
      Rows ``>= counts[g]`` are exactly 0.0 — dead capacity slots contribute
      nothing even under biased-M00 multipliers (masking, not slicing).
      Mesh-wrapped when a partition is active: experts over the
      ``acu_grouped_experts`` axes (expert parallelism), dispatch blocks
      over ``acu_grouped_rows``, opt-in ``acu_grouped_k`` contraction
      sharding with an int32 psum before the dequant.
    * ``"vmap"`` — the audited fallback (non-LUT mode, no Pallas routing, no
      table): ``fn`` is None and the caller keeps the per-expert vmapped
      ``approx_dense`` composition — which doubles as the bit-exactness
      oracle for the fused route when driven with the same pinned shared
      activation scale and live-row mask.
    """

    mode: AcuMode
    bits: int
    use_pallas: bool
    route: str
    spec: GroupedSpec
    fn: Optional[Callable[..., Array]] = None
    partition: Optional[object] = None
    report: tuple[str, ...] = ()

    def __call__(self, *args) -> Array:
        assert self.fn is not None, f"route {self.route} has no direct kernel"
        return self.fn(*args)

    def describe(self) -> dict:
        part = self.partition
        return {
            "route": self.route,
            "mode": self.mode.value,
            "experts": self.spec.n_experts,
            "cap": self.spec.cap,
            "n_blocks": self.spec.n_blocks,
            "gemm": f"({self.spec.n_blocks}x{self.spec.n_experts}, "
                    f"{self.spec.cap}, {self.spec.d_in}) x "
                    f"({self.spec.n_experts}, {self.spec.d_in}, "
                    f"{self.spec.d_out})",
            "partition": None if part is None else
                f"blocks{part.rows}x experts{part.cols}x k{part.k} "
                f"({part.n_rows}x{part.n_cols}x{part.n_k} way)",
            "report": list(self.report) + (list(part.report) if part else []),
        }


def grouped_plan(acu: Acu, spec: GroupedSpec, *, a_bits: Optional[int] = None,
                 mesh=None, route: Optional[str] = None) -> GroupedPlan:
    """Resolve one MoE grouped-GEMM site: expert geometry x (mode, bits,
    use_pallas) x mesh -> a concrete route. Mirrors :func:`attn_plan`'s
    silent-but-audited fallback contract: an ACU that cannot serve the fused
    grouped kernel resolves to ``"vmap"`` (the caller keeps its per-expert
    vmapped composition). ``route`` pins one explicitly (``"fused_grouped"``
    raises if unavailable; ``"vmap"`` forces the per-expert path — that is
    how the bit-exactness oracle and the bench baseline are driven).
    """
    a_bits = acu.bits if a_bits is None else a_bits
    ctx = _resolve_mesh(mesh)
    report: list[str] = []
    if route not in (None, "fused_grouped", "vmap"):
        raise ValueError(f"unknown grouped route {route!r}")

    can_fuse = acu.mode == AcuMode.LUT and acu.use_pallas \
        and acu.lut is not None
    if not can_fuse and route != "vmap":
        report.append(f"fused grouped GEMM needs LUT mode + use_pallas + a "
                      f"built table (have mode={acu.mode.value}, "
                      f"use_pallas={acu.use_pallas}); expert GEMMs stay on "
                      f"the per-expert vmapped route")
    if route == "fused_grouped" and not can_fuse:
        raise ValueError(f"fused_grouped route unavailable: {report}")
    if route == "vmap" or not can_fuse:
        if route == "vmap":
            report.append("route pinned to per-expert vmap by caller")
        return GroupedPlan(mode=acu.mode, bits=acu.bits,
                           use_pallas=acu.use_pallas, route="vmap", spec=spec,
                           report=tuple(report))

    from repro.kernels.fused_lut_grouped import ops as gops

    def grouped_call(xe, wq, xs, xz, ws, counts, *, emit_acc=False):
        # jnp.asarray stays inside fn: plans are cached across jit traces
        # and a device constant created during one trace must not leak
        # into another
        return gops.fused_lut_grouped(xe, wq, jnp.asarray(acu.lut),
                                      acu.offset, xs, xz, ws, counts,
                                      bits=a_bits, interpret=acu.interpret,
                                      emit_acc=emit_acc)

    partition = None
    fn = grouped_call
    if ctx is not None:
        from repro.parallel import acu_shard
        partition = acu_shard.resolve_grouped_partition(
            ctx, n_experts=spec.n_experts, n_blocks=spec.n_blocks)
        if partition is not None:
            fn = acu_shard.wrap_fused_grouped(
                grouped_call,
                lambda *args: grouped_call(*args, emit_acc=True),
                ctx, partition, acu.m00(), n_experts=spec.n_experts)

    return GroupedPlan(mode=acu.mode, bits=acu.bits, use_pallas=True,
                       route="fused_grouped", spec=spec, fn=fn,
                       partition=partition, report=tuple(report))


def make_acu(name: str, mode: AcuMode | str = AcuMode.LUT, rank: int = 8,
             use_pallas: bool = False, interpret: bool | None = None,
             fused: bool = False) -> Acu:
    """Build an ACU from a registered multiplier name.

    Large-bitwidth LUT requests fall back to FUNCTIONAL per the paper §3.4
    ("In case of large bitwidth ... substitute the LUT-based multiplication
    with functional-based multiplication").
    """
    mult = get_multiplier(name)
    mode = AcuMode(mode) if isinstance(mode, str) else mode
    lut = lowrank = None
    mask = None
    if mode == AcuMode.LUT:
        if mult.bits > 10:
            mode = AcuMode.FUNCTIONAL  # LUT would exceed VMEM; paper's fallback
        else:
            lut = build_lut(mult)
    if mode == AcuMode.LOWRANK:
        lowrank = factorize_error(mult, rank)
    if mode == AcuMode.FACTORED:
        mask = trunc_masks(mult)
        if mask is None:
            raise ValueError(f"{name} has no algebraic factorization; "
                             f"use LUT or LOWRANK")
    return Acu(multiplier=mult, mode=mode, lut=lut, lowrank=lowrank,
               mask=mask, use_pallas=use_pallas, interpret=interpret,
               fused=fused)
