"""Calibration (paper §3.2.1).

Observers collect statistics over a representative subset of the data
(the paper uses ~two batches); calibrators turn the statistics into a
``calib_max`` / (min, max). The paper's default is the 99.9-percentile
histogram calibrator; MSE and entropy (KL) calibrators are provided as the
"transparently usable" alternatives it mentions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .quantization import QParams, affine_qparams, symmetric_qparams

Array = jnp.ndarray


@dataclasses.dataclass
class HistogramObserver:
    """Single-pass |x| histogram with geometric range expansion.

    Bins cover [0, range]; when a batch exceeds the range, existing counts are
    re-binned into the doubled range (counts merge pairwise), so percentile
    queries stay consistent without a second pass over the data.
    """

    n_bins: int = 2048
    range: float = 0.0
    counts: Optional[np.ndarray] = None
    xmin: float = 0.0
    xmax: float = 0.0

    def update(self, x) -> None:
        x = np.asarray(x, dtype=np.float32).ravel()
        if x.size == 0:
            return
        self.xmin = min(self.xmin, float(x.min()))
        self.xmax = max(self.xmax, float(x.max()))
        amax = float(np.abs(x).max())
        if self.counts is None:
            self.counts = np.zeros(self.n_bins, dtype=np.int64)
            self.range = max(amax, 1e-12)
        while amax > self.range:
            # double the range; merge counts pairwise into the lower half
            c = self.counts
            merged = c.reshape(-1, 2).sum(axis=1)
            nc = np.zeros_like(c)
            nc[: self.n_bins // 2] = merged
            self.counts = nc
            self.range *= 2.0
        idx = np.minimum(
            (np.abs(x) / self.range * self.n_bins).astype(np.int64), self.n_bins - 1
        )
        np.add.at(self.counts, idx, 1)

    # -- calibrators ------------------------------------------------------

    def percentile_max(self, pct: float = 99.9) -> float:
        """calib_max = smallest |x| bound covering ``pct``% of observed values."""
        assert self.counts is not None, "observer saw no data"
        cdf = np.cumsum(self.counts)
        total = cdf[-1]
        k = int(np.searchsorted(cdf, pct / 100.0 * total))
        k = min(k, self.n_bins - 1)
        return float((k + 1) / self.n_bins * self.range)

    def mse_max(self, bits: int, n_grid: int = 64) -> float:
        """calib_max minimizing expected squared quantization error under the
        observed |x| histogram (grid search over candidate clip points)."""
        assert self.counts is not None
        centers = (np.arange(self.n_bins) + 0.5) / self.n_bins * self.range
        probs = self.counts / max(self.counts.sum(), 1)
        hi = (1 << (bits - 1)) - 1
        best, best_err = self.range, np.inf
        for frac in np.linspace(0.2, 1.0, n_grid):
            cmax = frac * self.range
            scale = cmax / hi
            q = np.clip(np.round(centers / scale), 0, hi) * scale
            err = float((probs * (centers - q) ** 2).sum())
            if err < best_err:
                best, best_err = cmax, err
        return best

    def entropy_max(self, bits: int, n_grid: int = 48) -> float:
        """TensorRT-style KL calibrator: pick the clip bound whose quantized
        distribution minimizes KL(P || Q) against the observed histogram."""
        assert self.counts is not None
        n_levels = 1 << (bits - 1)
        counts = self.counts.astype(np.float64)
        best, best_kl = self.range, np.inf
        start = max(n_levels, self.n_bins // 8)
        for stop in np.linspace(start, self.n_bins, n_grid).astype(int):
            p = counts[:stop].copy()
            p[-1] += counts[stop:].sum()  # clipped mass
            if p.sum() == 0:
                continue
            # quantize the first `stop` bins into n_levels buckets
            edges = np.linspace(0, stop, n_levels + 1).astype(int)
            q = np.zeros(stop)
            for i in range(n_levels):
                lo, hi_ = edges[i], max(edges[i + 1], edges[i] + 1)
                seg = p[lo:hi_]
                nz = (seg > 0).sum()
                if nz:
                    q[lo:hi_] = np.where(seg > 0, seg.sum() / nz, 0)
            mask = p > 0
            qq = np.where(q > 0, q, 1e-12)
            kl = float((p[mask] * np.log(p[mask] / qq[mask])).sum() / p.sum())
            if kl < best_kl:
                best_kl, best = kl, stop / self.n_bins * self.range
        return best


@dataclasses.dataclass
class PerChannelObserver:
    """Per-channel absolute-max observer (weights)."""

    axis: int = 0
    amax: Optional[np.ndarray] = None

    def update(self, w) -> None:
        w = np.asarray(w, dtype=np.float32)
        red = tuple(i for i in range(w.ndim) if i != self.axis)
        cur = np.abs(w).max(axis=red) if red else np.abs(w)
        self.amax = cur if self.amax is None else np.maximum(self.amax, cur)


def calibrate_activation(obs: HistogramObserver, bits: int,
                         method: str = "percentile", affine: bool = True,
                         pct: float = 99.9) -> QParams:
    if method == "percentile":
        cmax = obs.percentile_max(pct)
    elif method == "mse":
        cmax = obs.mse_max(bits)
    elif method == "entropy":
        cmax = obs.entropy_max(bits)
    elif method == "max":
        cmax = obs.range if obs.counts is not None else 1.0
    else:
        raise ValueError(f"unknown calibration method {method!r}")
    if affine and obs.xmin < 0 < obs.xmax:
        lo = max(obs.xmin, -cmax)
        hi = min(obs.xmax, cmax)
        return affine_qparams(jnp.float32(lo), jnp.float32(hi), bits)
    return symmetric_qparams(jnp.float32(cmax), bits)


def calibrate_weight(w, bits: int, axis: int = 0) -> QParams:
    obs = PerChannelObserver(axis=axis)
    obs.update(w)
    return symmetric_qparams(jnp.asarray(obs.amax, jnp.float32), bits, axis=axis)
