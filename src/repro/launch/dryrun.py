import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract roofline terms (DESIGN.md §7).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results.json
  ... --variant causal_blocking       (hillclimb variants, see VARIANTS)

The XLA flag above must precede every other import (jax locks the device
count at first init) — this module is the ONLY place it is set.
"""
import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, eligible, get_config  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_step, make_acfg  # noqa: E402


def _padded_heads(cfg):
    """Pad q heads to a multiple of 16 and kv heads to a divisor of that."""
    h = cfg.n_heads + (-cfg.n_heads) % 16
    kv = cfg.n_kv_heads
    while h % kv != 0:
        kv += 1
    return {"n_heads": h, "n_kv_heads": kv}


# §Perf hillclimb variants: named config transformations
VARIANTS = {
    "baseline": lambda cfg: cfg,
    # skip fully-masked KV blocks in causal chunked attention (~2x attn FLOPs)
    "causal_blocking": lambda cfg: dataclasses.replace(
        cfg, attn_causal_blocking=True),
    # save matmul outputs instead of recomputing everything (memory<->compute)
    "remat_dots": lambda cfg: dataclasses.replace(cfg, remat_policy="dots"),
    "no_remat": lambda cfg: dataclasses.replace(cfg, remat=False),
    "remat_dots_causal": lambda cfg: dataclasses.replace(
        cfg, remat_policy="dots", attn_causal_blocking=True),
    # larger attention chunk: fewer, bigger GEMMs
    "chunk2k": lambda cfg: dataclasses.replace(cfg, attn_chunk=2048),
    "chunk1k": lambda cfg: dataclasses.replace(cfg, attn_chunk=1024),
    # fp32->bf16 scores already; widen rwkv chunk (fewer boundary saves)
    "rwkv_chunk1k": lambda cfg: dataclasses.replace(cfg, rwkv_chunk=1024),
    # hillclimb #1 baseline reproduction: replicated MoE dispatch buffer
    "moe_replicated_dispatch": lambda cfg: dataclasses.replace(
        cfg, moe_shard_dispatch=False),
    # pad attention heads to the next multiple of the model axis so they
    # shard (zero-weight heads are exact); production would zero-pad weights
    "pad_heads": lambda cfg: dataclasses.replace(
        cfg, **_padded_heads(cfg)),
    "pad_heads_causal": lambda cfg: dataclasses.replace(
        cfg, attn_causal_blocking=True, **_padded_heads(cfg)),
}


def compile_cell(arch: str, shape_name: str, *, multi_pod: bool,
                 variant: str = "baseline", probe_unroll: bool = True,
                 verbose: bool = True, acu: str | None = None) -> dict:
    """Lower + compile one cell; returns the roofline record."""
    cfg = VARIANTS[variant](get_config(arch))
    shape = SHAPES[shape_name]
    ok, why = eligible(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.monotonic()
    acfg = make_acfg(acu)

    def lower_compile(c):
        bundle = build_step(c, shape, mesh, acfg=acfg)
        lowered = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        ).lower(*bundle.args)
        compiled = lowered.compile()
        return bundle, compiled

    bundle, compiled = lower_compile(cfg)
    cost_u1 = roofline.extract(compiled)
    mem = compiled.memory_analysis()

    groups = cfg.n_groups
    if probe_unroll and groups > 1:
        # two-point unroll probe (even group count required; shrink if odd)
        pg = groups if groups % 2 == 0 else groups - 1
        probe_cfg = dataclasses.replace(cfg, n_layers=pg * len(cfg.pattern))
        if pg != groups:
            _, c_p1 = lower_compile(probe_cfg)
            cost_p1 = roofline.extract(c_p1)
        else:
            cost_p1 = cost_u1
        _, c_p2 = lower_compile(dataclasses.replace(probe_cfg, scan_unroll=2))
        cost_p2 = roofline.extract(c_p2)
        delta = roofline.CellCost(
            flops=max(cost_p2.flops - cost_p1.flops, 0.0),
            bytes_accessed=max(cost_p2.bytes_accessed - cost_p1.bytes_accessed, 0.0),
            coll_bytes=0.0,
            coll_breakdown={k: max(cost_p2.coll_breakdown.get(k, 0) -
                                   cost_p1.coll_breakdown.get(k, 0), 0)
                            for k in set(cost_p1.coll_breakdown) |
                            set(cost_p2.coll_breakdown)},
            peak_memory=0.0, arg_bytes=0.0)
        total = roofline.CellCost(
            flops=cost_u1.flops + (groups - 1) * delta.flops,
            bytes_accessed=cost_u1.bytes_accessed + (groups - 1) * delta.bytes_accessed,
            coll_bytes=0.0,
            coll_breakdown={k: cost_u1.coll_breakdown.get(k, 0) +
                            (groups - 1) * delta.coll_breakdown.get(k, 0)
                            for k in set(cost_u1.coll_breakdown) |
                            set(delta.coll_breakdown)},
            peak_memory=cost_u1.peak_memory, arg_bytes=cost_u1.arg_bytes)
        total = dataclasses.replace(
            total, coll_bytes=float(sum(total.coll_breakdown.values())))
    else:
        total = cost_u1

    # analytic nested-recurrence correction (rwkv)
    dfl, dby = roofline.recurrence_correction(cfg, shape, n_dev)
    total = dataclasses.replace(total, flops=total.flops + dfl,
                                bytes_accessed=total.bytes_accessed + dby)

    mf = roofline.model_flops(cfg, shape, n_dev)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant, "acu": acu,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "kind": shape.kind, "n_groups": groups,
        **total.as_dict(),
        "model_flops": mf,
        "useful_ratio": mf / total.flops if total.flops else 0.0,
        "roofline_frac": (mf / roofline.PEAK_BF16) / total.step_time
        if total.step_time else 0.0,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "plan_report": bundle.meta.get("plan_report", []) +
        bundle.meta.get("cache_report", []),
        "compile_s": round(time.monotonic() - t0, 1),
    }
    if "moe_dispatch" in bundle.meta:   # resolved MoE dispatch geometry
        rec["moe_dispatch"] = bundle.meta["moe_dispatch"]
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}, {variant}): "
              f"T_comp={total.t_compute*1e3:.2f}ms T_mem={total.t_memory*1e3:.2f}ms "
              f"T_coll={total.t_collective*1e3:.2f}ms -> {total.bottleneck}; "
              f"useful={rec['useful_ratio']:.2f} roofline={rec['roofline_frac']:.2%} "
              f"args/dev={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"({rec['compile_s']}s)", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--acu", default=None,
                    help="emulate an ACU on every GEMM: 'mult:mode[:rank]'")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the two-point unroll probe (faster)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    records = []
    for a, s in cells:
        for mp in meshes:
            try:
                records.append(compile_cell(a, s, multi_pod=mp,
                                            variant=args.variant,
                                            probe_unroll=not args.no_probe,
                                            acu=args.acu))
            except Exception as e:  # noqa: BLE001 — report, don't abort the sweep
                print(f"[dryrun] FAILED {a} x {s} multipod={mp}: "
                      f"{type(e).__name__}: {e}", flush=True)
                records.append({"arch": a, "shape": s,
                                "mesh": "2x16x16" if mp else "16x16",
                                "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"[dryrun] wrote {len(records)} records to {args.out}")
    failed = [r for r in records if "error" in r]
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
