"""Serving launcher: batched greedy decoding through the serving engines.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        [--approx mul8s_1L2H:lut] [--requests 8] [--new-tokens 16] \
        [--continuous | --paged] [--arrival-rate 0.5] \
        [--block-size 16] [--hbm-budget BYTES]

``--continuous`` swaps the wave engine for slot-level continuous batching;
``--paged`` selects the paged-KV continuous engine (block pool + prefix
reuse, docs/serving.md "Paged KV") and prints the resolved attention plan
report plus the pool geometry. ``--block-size`` and ``--hbm-budget``
(bytes; default = the contiguous engine's footprint for the same slots)
shape the pool. ``--arrival-rate`` (arrivals per decode step,
continuous/paged only) replays a Poisson trace instead of firing every
request at t=0.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--approx", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--continuous", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="paged-KV continuous engine (implies slot-level "
                         "scheduling; see docs/serving.md)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size in tokens (paged only; pow2 >= 8)")
    ap.add_argument("--hbm-budget", type=int, default=None,
                    help="KV pool budget in bytes (paged only; default = "
                         "slots * max_seq contiguous footprint)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals per decode step "
                         "(continuous/paged only)")
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.launch.specs import make_acfg
    from repro.models.transformer import init_params
    from repro.serve.engine import (ContinuousServeEngine,
                                    PagedContinuousServeEngine, Request,
                                    ServeEngine, kv_block_bytes,
                                    poisson_arrivals)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    acfg = make_acfg(args.approx)
    max_seq = 256
    if args.paged:
        eng = PagedContinuousServeEngine(
            params, cfg, slots=args.slots, max_seq=max_seq,
            block_size=args.block_size, acfg=acfg,
            hbm_budget=args.hbm_budget)
        bbytes = kv_block_bytes(cfg, args.block_size)
        print(f"paged pool: {eng.n_blocks} blocks x {args.block_size} tok "
              f"({bbytes} B/block, budget {eng.hbm_budget} B, "
              f"{eng.n_logical} logical blocks/slot)")
        if acfg is not None and acfg.acu is not None:
            from repro.core.acu import AttnSpec, attn_plan
            spec = AttnSpec(hq=cfg.n_heads, hkv=cfg.n_kv_heads,
                            bk=args.block_size, kv_layout="paged")
            plan = attn_plan(acfg.acu, spec, a_bits=acfg.a_bits, mesh=False)
            for k, v in plan.describe().items():
                print(f"attn_plan.{k}: {v}")
    else:
        cls = ContinuousServeEngine if args.continuous else ServeEngine
        eng = cls(params, cfg, slots=args.slots, max_seq=max_seq, acfg=acfg)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(4, 12)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    slotted = args.continuous or args.paged
    arrivals = None
    if args.arrival_rate is not None:
        if not slotted:
            ap.error("--arrival-rate needs --continuous or --paged")
        arrivals = poisson_arrivals(len(reqs), args.arrival_rate, seed=0)
    import time
    t0 = time.monotonic()
    done = eng.run(reqs, arrivals) if slotted else eng.run(reqs)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    if slotted:
        print(f"stats: {eng.stats}")
    for i, r in enumerate(done[:4]):
        print(f"req{i}: {list(r.prompt)[:6]}... -> {list(r.out)[:8]}...")


if __name__ == "__main__":
    main()
