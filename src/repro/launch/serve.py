"""Serving launcher: batched greedy decoding through the serving engines.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        [--approx mul8s_1L2H:lut] [--requests 8] [--new-tokens 16] \
        [--continuous] [--arrival-rate 0.5]

``--continuous`` swaps the wave engine for slot-level continuous batching;
``--arrival-rate`` (arrivals per decode step) replays a Poisson trace
through it instead of firing every request at t=0.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--approx", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--continuous", action="store_true")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals per decode step (continuous only)")
    args = ap.parse_args()

    from repro.configs import get_config, reduced_config
    from repro.launch.specs import make_acfg
    from repro.models.transformer import init_params
    from repro.serve.engine import (ContinuousServeEngine, Request,
                                    ServeEngine, poisson_arrivals)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    cls = ContinuousServeEngine if args.continuous else ServeEngine
    eng = cls(params, cfg, slots=args.slots, max_seq=256,
              acfg=make_acfg(args.approx))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(4, 12)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    arrivals = None
    if args.arrival_rate is not None:
        if not args.continuous:
            ap.error("--arrival-rate needs --continuous")
        arrivals = poisson_arrivals(len(reqs), args.arrival_rate, seed=0)
    import time
    t0 = time.monotonic()
    done = eng.run(reqs, arrivals) if args.continuous else eng.run(reqs)
    dt = time.monotonic() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    if args.continuous:
        print(f"stats: {eng.stats}")
    for i, r in enumerate(done[:4]):
        print(f"req{i}: {list(r.prompt)[:6]}... -> {list(r.out)[:8]}...")


if __name__ == "__main__":
    main()
