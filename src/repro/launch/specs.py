"""Abstract input specs + jit-able step functions for every (arch x shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation); ``build_step`` returns the function to lower
plus matching in_shardings — the dry-run and the roofline extractor both
consume these.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.models import whisper as W
from repro.optim.adamw import AdamW, cosine_schedule
from repro.parallel import planner
from repro.parallel.sharding import use_mesh


def make_acfg(acu_spec):
    """'mult:mode[:rank]' -> ApproxConfig (e.g. mul8s_1L2H:lut,
    mul8s_trunc2:factored, mul8s_1L2H:lowrank:8)."""
    if not acu_spec:
        return None
    from repro.core.acu import AcuMode, make_acu
    from repro.core.approx_ops import ApproxConfig
    parts = acu_spec.split(":")
    name, mode = parts[0], parts[1] if len(parts) > 1 else "lut"
    rank = int(parts[2]) if len(parts) > 2 else 8
    return ApproxConfig(acu=make_acu(name, AcuMode(mode), rank=rank))


@dataclasses.dataclass
class StepBundle:
    fn: Callable                 # jit-able step
    args: tuple                  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_params(cfg: ModelConfig):
    init = W.init_params if cfg.enc_dec else T.init_params
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0))


def pick_microbatches(cfg: ModelConfig, global_batch: int, seq: int,
                      mesh) -> int:
    """Gradient-accumulation factor: keep per-microbatch saved activations
    (scan carries + attention temps) within ~4 GiB/device. Statically
    unrolled (Python loop), so cost_analysis sees every microbatch."""
    shards = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and global_batch % (shards * mesh.shape[a]) == 0:
            shards *= mesh.shape[a]
    b_local = max(global_batch // shards, 1)
    # saved carry per group per microbatch-row: S x d x 2 bytes
    bytes_full = b_local * seq * cfg.d_model * 2 * max(cfg.n_groups, 1)
    n_micro = 1
    while n_micro < b_local and bytes_full / n_micro > 4e9:
        n_micro *= 2
    while b_local % n_micro != 0:
        n_micro //= 2
    return max(n_micro, 1)


def make_optimizer(cfg: ModelConfig) -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, 200, 10000), weight_decay=0.01,
                 clip_norm=1.0)


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               acfg=None) -> StepBundle:
    """Construct (fn, abstract args, shardings) for one cell."""
    b, s = shape.global_batch, shape.seq_len
    params = abstract_params(cfg)
    pplan = planner.param_specs(cfg, params, mesh,
                                mode="train" if shape.kind == "train" else "serve")
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pplan.specs,
                          is_leaf=lambda x: isinstance(x, P))
    tok_spec = planner.batch_spec(mesh, b, extra_dims=1)
    tok_shard = NamedSharding(mesh, tok_spec)
    meta = {"plan_report": pplan.report, "kind": shape.kind}
    if cfg.n_experts:
        # static MoE dispatch geometry under this mesh (resolved block
        # count, per-block capacity) — the dry-run surfaces it per cell
        from repro.models.moe import dispatch_geometry
        with use_mesh(mesh):
            meta["moe_dispatch"] = dispatch_geometry(
                cfg, b * (1 if shape.kind == "decode" else s))

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        opt_state = jax.eval_shape(opt.init, params)
        ospecs = planner.opt_state_specs(pplan, opt_state)
        oshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), ospecs,
                              is_leaf=lambda x: isinstance(x, P))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)

        if cfg.enc_dec:
            frames = jax.ShapeDtypeStruct((b, cfg.enc_ctx, cfg.d_model),
                                          cfg.param_dtype)
            fr_shard = NamedSharding(mesh, planner.batch_spec(mesh, b, extra_dims=2))

            def train_step(params, opt_state, frames, tokens, labels):
                with use_mesh(mesh):
                    loss, grads = jax.value_and_grad(W.loss_fn)(
                        params, frames, tokens, labels, cfg, acfg)
                    new_params, new_state = opt.update(grads, opt_state, params)
                return new_params, new_state, loss

            return StepBundle(
                fn=train_step, args=(params, opt_state, frames, toks, toks),
                in_shardings=(pshard, oshard, fr_shard, tok_shard, tok_shard),
                out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                donate_argnums=(0, 1), meta=meta)

        n_micro = pick_microbatches(cfg, b, s, mesh)
        meta["n_microbatches"] = n_micro

        def train_step(params, opt_state, tokens, labels):
            with use_mesh(mesh):
                if n_micro == 1:
                    loss, grads = jax.value_and_grad(T.loss_fn)(
                        params, tokens, labels, cfg, acfg)
                else:
                    # statically-unrolled gradient accumulation: every
                    # microbatch appears in the HLO (roofline-correct) and
                    # the backward working set shrinks by n_micro
                    mb = b // n_micro
                    loss = 0.0
                    grads = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    for i in range(n_micro):
                        tk = jax.lax.dynamic_slice_in_dim(tokens, i * mb, mb)
                        lb = jax.lax.dynamic_slice_in_dim(labels, i * mb, mb)
                        li, gi = jax.value_and_grad(T.loss_fn)(
                            params, tk, lb, cfg, acfg)
                        loss = loss + li / n_micro
                        grads = jax.tree.map(
                            lambda a, g: a + g.astype(jnp.float32) / n_micro,
                            grads, gi)
                new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        return StepBundle(
            fn=train_step, args=(params, opt_state, toks, toks),
            in_shardings=(pshard, oshard, tok_shard, tok_shard),
            out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1), meta=meta)

    # ---- serving shapes ---------------------------------------------------
    long_ctx = shape.name.startswith("long")
    if shape.kind == "prefill":
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        cache = jax.eval_shape(
            lambda: (W.init_cache if cfg.enc_dec else T.init_cache)(cfg, b, s))
        cplan = planner.cache_specs(cfg, cache, mesh, global_batch=b,
                                    long_context=long_ctx)
        cshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cplan.specs,
                              is_leaf=lambda x: isinstance(x, P))
        meta["cache_report"] = cplan.report

        if cfg.enc_dec:
            frames = jax.ShapeDtypeStruct((b, cfg.enc_ctx, cfg.d_model),
                                          cfg.param_dtype)
            fr_shard = NamedSharding(mesh, planner.batch_spec(mesh, b, extra_dims=2))

            def prefill_step(params, cache, frames, tokens):
                with use_mesh(mesh):
                    enc = W.encode(params, frames, cfg, acfg)
                    logits, cache = W.decode(params, tokens, enc, cfg,
                                             acfg=acfg, cache=cache,
                                             cache_pos=0, last_only=True)
                return logits[:, -1], cache

            return StepBundle(
                fn=prefill_step, args=(params, cache, frames, toks),
                in_shardings=(pshard, cshard, fr_shard, tok_shard),
                out_shardings=(NamedSharding(mesh, planner.batch_spec(mesh, b)),
                               cshard),
                donate_argnums=(1,), meta=meta)

        def prefill_step(params, cache, tokens):
            with use_mesh(mesh):
                logits, cache = T.apply_model(params, tokens, cfg, acfg=acfg,
                                              cache=cache, cache_pos=0,
                                              last_only=True)
            return logits[:, -1], cache

        return StepBundle(
            fn=prefill_step, args=(params, cache, toks),
            in_shardings=(pshard, cshard, tok_shard),
            out_shardings=(NamedSharding(mesh, planner.batch_spec(mesh, b)), cshard),
            donate_argnums=(1,), meta=meta)

    # decode: one new token against a seq_len-deep cache
    toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.eval_shape(
        lambda: (W.init_cache if cfg.enc_dec else T.init_cache)(cfg, b, s))
    cplan = planner.cache_specs(cfg, cache, mesh, global_batch=b,
                                long_context=long_ctx)
    cshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cplan.specs,
                          is_leaf=lambda x: isinstance(x, P))
    meta["cache_report"] = cplan.report
    rep = NamedSharding(mesh, P())

    if cfg.enc_dec:
        enc_out = jax.ShapeDtypeStruct((b, cfg.enc_ctx, cfg.d_model),
                                       cfg.param_dtype)
        enc_shard = NamedSharding(mesh, planner.batch_spec(mesh, b, extra_dims=2))

        def decode_step(params, cache, enc_out, tokens, pos):
            with use_mesh(mesh):
                logits, cache = W.decode(params, tokens, enc_out, cfg,
                                         acfg=acfg, cache=cache, cache_pos=pos)
            return logits[:, -1], cache

        return StepBundle(
            fn=decode_step, args=(params, cache, enc_out, toks, pos),
            in_shardings=(pshard, cshard, enc_shard, tok_shard, rep),
            out_shardings=(NamedSharding(mesh, planner.batch_spec(mesh, b)), cshard),
            donate_argnums=(1,), meta=meta)

    def decode_step(params, cache, tokens, pos):
        with use_mesh(mesh):
            logits, cache = T.apply_model(params, tokens, cfg, acfg=acfg,
                                          cache=cache, cache_pos=pos, decode=True)
        return logits[:, -1], cache

    return StepBundle(
        fn=decode_step, args=(params, cache, toks, pos),
        in_shardings=(pshard, cshard, tok_shard, rep),
        out_shardings=(NamedSharding(mesh, planner.batch_spec(mesh, b)), cshard),
        donate_argnums=(1,), meta=meta)
