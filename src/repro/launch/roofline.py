"""Roofline extraction from compiled SPMD artifacts (DESIGN.md §7).

Terms per (arch x shape x mesh), all **per device**:
  T_compute    = HLO_FLOPs / peak_FLOP/s
  T_memory     = HLO_bytes / HBM_bw
  T_collective = collective_bytes / ICI_link_bw

`cost_analysis()` counts `lax.scan` bodies ONCE (measured), so each model is
compiled twice — scan_unroll=1 and =2 — and the per-layer-group delta is
scaled by the group count (`two_point`). Collective bytes are absent from
cost_analysis and are parsed from the compiled HLO text instead.

Analytic correction: time-recurrences that live inside nested scans (the
RWKV WKV loop) are under-counted even by the two-point method; their FLOPs
are added analytically (`recurrence_correction`) — they are <2% of any cell.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e (assignment constants)
PEAK_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?\S+\s*=\s*)?\(?([a-z0-9\[\],\s{}()]*?)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes of every collective op (per-device traffic
    proxy: ring all-reduce moves ~2x, all-gather ~(n-1)/n x result bytes —
    within 2x of the true per-link bytes; we report result bytes and note
    the convention)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_part, kind = m.group(1), m.group(2).lower()
        b = _shape_bytes(shape_part)
        if b:
            out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class CellCost:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: float            # per device (result-bytes convention)
    coll_breakdown: dict
    peak_memory: float           # per device bytes (args + temps)
    arg_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes, "coll_breakdown": self.coll_breakdown,
            "peak_memory": self.peak_memory, "arg_bytes": self.arg_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "step_time_lb": self.step_time,
        }


def extract(compiled, hlo_text: Optional[str] = None) -> CellCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        peak_memory=float(ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                          ma.output_size_in_bytes - ma.alias_size_in_bytes),
        arg_bytes=float(ma.argument_size_in_bytes),
    )


def two_point(cost_u1: CellCost, cost_u2: CellCost, n_groups: int) -> CellCost:
    """total = outside + n_groups * (group delta); memory stats from u1."""
    def comb(a, b):
        delta = max(b - a, 0.0)
        return a + (n_groups - 1) * delta

    coll = {}
    keys = set(cost_u1.coll_breakdown) | set(cost_u2.coll_breakdown)
    for k in keys:
        coll[k] = comb(cost_u1.coll_breakdown.get(k, 0),
                       cost_u2.coll_breakdown.get(k, 0))
    return CellCost(
        flops=comb(cost_u1.flops, cost_u2.flops),
        bytes_accessed=comb(cost_u1.bytes_accessed, cost_u2.bytes_accessed),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        peak_memory=cost_u1.peak_memory,
        arg_bytes=cost_u1.arg_bytes,
    )


def model_flops(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS per device: 6*N*D train, 2*N*D forward-only (D = tokens
    processed; decode D = global_batch tokens). MoE uses active params."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        total = 6.0 * n * toks
    elif shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        total = 2.0 * n * toks
    else:  # decode: one token per sequence
        total = 2.0 * n * shape.global_batch
    return total / n_devices


def recurrence_correction(cfg, shape, n_devices: int) -> tuple[float, float]:
    """Analytic FLOPs/bytes for nested-scan recurrences (RWKV WKV): counted
    once by cost_analysis even with the two-point method."""
    if not cfg.pattern or cfg.pattern[0] != "rwkv":
        return 0.0, 0.0
    if shape.kind == "decode":
        toks = shape.global_batch
    else:
        toks = shape.global_batch * shape.seq_len
    h, hd = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    # per token per layer: kv outer (h*hd*hd) + state update (2x) + readout (2x)
    fl = 5.0 * h * hd * hd * toks * cfg.n_layers
    by = 2.0 * 4.0 * h * hd * hd * toks * cfg.n_layers  # state r/w fp32
    return fl / n_devices, by / n_devices
