"""Production meshes (per the assignment).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only
dryrun.py sets the 512-device XLA flag).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist on newer releases; older ones
    default to auto axes anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests (1x1, same axis names)."""
    return compat_make_mesh((1, 1), ("data", "model"))


def make_host_multi_mesh(shape=(2, 4)):
    """Multi-device host-platform mesh for sharded-ACU tests and the
    ``[sharded]`` benchmark section (same ``(data, model)`` axis names as
    production). Needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (N >= prod(shape)) exported *before* jax initializes; raises otherwise so
    callers fail loudly instead of silently benchmarking a 1-device mesh."""
    import numpy as np
    need = int(np.prod(shape))
    have = len(jax.devices())
    if have < need:
        raise RuntimeError(
            f"host mesh {shape} needs {need} devices, found {have}; export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} before "
            f"importing jax")
    return compat_make_mesh(shape, ("data", "model"))
