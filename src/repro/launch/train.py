"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        [--steps N] [--approx mul8s_1L2H:lut] [--ckpt DIR] [--reduced]

On real hardware this process runs per-host under `jax.distributed`
(initialize() is called when the standard cluster env vars are present);
in this container it runs single-process. The step function, planner
shardings, checkpointing and recovery paths are identical either way —
that's the point of the dry-run-first design.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--approx", default=None, help="mult:mode[:rank]")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--reduced", action="store_true",
                    help="width-reduced config (CPU-sized)")
    args = ap.parse_args()

    if "JAX_COORDINATOR_ADDRESS" in os.environ:  # multi-host cluster
        jax.distributed.initialize()

    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import MarkovLM, Prefetcher
    from repro.launch.specs import make_acfg
    from repro.models.transformer import init_params, loss_fn
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 4096),
                              vocab_pad_mult=16)
    acfg = make_acfg(args.approx)

    lm = MarkovLM(vocab=cfg.vocab_size, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=cosine_schedule(3e-4, 100, args.steps), weight_decay=0.01)

    trainer = Trainer(
        lambda p, b: loss_fn(p, b["tokens"], b["labels"], cfg, acfg), opt,
        TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=100, log_every=20))
    data = Prefetcher(lm.batches(args.batch, args.seq), depth=2)
    trainer.fit(params, opt.init(params), data, args.steps)
    data.close()
    for h in trainer.history[-10:]:
        print(h)


if __name__ == "__main__":
    main()
