"""Synthetic deterministic data pipeline.

No external datasets are available offline (DESIGN.md §9); these generators
produce *learnable* tasks so the paper's relative claims (quant ~= fp32,
approx << quant, retrain ~= quant) can be validated end-to-end:

* token streams from a fixed random Markov chain (LM pretraining demo),
* class-conditional image patterns + noise (CNN classification),
* class-conditional token distributions (LSTM text classification),
* digit-like blobs (VAE / GAN reconstruction).

The host pipeline shards each global batch across the ``("pod","data")`` mesh
axes and prefetches with a bounded queue (straggler posture, DESIGN.md §5).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM token stream: order-1 Markov chain with heavy-tailed transitions
# ---------------------------------------------------------------------------

class MarkovLM:
    def __init__(self, vocab: int, seed: int = 0, branching: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        self.succ = rng.integers(0, vocab, (vocab, branching))
        w = 1.0 / np.arange(1, branching + 1)
        self.probs = w / w.sum()

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        for t in range(seq):
            choice = rng.choice(self.succ.shape[1], size=batch, p=self.probs)
            out[:, t + 1] = self.succ[out[:, t], choice]
        return out

    def batches(self, batch: int, seq: int, seed: int = 1) -> Iterator[dict]:
        rng = np.random.default_rng(seed)
        while True:
            chunk = self.sample(rng, batch, seq)
            yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}


# ---------------------------------------------------------------------------
# vision: class-conditional patterns + noise (CIFAR stand-in)
# ---------------------------------------------------------------------------

def image_task(n_classes: int = 10, size: int = 32, channels: int = 3,
               seed: int = 0):
    rng = np.random.default_rng(seed)
    bases = rng.normal(size=(n_classes, channels, size, size)).astype(np.float32)

    def batches(batch: int, noise: float = 0.8, seed: int = 1) -> Iterator[dict]:
        r = np.random.default_rng(seed)
        while True:
            y = r.integers(0, n_classes, batch)
            x = bases[y] + noise * r.normal(size=(batch, channels, size, size)
                                            ).astype(np.float32)
            yield {"image": x.astype(np.float32), "label": y.astype(np.int32)}

    return batches


# ---------------------------------------------------------------------------
# text classification: class-dependent token distributions (IMDB stand-in)
# ---------------------------------------------------------------------------

def text_cls_task(vocab: int = 1000, n_classes: int = 2, seed: int = 0):
    rng = np.random.default_rng(seed)
    class_logits = rng.normal(size=(n_classes, vocab)).astype(np.float32) * 1.5

    def batches(batch: int, seq: int = 64, seed: int = 1) -> Iterator[dict]:
        r = np.random.default_rng(seed)
        probs = np.exp(class_logits)
        probs /= probs.sum(-1, keepdims=True)
        while True:
            y = r.integers(0, n_classes, batch)
            toks = np.stack([r.choice(vocab, size=seq, p=probs[c]) for c in y])
            yield {"tokens": toks.astype(np.int32), "label": y.astype(np.int32)}

    return batches


# ---------------------------------------------------------------------------
# digit-like blobs for VAE/GAN (MNIST stand-in)
# ---------------------------------------------------------------------------

def blob_task(size: int = 28, n_classes: int = 10, seed: int = 0):
    rng = np.random.default_rng(seed)
    cx, cy = rng.uniform(6, size - 6, (2, n_classes))
    r0 = rng.uniform(2, 6, n_classes)
    yy, xx = np.mgrid[0:size, 0:size]

    def batches(batch: int, seed: int = 1) -> Iterator[dict]:
        r = np.random.default_rng(seed)
        while True:
            y = r.integers(0, n_classes, batch)
            d2 = (xx[None] - cx[y, None, None]) ** 2 + \
                (yy[None] - cy[y, None, None]) ** 2
            img = (d2 < r0[y, None, None] ** 2).astype(np.float32)
            img = np.clip(img + 0.1 * r.normal(size=img.shape), 0, 1)
            yield {"image": img.reshape(batch, -1).astype(np.float32),
                   "label": y.astype(np.int32)}

    return batches


# ---------------------------------------------------------------------------
# device placement + bounded prefetch
# ---------------------------------------------------------------------------

def shard_batch(batch: dict, sharding=None) -> dict:
    if sharding is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


class Prefetcher:
    """Bounded-depth background prefetch: a persistently slow producer can
    never stall consumers by more than ``depth`` steps (straggler bound).

    Producer exceptions propagate: the daemon thread enqueues the exception
    as a sentinel item and ``__next__`` re-raises it on the consumer thread
    (it used to die silently in the thread, leaving ``__next__`` blocked on
    ``q.get()`` forever). Exhaustion likewise flows through as a sentinel ->
    ``StopIteration``. ``close()`` reliably unblocks a producer stuck on a
    full queue: the producer only ever waits on ``put`` with a timeout and
    re-checks the stop flag, and ``close`` drains the queue until the thread
    exits.
    """

    _END = object()

    def __init__(self, it: Iterator[dict], depth: int = 2, sharding=None):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self.sharding = sharding
        self._stop = threading.Event()
        self._err: BaseException | None = None
        self._done = False
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _put(self, item) -> bool:
        """Stop-aware bounded put; False when the prefetcher was closed."""
        while not self._stop.is_set():
            try:
                self.q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _run(self):
        try:
            for b in self.it:
                if self._stop.is_set():
                    return
                if not self._put(("item", shard_batch(b, self.sharding))):
                    return
        except BaseException as e:  # noqa: BLE001 — must reach the consumer
            self._put(("error", e))
        else:
            self._put(("end", None))

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        if self._err is not None:
            raise self._err
        if self._done:
            raise StopIteration
        kind, val = self.q.get()
        if kind == "item":
            return val
        if kind == "error":
            self._err = val
            raise val
        self._done = True
        raise StopIteration

    def close(self):
        self._stop.set()
        # drain so a producer blocked on a full queue sees the stop flag
        while self.t.is_alive():
            try:
                self.q.get_nowait()
            except queue.Empty:
                pass
            self.t.join(timeout=0.05)
