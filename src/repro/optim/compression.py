"""Int8 error-feedback gradient compression (distributed-optimization trick).

Data-parallel gradient all-reduce dominates the collective term for small
models; quantizing gradients to int8 with per-leaf scales cuts those bytes 4x
(vs fp32). Error feedback accumulates the quantization residual locally and
re-injects it next step, which keeps SGD/Adam convergence (Karimireddy et al.)
— validated by tests/test_compression.py on a real training task.

Used via ``shard_map`` (the explicit-collective path in train/trainer.py):
inside jit, GSPMD owns the all-reduce and would not see this compression.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # same structure as grads, fp32


def init_ef(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(g: jnp.ndarray, amax: jnp.ndarray | None = None
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 -> (int8 codes, scale).

    ``amax`` overrides the calibration bound (``compressed_psum`` passes its
    pmax'd cross-worker bound so every worker quantizes on the same grid —
    local bounds would make the same value code differently per worker and
    the psum'd average drift from what each worker's residual accounts for).
    """
    g = g.astype(jnp.float32)
    if amax is None:
        amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: EFState, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Scales are psum-maxed first so codes are commensurable across workers;
    the residual keeps what int8 dropped. Quantization goes through the same
    :func:`compress`/:func:`decompress` pair as the standalone API, so the
    wire format is actual int8 codes and the round-trip bound proven by the
    standalone tests holds verbatim inside the psum path.
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        q, scale = compress(g, amax)
        sent = decompress(q, scale)
        new_r = g - sent
        summed = jax.lax.psum(sent, axis_name) / jax.lax.psum(1.0, axis_name)
        return summed, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    summed = tdef.unflatten([o[0] for o in out])
    resid = tdef.unflatten([o[1] for o in out])
    return summed, EFState(residual=resid)
