"""Int8 error-feedback gradient compression (distributed-optimization trick).

Data-parallel gradient all-reduce dominates the collective term for small
models; quantizing gradients to int8 with per-leaf scales cuts those bytes 4x
(vs fp32). Error feedback accumulates the quantization residual locally and
re-injects it next step, which keeps SGD/Adam convergence (Karimireddy et al.)
— validated by tests/test_compression.py on a real training task.

Used via ``shard_map`` (the explicit-collective path in train/trainer.py):
inside jit, GSPMD owns the all-reduce and would not see this compression.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict  # same structure as grads, fp32


def init_ef(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(g: jnp.ndarray, amax: jnp.ndarray | None = None
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fp32 -> (int8 codes, scale).

    ``amax`` overrides the calibration bound (``compressed_psum`` passes its
    pmax'd cross-worker bound so every worker quantizes on the same grid —
    local bounds would make the same value code differently per worker and
    the psum'd average drift from what each worker's residual accounts for).
    """
    g = g.astype(jnp.float32)
    if amax is None:
        amax = jnp.max(jnp.abs(g))
    bound = jnp.maximum(amax, 1e-12)
    scale = bound * (1.0 / 127.0)   # multiply form: see inv_scale note below
    # quantize by MULTIPLYING with the inverse scale, not dividing by scale:
    # XLA fusion rewrites x/s to x*(1/s) in some contexts, so a divide-form
    # code can flip at rounding boundaries between the eager and jitted
    # paths — the multiply form lowers identically everywhere, which the
    # sharded==single-device bitwise pins (tests/test_damping.py) rely on.
    inv_scale = 127.0 / bound
    q = jnp.clip(jnp.round(g * inv_scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: EFState, axis_name, *, with_stats: bool = False):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Scales are psum-maxed first so codes are commensurable across workers;
    the residual keeps what int8 dropped. Quantization goes through the same
    :func:`compress`/:func:`decompress` pair as the standalone API, so the
    wire format is actual int8 codes and the round-trip bound proven by the
    standalone tests holds verbatim inside the psum path.

    The collective sums the INT32-widened codes and applies ``scale / n``
    once afterwards: integer addition is associative, so the psum'd mean is
    bitwise independent of the reduction order (the float-psum-of-decompressed
    form it replaces was not) — this is what lets the damped mesh step match
    a single-device oracle exactly (optim/damping.py). It also quarters the
    wire bytes relative to psumming decompressed fp32.

    ``with_stats=True`` additionally returns a :class:`~repro.optim.damping.
    NoiseStats`-shaped dict of free gradient-noise statistics: the mean
    per-worker |g|^2 (RAW shard gradients, before the residual is folded
    in), the |mean|^2 of the transmitted mean, and the mean residual energy
    — the small/large-batch estimator pair plus the second noise signal,
    with no extra gradient passes and only two extra scalar psums.
    """
    n = jax.lax.psum(1.0, axis_name)

    def one(g, r):
        g_raw = g.astype(jnp.float32)
        g = g_raw + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        q, scale = compress(g, amax)
        new_r = g - decompress(q, scale)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        summed = q_sum.astype(jnp.float32) * (scale / n)
        return summed, new_r, g_raw

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    summed = tdef.unflatten([o[0] for o in out])
    resid = tdef.unflatten([o[1] for o in out])
    new_ef = EFState(residual=resid)
    if not with_stats:
        return summed, new_ef
    sq = lambda leaves: sum(jnp.sum(jnp.square(x)) for x in leaves)
    stats = {
        "gsq_small": jax.lax.psum(sq([o[2] for o in out]), axis_name) / n,
        "gsq_big": sq([o[0] for o in out]),
        "resid_sq": jax.lax.psum(sq([o[1] for o in out]), axis_name) / n,
    }
    return summed, new_ef, stats
