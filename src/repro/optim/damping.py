"""Gradient-noise batch damping for QAT recovery (the adadamp regime).

Approximate gradients are noisy early in recovery: the ACU's multiplier error
acts as extra per-sample gradient noise on top of sampling noise, and both
shrink as the model adapts to the approximate forward/backward. Following
McCandlish et al. ("An Empirical Model of Large-Batch Training") and adadamp,
the *gradient noise scale*

    B_noise = S / |G|^2,   with   E[|G_B|^2] = |G|^2 + S / B

is the batch size at which sampling noise stops dominating; training is
sample-efficient while the effective batch tracks ~B_noise. The two-point
estimator needs gradient norms at two batch sizes (B_small < B_big):

    |G|^2 ~= (B_big |G_big|^2 - B_small |G_small|^2) / (B_big - B_small)
    S     ~= (|G_small|^2 - |G_big|^2) / (1/B_small - 1/B_big)

Both pairs are FREE in this codebase — no extra gradient passes:

* the microbatch ``lax.scan`` in ``train/trainer.py`` already holds each
  per-microbatch gradient before accumulating it (B_small = microbatch rows,
  B_big = full accumulated batch);
* the mesh's ``compressed_psum`` (``optim/compression.py``) already holds
  each worker's local shard gradient next to the psum'd mean (B_small =
  shard rows, B_big = global batch) — ``compressed_psum(..., with_stats=
  True)`` exports exactly that pair;
* the error-feedback residual energy from the same psum is a second noise
  signal: what int8 dropped this step is gradient content the optimizer has
  not seen yet, so it blends into S with ``DampingConfig.residual_weight``.

The schedule side is deliberately host-side and integer-valued: the trainer
grows its accumulation factor (whole data batches per optimizer step), so
every distinct effective batch is one more jit cache entry, not a recompile
per step. State round-trips through the checkpoint manifest ``extra`` as
plain JSON so a kill-and-resume replays the identical schedule.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


def tree_sqnorm(tree) -> jnp.ndarray:
    """Sum of squared entries over every leaf (fp32)."""
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(tree))


class NoiseStats(NamedTuple):
    """One step's raw small/large-batch gradient-norm pair.

    ``gsq_small`` is the MEAN over the small-batch estimates (microbatches or
    workers) of |g_i|^2; ``gsq_big`` is |mean_i g_i|^2; ``resid_sq`` is the
    error-feedback residual energy (0 when compression is off).
    """

    gsq_small: jnp.ndarray
    gsq_big: jnp.ndarray
    b_small: int
    b_big: int
    resid_sq: jnp.ndarray = jnp.float32(0.0)


def noise_scale(gsq_small: float, gsq_big: float, b_small: int, b_big: int
                ) -> tuple[float, float]:
    """Unbiased (S, |G|^2) estimates from a two-batch-size norm pair.

    Per-step estimates are noisy and either can go negative — consumers EMA
    them separately (``DampingState``) and clamp only at the ratio.
    """
    assert b_big > b_small > 0, (b_small, b_big)
    g2 = (b_big * gsq_big - b_small * gsq_small) / (b_big - b_small)
    s = (gsq_small - gsq_big) / (1.0 / b_small - 1.0 / b_big)
    return float(s), float(g2)


def microbatch_noise_stats(micro_sqsum: jnp.ndarray, grads_mean,
                           b_small: int, b_big: int) -> NoiseStats:
    """Stats from the trainer's accumulation scan: ``micro_sqsum`` is the
    scan-accumulated sum of per-microbatch |g_i|^2 over ``n`` microbatches
    (so mean = sum / n with n = b_big // b_small)."""
    n = b_big // b_small
    return NoiseStats(gsq_small=micro_sqsum / n,
                      gsq_big=tree_sqnorm(grads_mean),
                      b_small=b_small, b_big=b_big)


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DampingConfig:
    """Batch-damping policy. ``accum`` counts whole data batches folded into
    one optimizer step, so effective batch = accum * batch_size."""

    accum_min: int = 1
    accum_max: int = 16
    ema: float = 0.8              # EMA decay for the S and |G|^2 estimates
    check_every: int = 1          # steps between schedule updates
    warmup_updates: int = 2       # estimates folded in before first growth
    grow_only: bool = True        # monotone schedule (QAT recovery posture)
    max_growth: int = 2           # accum can at most double per update
    residual_weight: float = 0.0  # EF residual energy blended into S
    target_frac: float = 1.0      # aim effective batch = frac * B_noise


@dataclasses.dataclass
class DampingState:
    """EMA'd noise estimates + the current integer schedule position.

    JSON-plain on purpose: ``to_dict``/``from_dict`` round-trip through the
    checkpoint manifest ``extra`` so a resumed run replays the exact
    schedule (bitwise: the fields are Python floats, not arrays).
    """

    accum: int = 1
    updates: int = 0
    ema_s: float = 0.0
    ema_g2: float = 0.0
    ema_resid: float = 0.0
    b_noise: float = 0.0          # last smoothed S/|G|^2 (diagnostics)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DampingState":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def init_state(cfg: DampingConfig) -> DampingState:
    return DampingState(accum=cfg.accum_min)


def update_state(state: DampingState, cfg: DampingConfig, stats: NoiseStats,
                 batch_size: int) -> DampingState:
    """Fold one step's stats into the EMAs and move the integer schedule.

    Host-side float math on host-side floats: given identical stats the
    transition is deterministic, which is what makes the damped schedule
    checkpoint-replayable.
    """
    s, g2 = noise_scale(float(stats.gsq_small), float(stats.gsq_big),
                        int(stats.b_small), int(stats.b_big))
    resid = float(stats.resid_sq)
    if cfg.residual_weight:
        # what int8 dropped is gradient content the step didn't apply —
        # count it as extra per-sample noise at the small batch size
        s = s + cfg.residual_weight * resid * int(stats.b_small)
    k = state.updates + 1
    # debiased EMA (Adam-style) so early estimates aren't pulled toward 0
    ema_s = cfg.ema * state.ema_s + (1 - cfg.ema) * s
    ema_g2 = cfg.ema * state.ema_g2 + (1 - cfg.ema) * g2
    ema_resid = cfg.ema * state.ema_resid + (1 - cfg.ema) * resid
    bias = 1.0 - cfg.ema ** k
    b_noise = max(ema_s / bias, 0.0) / max(ema_g2 / bias, 1e-20)

    accum = state.accum
    if k >= cfg.warmup_updates:
        want = cfg.target_frac * b_noise / max(batch_size, 1)
        target = int(min(max(round(want), cfg.accum_min), cfg.accum_max))
        if target > state.accum:                      # rate-limited growth
            accum = min(target, state.accum * cfg.max_growth)
        elif target < state.accum and not cfg.grow_only:
            accum = max(target, state.accum // cfg.max_growth, cfg.accum_min)
    return DampingState(accum=accum, updates=k, ema_s=ema_s, ema_g2=ema_g2,
                        ema_resid=ema_resid, b_noise=b_noise)


# ---------------------------------------------------------------------------
# mesh-side stats (see also compressed_psum(with_stats=True))
# ---------------------------------------------------------------------------

def shard_noise_stats(grads, grads_mean, axis_name, b_local: int,
                      n_workers: int) -> NoiseStats:
    """Inside ``shard_map``: the per-worker vs psum'd-mean pair.

    ``grads`` is this worker's local shard gradient, ``grads_mean`` the
    already-psum'd mean (both free — the mesh computes them anyway). Only
    one scalar psum is added. ``gsq_big`` is computed on the replicated
    mean, so every worker (and a single-device oracle fed the same mean)
    reduces it in the identical order. ``n_workers`` is static (the mesh
    axis product) so the batch sizes stay Python ints.
    """
    local = tree_sqnorm(grads)
    small = jax.lax.psum(local, axis_name) / jnp.float32(n_workers)
    return NoiseStats(gsq_small=small, gsq_big=tree_sqnorm(grads_mean),
                      b_small=b_local, b_big=int(b_local) * int(n_workers))
