"""Optimizers (pure-JAX, optax-free): AdamW + SGD, schedules, clipping.

Optimizer state dtype is fp32 regardless of param dtype (bf16 training keeps
fp32 master moments); state shards with the same planner rules as params
(FSDP over "data", TP over "model") — the ZeRO posture from DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: dict


@dataclasses.dataclass(frozen=True)
class SGD:
    """Paper §5.1 retrains with SGD, lr 1e-4."""

    lr: Callable | float = 1e-4
    momentum: float = 0.0
    clip_norm: Optional[float] = None

    def init(self, params) -> SGDState:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=m)

    def update(self, grads, state: SGDState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)
        if self.momentum:
            m = jax.tree.map(lambda mm, g: self.momentum * mm + g,
                             state.momentum, grads)
        else:
            m = grads
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params, m)
        return new_params, SGDState(step=step, momentum=m if self.momentum else state.momentum)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
