"""Batched serving demo: prefill + KV-cache greedy decode through the
ServeEngine (wave batching), optionally through an approximate ACU.

    PYTHONPATH=src python examples/serve_decode.py [--approx mul8s_1L2H]
"""
import argparse

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--approx", default=None)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    acfg = (ApproxConfig(acu=make_acu(args.approx, AcuMode.LUT))
            if args.approx else None)

    eng = ServeEngine(params, cfg, slots=4, max_seq=128, acfg=acfg)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(3, 10)).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    done = eng.run(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt={list(r.prompt)} -> out={list(r.out)}")


if __name__ == "__main__":
    main()
