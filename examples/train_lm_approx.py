"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic Markov stream — with the paper's approximate-multiplier emulation
switchable on any GEMM.

    PYTHONPATH=src python examples/train_lm_approx.py \
        --arch smollm-135m --steps 300 [--approx mul8s_1L2H] [--full-size]

Default runs a width-reduced smollm (CPU-sized); --full-size uses the real
135M config (slow on CPU but exercises the production path: planner
shardings, microbatching, checkpointing, fault recovery).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.data.pipeline import MarkovLM, Prefetcher
from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--approx", default=None,
                    help="multiplier name, e.g. mul8s_1L2H")
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.full_size:
        cfg = dataclasses.replace(get_config(args.arch), dtype="float32",
                                  vocab_size=2048, vocab_pad_mult=16)
    else:
        cfg = dataclasses.replace(reduced_config(args.arch),
                                  d_model=192, n_heads=12, n_kv_heads=4,
                                  head_dim=16, d_ff=512, n_layers=6,
                                  vocab_size=2048, vocab_pad_mult=16)
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M "
          f"(reduced vocab for the synthetic task)")

    acfg = None
    if args.approx:
        acfg = ApproxConfig(acu=make_acu(args.approx, AcuMode.LUT))
        print(f"ACU emulation ON: {args.approx}")

    lm = MarkovLM(vocab=cfg.vocab_size, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=cosine_schedule(3e-4, 50, args.steps), weight_decay=0.01)

    def batch_loss(p, batch):
        return loss_fn(p, batch["tokens"], batch["labels"], cfg, acfg)

    trainer = Trainer(batch_loss, opt,
                      TrainerConfig(ckpt_dir=args.ckpt, ckpt_every=100,
                                    log_every=20))
    data = Prefetcher(lm.batches(args.batch, args.seq), depth=2)
    params, _ = trainer.fit(params, opt.init(params), data, args.steps)
    data.close()

    for h in trainer.history:
        if "loss" in h:
            print(f"step {h['step']:4d}  loss {h['loss']:.4f}  {h['dt']*1e3:.0f}ms")
        else:
            print(f"step {h['step']:4d}  {h['event']}")


if __name__ == "__main__":
    main()
