"""Paper Table-2 flow as a script: quantize -> evaluate approx -> retrain.

    PYTHONPATH=src python examples/retrain_recovery.py [--acu mul8s_1L2H]

Shows calibration (percentile histogram observer), post-training
quantization, the accuracy drop under a lossy ACU, and QAT recovery —
the full Fig. 1 pipeline on a CNN + an LSTM.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.core.calibration import HistogramObserver, calibrate_activation
from repro.data.pipeline import image_task, text_cls_task
from repro.models.rnn import init_lstm, lstm
from repro.models.vision import cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)


def cnn_flow(acu_name: str):
    print(f"\n=== CNN x {acu_name} ===")
    task = image_task(n_classes=4, size=16)
    params = init_cnn(KEY, n_classes=4, width=8, img=16)

    def xent(p, img, lab, acfg=None):
        logits = cnn_forward(p, img, acfg)
        return (jax.nn.logsumexp(logits, -1) -
                jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]).mean()

    def train(p, steps, lr, acfg=None, seed=1):
        step = jax.jit(lambda p, i, l: jax.tree.map(
            lambda w, g: w - lr * g, p,
            jax.grad(lambda p: xent(p, i, l, acfg))(p)))
        it = iter(task(64, seed=seed))
        for _ in range(steps):
            b = next(it)
            p = step(p, jnp.asarray(b["image"]), jnp.asarray(b["label"]))
        return p

    def acc(p, acfg=None):
        it = iter(task(64, seed=99))
        hits = 0
        for _ in range(3):
            b = next(it)
            pred = jnp.argmax(cnn_forward(p, jnp.asarray(b["image"]), acfg), -1)
            hits += int((pred == jnp.asarray(b["label"])).sum())
        return hits / 192

    params = train(params, 60, 3e-3)
    print(f"FP32:            {acc(params):.3f}")

    # calibration demo: observe activations on a representative subset
    # (the paper: "only a representative subset ... ~10% of training data")
    obs = HistogramObserver()
    it = iter(task(64, seed=5))
    for _ in range(2):  # two batches, like the paper §5.1
        obs.update(next(it)["image"])
    qp = calibrate_activation(obs, 8, method="percentile")
    print(f"calibrated activation scale: {float(qp.scale):.5f} "
          f"(99.9% percentile histogram)")

    quant = ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT))
    print(f"8-bit quantized: {acc(params, quant):.3f}")

    bits = 12 if "12" in acu_name else 8
    mode = AcuMode.FUNCTIONAL if bits > 10 else AcuMode.LUT
    apx = ApproxConfig(acu=make_acu(acu_name, mode), a_bits=bits, w_bits=bits)
    print(f"{bits}-bit approx:   {acc(params, apx):.3f}")

    params = train(params, 30, 1e-3, acfg=apx, seed=2)
    print(f"after retrain:   {acc(params, apx):.3f}")


def lstm_flow(acu_name: str):
    print(f"\n=== LSTM x {acu_name} ===")
    task = text_cls_task(vocab=200, n_classes=2)
    emb = jax.random.normal(KEY, (200, 16)) * 0.3
    p = {"lstm": init_lstm(KEY, 16, 32),
         "head": jax.random.normal(KEY, (32, 2)) * 0.2}

    def fwd(p, toks, acfg=None):
        return lstm(emb[toks], p["lstm"], acfg) @ p["head"]

    def xent(p, toks, lab, acfg=None):
        logits = fwd(p, toks, acfg)
        return (jax.nn.logsumexp(logits, -1) -
                jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]).mean()

    def train(p, steps, lr, acfg=None, seed=3):
        step = jax.jit(lambda p, t, l: jax.tree.map(
            lambda w, g: w - lr * g, p,
            jax.grad(lambda p: xent(p, t, l, acfg))(p)))
        it = iter(task(32, seq=24, seed=seed))
        for _ in range(steps):
            b = next(it)
            p = step(p, jnp.asarray(b["tokens"]), jnp.asarray(b["label"]))
        return p

    def acc(p, acfg=None):
        it = iter(task(64, seq=24, seed=99))
        hits = 0
        for _ in range(3):
            b = next(it)
            pred = jnp.argmax(fwd(p, jnp.asarray(b["tokens"]), acfg), -1)
            hits += int((pred == jnp.asarray(b["label"])).sum())
        return hits / 192

    p = train(p, 60, 1e-2)
    print(f"FP32:            {acc(p):.3f}")
    bits = 12 if "12" in acu_name else 8
    mode = AcuMode.FUNCTIONAL if bits > 10 else AcuMode.LUT
    apx = ApproxConfig(acu=make_acu(acu_name, mode), a_bits=bits, w_bits=bits)
    print(f"{bits}-bit approx:   {acc(p, apx):.3f}")
    p = train(p, 20, 1e-3, acfg=apx, seed=4)
    print(f"after retrain:   {acc(p, apx):.3f}")


def fused_bwd_qat_step(acu_name: str):
    """One ImageNet-scale QAT step with the fused approximate backward
    (PR 6): a 1x64x224x224 conv whose STE gradients run through the ACU
    in-kernel — banded weight-grad + per-band input-grad GEMMs, so the
    (N*Ho*Wo, Kh*Kw*Cin) im2col patch tensor never exists in HBM in either
    direction (docs/fused_conv.md, "Approximate backward")."""
    print(f"\n=== fused approx-backward QAT step x {acu_name} (1x64x224x224) ===")
    from repro.core.approx_ops import conv2d, conv_plan_report

    acu = make_acu(acu_name, AcuMode.LUT, use_pallas=True, fused=True)
    apx = ApproxConfig(acu=acu, approx_bwd=True)
    x = jax.random.normal(KEY, (1, 64, 224, 224), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64, 3, 3)) * 0.05

    rep = conv_plan_report(x.shape, w.shape, apx)
    print(f"forward route: {rep['route']}, backward route: "
          f"{rep.get('bwd_route')} (no materialized im2col)")

    def loss(w):
        return (conv2d(x, w, cfg=apx) ** 2).mean()

    step = jax.jit(lambda w: w - 1e-2 * jax.grad(loss)(w))
    l0 = float(loss(w))
    w = step(w)                      # the QAT step: grads via the LUT
    print(f"loss {l0:.5f} -> {float(loss(w)):.5f} after one fused-bwd step")


def damped_recovery_flow(acu_name: str):
    """Mesh-wide damped QAT recovery (docs/training.md): drop a pretrained
    CNN onto a lossy ACU, then recover through the fused approximate
    backward twice with the fault-tolerant ``Trainer`` — once at a fixed
    large batch, once with gradient-noise batch damping growing the
    effective batch from a quarter of it. Runs data-parallel on the 2x4
    host mesh (int8 error-feedback compressed psum) when 8 devices are
    available, single-device otherwise."""
    from repro.optim.adamw import SGD
    from repro.optim.damping import DampingConfig
    from repro.train.trainer import Trainer, TrainerConfig

    mesh = None
    if len(jax.devices()) >= 8:
        from repro.launch.mesh import make_host_multi_mesh
        mesh = make_host_multi_mesh((2, 4))
    print(f"\n=== damped mesh-wide recovery x {acu_name} "
          f"(mesh={'2x4' if mesh is not None else 'single-device'}) ===")

    task0 = image_task(n_classes=4, size=8)
    task = lambda b, seed: task0(b, noise=0.55, seed=seed)
    params = init_cnn(KEY, n_classes=4, width=8, img=8)
    apx = ApproxConfig(acu=make_acu(acu_name, AcuMode.LUT, use_pallas=True,
                                    fused=True), approx_bwd=True)

    def xent(p, b, acfg=None):
        logits = cnn_forward(p, b["image"], acfg)
        return (jax.nn.logsumexp(logits, -1) -
                jnp.take_along_axis(logits, b["label"][:, None], -1)[:, 0]
                ).mean()

    pre = jax.jit(lambda p, b: jax.tree.map(
        lambda w, g: w - 3e-3 * g, p, jax.grad(xent)(p, b)))
    it = iter(task(64, seed=1))
    for _ in range(60):
        b = next(it)
        params = pre(params, {k: jnp.asarray(v) for k, v in b.items()})

    eb = next(iter(task(256, seed=99)))
    eimg, elab = jnp.asarray(eb["image"]), jnp.asarray(eb["label"])
    acc = jax.jit(lambda p: jnp.mean(
        jnp.argmax(cnn_forward(p, eimg, apx), -1) == elab))
    print(f"dropped onto {acu_name}: acc {float(acc(params)):.3f}")

    def recover(damping, batch, n_steps):
        tr = Trainer(lambda p, b: xent(p, b, apx), SGD(lr=3e-3),
                     TrainerConfig(mesh=mesh, log_every=10**9,
                                   damping=damping), donate=False)
        p0 = jax.tree.map(jnp.copy, params)
        p, _ = tr.fit(p0, SGD(lr=3e-3).init(p0),
                      ({k: jnp.asarray(v) for k, v in bt.items()}
                       for bt in task(batch, seed=2)), n_steps)
        return p, tr

    p_fix, tr_fix = recover(None, 32, 40)
    print(f"fixed batch=32, 40 steps ({tr_fix.consumed * 32} samples): "
          f"acc {float(acc(p_fix)):.3f}")
    p_dmp, tr_dmp = recover(
        DampingConfig(accum_max=4, warmup_updates=2, ema=0.5), 8, 60)
    print(f"damped batch=8->accum {tr_dmp.damp_state.accum}x, 60 steps "
          f"({tr_dmp.consumed * 8} samples): acc {float(acc(p_dmp)):.3f} "
          f"(B_noise~{tr_dmp.damp_state.b_noise:.0f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--acu", default="mul8s_1L2H")
    ap.add_argument("--skip-imagenet-scale", action="store_true",
                    help="skip the 224^2 fused-backward QAT step")
    ap.add_argument("--damped-acu", default="mul8s_trunc3",
                    help="lossy ACU for the damped mesh-wide recovery demo")
    ap.add_argument("--skip-damped", action="store_true",
                    help="skip the damped mesh-wide recovery demo")
    args = ap.parse_args()
    cnn_flow(args.acu)
    lstm_flow(args.acu)
    if not args.skip_imagenet_scale:
        fused_bwd_qat_step(args.acu)
    if not args.skip_damped:
        damped_recovery_flow(args.damped_acu)
