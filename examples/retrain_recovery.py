"""Paper Table-2 flow as a script: quantize -> evaluate approx -> retrain.

    PYTHONPATH=src python examples/retrain_recovery.py [--acu mul8s_1L2H]

Shows calibration (percentile histogram observer), post-training
quantization, the accuracy drop under a lossy ACU, and QAT recovery —
the full Fig. 1 pipeline on a CNN + an LSTM.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig
from repro.core.calibration import HistogramObserver, calibrate_activation
from repro.data.pipeline import image_task, text_cls_task
from repro.models.rnn import init_lstm, lstm
from repro.models.vision import cnn_forward, init_cnn

KEY = jax.random.PRNGKey(0)


def cnn_flow(acu_name: str):
    print(f"\n=== CNN x {acu_name} ===")
    task = image_task(n_classes=4, size=16)
    params = init_cnn(KEY, n_classes=4, width=8, img=16)

    def xent(p, img, lab, acfg=None):
        logits = cnn_forward(p, img, acfg)
        return (jax.nn.logsumexp(logits, -1) -
                jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]).mean()

    def train(p, steps, lr, acfg=None, seed=1):
        step = jax.jit(lambda p, i, l: jax.tree.map(
            lambda w, g: w - lr * g, p,
            jax.grad(lambda p: xent(p, i, l, acfg))(p)))
        it = iter(task(64, seed=seed))
        for _ in range(steps):
            b = next(it)
            p = step(p, jnp.asarray(b["image"]), jnp.asarray(b["label"]))
        return p

    def acc(p, acfg=None):
        it = iter(task(64, seed=99))
        hits = 0
        for _ in range(3):
            b = next(it)
            pred = jnp.argmax(cnn_forward(p, jnp.asarray(b["image"]), acfg), -1)
            hits += int((pred == jnp.asarray(b["label"])).sum())
        return hits / 192

    params = train(params, 60, 3e-3)
    print(f"FP32:            {acc(params):.3f}")

    # calibration demo: observe activations on a representative subset
    # (the paper: "only a representative subset ... ~10% of training data")
    obs = HistogramObserver()
    it = iter(task(64, seed=5))
    for _ in range(2):  # two batches, like the paper §5.1
        obs.update(next(it)["image"])
    qp = calibrate_activation(obs, 8, method="percentile")
    print(f"calibrated activation scale: {float(qp.scale):.5f} "
          f"(99.9% percentile histogram)")

    quant = ApproxConfig(acu=make_acu("mul8s_exact", AcuMode.EXACT))
    print(f"8-bit quantized: {acc(params, quant):.3f}")

    bits = 12 if "12" in acu_name else 8
    mode = AcuMode.FUNCTIONAL if bits > 10 else AcuMode.LUT
    apx = ApproxConfig(acu=make_acu(acu_name, mode), a_bits=bits, w_bits=bits)
    print(f"{bits}-bit approx:   {acc(params, apx):.3f}")

    params = train(params, 30, 1e-3, acfg=apx, seed=2)
    print(f"after retrain:   {acc(params, apx):.3f}")


def lstm_flow(acu_name: str):
    print(f"\n=== LSTM x {acu_name} ===")
    task = text_cls_task(vocab=200, n_classes=2)
    emb = jax.random.normal(KEY, (200, 16)) * 0.3
    p = {"lstm": init_lstm(KEY, 16, 32),
         "head": jax.random.normal(KEY, (32, 2)) * 0.2}

    def fwd(p, toks, acfg=None):
        return lstm(emb[toks], p["lstm"], acfg) @ p["head"]

    def xent(p, toks, lab, acfg=None):
        logits = fwd(p, toks, acfg)
        return (jax.nn.logsumexp(logits, -1) -
                jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]).mean()

    def train(p, steps, lr, acfg=None, seed=3):
        step = jax.jit(lambda p, t, l: jax.tree.map(
            lambda w, g: w - lr * g, p,
            jax.grad(lambda p: xent(p, t, l, acfg))(p)))
        it = iter(task(32, seq=24, seed=seed))
        for _ in range(steps):
            b = next(it)
            p = step(p, jnp.asarray(b["tokens"]), jnp.asarray(b["label"]))
        return p

    def acc(p, acfg=None):
        it = iter(task(64, seq=24, seed=99))
        hits = 0
        for _ in range(3):
            b = next(it)
            pred = jnp.argmax(fwd(p, jnp.asarray(b["tokens"]), acfg), -1)
            hits += int((pred == jnp.asarray(b["label"])).sum())
        return hits / 192

    p = train(p, 60, 1e-2)
    print(f"FP32:            {acc(p):.3f}")
    bits = 12 if "12" in acu_name else 8
    mode = AcuMode.FUNCTIONAL if bits > 10 else AcuMode.LUT
    apx = ApproxConfig(acu=make_acu(acu_name, mode), a_bits=bits, w_bits=bits)
    print(f"{bits}-bit approx:   {acc(p, apx):.3f}")
    p = train(p, 20, 1e-3, acfg=apx, seed=4)
    print(f"after retrain:   {acc(p, apx):.3f}")


def fused_bwd_qat_step(acu_name: str):
    """One ImageNet-scale QAT step with the fused approximate backward
    (PR 6): a 1x64x224x224 conv whose STE gradients run through the ACU
    in-kernel — banded weight-grad + per-band input-grad GEMMs, so the
    (N*Ho*Wo, Kh*Kw*Cin) im2col patch tensor never exists in HBM in either
    direction (docs/fused_conv.md, "Approximate backward")."""
    print(f"\n=== fused approx-backward QAT step x {acu_name} (1x64x224x224) ===")
    from repro.core.approx_ops import conv2d, conv_plan_report

    acu = make_acu(acu_name, AcuMode.LUT, use_pallas=True, fused=True)
    apx = ApproxConfig(acu=acu, approx_bwd=True)
    x = jax.random.normal(KEY, (1, 64, 224, 224), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64, 3, 3)) * 0.05

    rep = conv_plan_report(x.shape, w.shape, apx)
    print(f"forward route: {rep['route']}, backward route: "
          f"{rep.get('bwd_route')} (no materialized im2col)")

    def loss(w):
        return (conv2d(x, w, cfg=apx) ** 2).mean()

    step = jax.jit(lambda w: w - 1e-2 * jax.grad(loss)(w))
    l0 = float(loss(w))
    w = step(w)                      # the QAT step: grads via the LUT
    print(f"loss {l0:.5f} -> {float(loss(w)):.5f} after one fused-bwd step")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--acu", default="mul8s_1L2H")
    ap.add_argument("--skip-imagenet-scale", action="store_true",
                    help="skip the 224^2 fused-backward QAT step")
    args = ap.parse_args()
    cnn_flow(args.acu)
    lstm_flow(args.acu)
    if not args.skip_imagenet_scale:
        fused_bwd_qat_step(args.acu)
