"""Quickstart: emulate an approximate multiplier inside a CNN in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's Fig. 2 flow: pick a DNN -> pick an ACU -> calibrate ->
evaluate approximately -> (optionally) fine-tune. Runs in <1 min on CPU.
"""
import jax
import jax.numpy as jnp

from repro.core import error_stats, get_multiplier, make_acu
from repro.core.acu import AcuMode
from repro.core.approx_ops import ApproxConfig, conv_plan_report
from repro.data.pipeline import image_task
from repro.models.vision import cnn_forward, init_cnn

# 1. the DNN (a small VGG-style CNN) and a synthetic classification task
key = jax.random.PRNGKey(0)
params = init_cnn(key, n_classes=4, width=8, img=16)
task = image_task(n_classes=4, size=16)

# 2. the approximate compute unit: the paper's lossy 8-bit multiplier role,
#    emulated bit-exactly through its VMEM look-up table
print("multiplier stats:", error_stats(get_multiplier("mul8s_1L2H")))
acfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT))

# which conv route will this model's first layer take? conv_plan resolves
# (geometry x mode x fusion x mesh) before anything runs — the jnp-LUT ACU
# lowers to eager im2col + LUT GEMM, while a Pallas ACU with fused=True
# rides the patch-streaming fused kernel (docs/fused_conv.md)
first_conv = dict(x_shape=(64, 3, 16, 16), w_shape=(8, 3, 3, 3))
print("conv_plan (this ACU):   ", conv_plan_report(
    first_conv["x_shape"], first_conv["w_shape"], acfg))
fused_cfg = ApproxConfig(acu=make_acu("mul8s_1L2H", AcuMode.LUT,
                                      use_pallas=True, fused=True))
print("conv_plan (fused Pallas):", conv_plan_report(
    first_conv["x_shape"], first_conv["w_shape"], fused_cfg))

# 3. quick training (exact fp32), then accuracy under exact vs approx compute
def accuracy(p, acfg=None, n=3):
    it = iter(task(64, seed=99))
    hits = tot = 0
    for _ in range(n):
        b = next(it)
        pred = jnp.argmax(cnn_forward(p, jnp.asarray(b["image"]), acfg), -1)
        hits += int((pred == jnp.asarray(b["label"])).sum())
        tot += 64
    return hits / tot

def xent(p, img, lab, acfg=None):
    logits = cnn_forward(p, img, acfg)
    return (jax.nn.logsumexp(logits, -1) -
            jnp.take_along_axis(logits, lab[:, None], -1)[:, 0]).mean()

@jax.jit
def sgd(p, img, lab):
    return jax.tree.map(lambda w, g: w - 3e-3 * g, p,
                        jax.grad(xent)(p, img, lab))

it = iter(task(64, seed=1))
for _ in range(60):
    b = next(it)
    params = sgd(params, jnp.asarray(b["image"]), jnp.asarray(b["label"]))

print(f"fp32 accuracy:        {accuracy(params):.3f}")
print(f"approx (mul8s_1L2H):  {accuracy(params, acfg):.3f}")

# 4. approximation-aware fine-tuning (approx forward, STE backward)
@jax.jit
def qat_step(p, img, lab):
    return jax.tree.map(lambda w, g: w - 1e-3 * g, p,
                        jax.grad(lambda p: xent(p, img, lab, acfg))(p))

it = iter(task(64, seed=2))
for _ in range(30):
    b = next(it)
    params = qat_step(params, jnp.asarray(b["image"]), jnp.asarray(b["label"]))

print(f"after QAT recovery:   {accuracy(params, acfg):.3f}")
